//! Per-request trace contexts and the slow-request ring.
//!
//! A sampled request (every `trace_sample`-th engine submission)
//! carries a [`TraceState`] on its reply metadata through reactor →
//! dispatch → lane → completion; each instrumented stage adds its
//! nanoseconds as it happens. When the response is delivered back to
//! the client, the state is folded into a [`TraceEntry`] — the
//! admission→delivery wall time plus the per-stage breakdown — and, if
//! the total crossed the `--trace-slow-ms` threshold, the entry is
//! pushed into a fixed-capacity ring buffer (newest wins) and dumped as
//! one structured JSON line on stderr. The ring is served back over the
//! wire by the `metrics` op (`slow_traces`).
//!
//! Traces only exist on the engine (cold) path: warm cache hits are
//! answered inline on the reactor thread and must stay
//! zero-allocation, so they are histogram-only.

use super::Stage;

/// Mutable per-request stage accumulator, boxed onto `ReqMeta` for
/// sampled requests (cold path only — the submit already allocates).
#[derive(Debug, Clone, Default)]
pub struct TraceState {
    /// Monotone trace sequence number (sampling counter value).
    pub seq: u64,
    pub parse_ns: u64,
    pub queue_wait_ns: u64,
    pub batch_assembly_ns: u64,
    pub execute_ns: u64,
    pub completion_wait_ns: u64,
}

impl TraceState {
    /// Fold one stage observation in. Stages outside the per-request
    /// path (warm lookup, registry swap, write flush) are ignored —
    /// they are histogram-only.
    pub fn note(&mut self, stage: Stage, ns: u64) {
        match stage {
            Stage::Parse => self.parse_ns += ns,
            Stage::QueueWait => self.queue_wait_ns += ns,
            Stage::BatchAssembly => self.batch_assembly_ns += ns,
            Stage::Execute => self.execute_ns += ns,
            Stage::CompletionWait => self.completion_wait_ns += ns,
            Stage::WarmLookup | Stage::RegistrySwap | Stage::WriteFlush => {}
        }
    }
}

/// One finished slow-request record: total admission→delivery latency
/// plus the attributed stage breakdown, milliseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEntry {
    pub seq: u64,
    pub op: &'static str,
    pub temp: &'static str,
    pub total_ms: f64,
    pub parse_ms: f64,
    pub queue_wait_ms: f64,
    pub batch_assembly_ms: f64,
    pub execute_ms: f64,
    pub completion_wait_ms: f64,
    /// `total - sum(stages)`, clamped at zero: reactor readiness gaps,
    /// scheduler noise, and the un-instrumented tail of the path.
    pub unattributed_ms: f64,
}

const NS_PER_MS: f64 = 1e6;

impl TraceEntry {
    /// Fold a completed [`TraceState`] into an entry. `total_ms` is the
    /// admission→delivery wall time measured by the caller.
    pub fn from_state(op: &'static str, temp: &'static str, total_ms: f64, st: &TraceState) -> TraceEntry {
        let parse_ms = st.parse_ns as f64 / NS_PER_MS;
        let queue_wait_ms = st.queue_wait_ns as f64 / NS_PER_MS;
        let batch_assembly_ms = st.batch_assembly_ns as f64 / NS_PER_MS;
        let execute_ms = st.execute_ns as f64 / NS_PER_MS;
        let completion_wait_ms = st.completion_wait_ns as f64 / NS_PER_MS;
        let attributed = parse_ms + queue_wait_ms + batch_assembly_ms + execute_ms + completion_wait_ms;
        TraceEntry {
            seq: st.seq,
            op,
            temp,
            total_ms,
            parse_ms,
            queue_wait_ms,
            batch_assembly_ms,
            execute_ms,
            completion_wait_ms,
            unattributed_ms: (total_ms - attributed).max(0.0),
        }
    }

    /// One structured JSON line for the stderr slow-request dump (keys
    /// byte-sorted, same convention as the wire).
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"batch_assembly_ms\":{:.3},\"completion_wait_ms\":{:.3},\
             \"execute_ms\":{:.3},\"op\":\"{}\",\"parse_ms\":{:.3},\
             \"queue_wait_ms\":{:.3},\"seq\":{},\"slow_trace\":true,\
             \"temp\":\"{}\",\"total_ms\":{:.3},\"unattributed_ms\":{:.3}}}",
            self.batch_assembly_ms,
            self.completion_wait_ms,
            self.execute_ms,
            self.op,
            self.parse_ms,
            self.queue_wait_ms,
            self.seq,
            self.temp,
            self.total_ms,
            self.unattributed_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn note_routes_stages_and_ignores_histogram_only_ones() {
        let mut st = TraceState::default();
        st.note(Stage::Parse, 1_000);
        st.note(Stage::QueueWait, 2_000);
        st.note(Stage::QueueWait, 3_000); // accumulates
        st.note(Stage::BatchAssembly, 4_000);
        st.note(Stage::Execute, 5_000);
        st.note(Stage::CompletionWait, 6_000);
        st.note(Stage::WarmLookup, 999_999);
        st.note(Stage::RegistrySwap, 999_999);
        st.note(Stage::WriteFlush, 999_999);
        assert_eq!(st.parse_ns, 1_000);
        assert_eq!(st.queue_wait_ns, 5_000);
        assert_eq!(st.batch_assembly_ns, 4_000);
        assert_eq!(st.execute_ns, 5_000);
        assert_eq!(st.completion_wait_ns, 6_000);
    }

    #[test]
    fn entry_attributes_and_clamps_unattributed() {
        let st = TraceState {
            seq: 3,
            parse_ns: 1_000_000,
            queue_wait_ns: 2_000_000,
            batch_assembly_ns: 0,
            execute_ns: 3_000_000,
            completion_wait_ns: 500_000,
        };
        let e = TraceEntry::from_state("predict", "cold", 10.0, &st);
        assert_eq!(e.seq, 3);
        assert!((e.unattributed_ms - 3.5).abs() < 1e-9);
        // clock skew between independent Instants can make the parts
        // exceed the whole; the residual clamps at zero
        let tight = TraceEntry::from_state("predict", "cold", 5.0, &st);
        assert_eq!(tight.unattributed_ms, 0.0);

        let line = e.to_json_line();
        assert!(line.starts_with('{') && line.ends_with('}'));
        assert!(line.contains("\"op\":\"predict\""));
        assert!(line.contains("\"slow_trace\":true"));
        assert!(line.contains("\"total_ms\":10.000"));
        crate::util::Json::parse(&line).expect("dump line is valid JSON");
    }
}
