//! # obs — the serving tier's latency observatory
//!
//! Dependency-free instrumentation layer for the coordinator: per-stage
//! log-linear latency histograms ([`hist`]), per-request trace contexts
//! with a sampled slow-request ring ([`trace`]), and the merged
//! [`MetricsSnapshot`] served by the `metrics` wire op.
//!
//! Design constraints, in order:
//!
//! 1. **The warm predict path stays zero-allocation.** Recording is a
//!    thread-local shard pick plus two relaxed atomic adds into a
//!    pre-sized bucket table — no locks, no boxing, no `Instant`
//!    indirection. Everything that allocates (snapshots, traces, the
//!    ring) lives on cold paths.
//! 2. **Shards merge on read.** Each recording thread writes its own
//!    shard (assigned round-robin on first use); the `metrics` op
//!    merges shards into one [`hist::HistSnapshot`] per cell. Merge is
//!    associative and commutative, so read-side cost never touches the
//!    hot path.
//! 3. **Fixed taxonomy.** Cells are keyed `(Stage, OpClass, Temp)` —
//!    eight pipeline stages × seven op classes × warm/cold — documented
//!    in `docs/OBSERVABILITY.md`. The cube is dense and pre-allocated
//!    (112 cells/shard) so recording never takes a map lookup.

pub mod hist;
pub mod trace;

pub use hist::{Hist, HistSnapshot, N_BUCKETS, QUANTILE_REL_ERROR};
pub use trace::{TraceEntry, TraceState};

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Instrumented pipeline stages, in request-path order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Streaming wire decode on the reactor thread.
    Parse,
    /// Prediction-cache key build + probe on the reactor thread.
    WarmLookup,
    /// Engine submit → lane dequeue (queueing delay).
    QueueWait,
    /// Lane dequeue → coalesced batch execution start.
    BatchAssembly,
    /// Engine/model execution (per coalesced group on predict lanes).
    Execute,
    /// Model-registry swap pause (publish critical section).
    RegistrySwap,
    /// Completion-queue push → reactor delivery pickup.
    CompletionWait,
    /// Response bytes → socket (per delivery flush attempt).
    WriteFlush,
}

impl Stage {
    pub const ALL: [Stage; 8] = [
        Stage::Parse,
        Stage::WarmLookup,
        Stage::QueueWait,
        Stage::BatchAssembly,
        Stage::Execute,
        Stage::RegistrySwap,
        Stage::CompletionWait,
        Stage::WriteFlush,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::WarmLookup => "warm_lookup",
            Stage::QueueWait => "queue_wait",
            Stage::BatchAssembly => "batch_assembly",
            Stage::Execute => "execute",
            Stage::RegistrySwap => "registry_swap",
            Stage::CompletionWait => "completion_wait",
            Stage::WriteFlush => "write_flush",
        }
    }

    #[inline]
    fn index(self) -> usize {
        match self {
            Stage::Parse => 0,
            Stage::WarmLookup => 1,
            Stage::QueueWait => 2,
            Stage::BatchAssembly => 3,
            Stage::Execute => 4,
            Stage::RegistrySwap => 5,
            Stage::CompletionWait => 6,
            Stage::WriteFlush => 7,
        }
    }
}

/// Op classes histograms are keyed by. The phase-2 interpolation ops
/// ride under [`OpClass::Predict`]; `health`/`stats`/`instances`/
/// `metrics` and infrastructure work (write backlog flushes, registry
/// swaps) land in [`OpClass::Other`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Predict,
    Recommend,
    Plan,
    Ingest,
    Onboard,
    Reload,
    Other,
}

impl OpClass {
    pub const ALL: [OpClass; 7] = [
        OpClass::Predict,
        OpClass::Recommend,
        OpClass::Plan,
        OpClass::Ingest,
        OpClass::Onboard,
        OpClass::Reload,
        OpClass::Other,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OpClass::Predict => "predict",
            OpClass::Recommend => "recommend",
            OpClass::Plan => "plan",
            OpClass::Ingest => "ingest",
            OpClass::Onboard => "onboard",
            OpClass::Reload => "reload",
            OpClass::Other => "other",
        }
    }

    #[inline]
    fn index(self) -> usize {
        match self {
            OpClass::Predict => 0,
            OpClass::Recommend => 1,
            OpClass::Plan => 2,
            OpClass::Ingest => 3,
            OpClass::Onboard => 4,
            OpClass::Reload => 5,
            OpClass::Other => 6,
        }
    }
}

/// Cache temperature of the path that served the request. Only
/// meaningful for `predict`; every other op records as [`Temp::Cold`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Temp {
    /// Answered inline from the prediction cache on the reactor thread.
    Warm,
    /// Went through an engine lane.
    Cold,
}

impl Temp {
    pub fn name(self) -> &'static str {
        match self {
            Temp::Warm => "warm",
            Temp::Cold => "cold",
        }
    }

    #[inline]
    fn index(self) -> usize {
        match self {
            Temp::Warm => 0,
            Temp::Cold => 1,
        }
    }
}

const N_OPS: usize = OpClass::ALL.len();
const N_TEMPS: usize = 2;
const N_CELLS: usize = Stage::ALL.len() * N_OPS * N_TEMPS;

#[inline]
fn cell_index(stage: Stage, op: OpClass, temp: Temp) -> usize {
    (stage.index() * N_OPS + op.index()) * N_TEMPS + temp.index()
}

/// Recording shards per [`Obs`]. More than the reactor-thread cap (4)
/// plus a typical lane count, so contention is rare even on wide
/// machines; threads beyond this share shards round-robin.
const N_SHARDS: usize = 8;

/// Capacity of the slow-request ring (newest entries win).
pub const SLOW_RING_CAP: usize = 64;

/// Process-wide thread registration for shard picks: each thread gets a
/// stable small integer on first record, used modulo the shard count.
/// Shared across `Obs` instances by design — the slot is a property of
/// the thread, not of the registry.
static THREAD_SEQ: AtomicUsize = AtomicUsize::new(0);
thread_local! {
    static THREAD_SLOT: Cell<usize> = const { Cell::new(usize::MAX) };
}

#[inline]
fn thread_slot() -> usize {
    THREAD_SLOT.with(|s| {
        let v = s.get();
        if v != usize::MAX {
            return v;
        }
        // ordering: slot assignment only needs uniqueness, which
        // fetch_add gives under any ordering.
        let v = THREAD_SEQ.fetch_add(1, Relaxed);
        s.set(v);
        v
    })
}

struct Shard {
    cells: Vec<Hist>,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            cells: (0..N_CELLS).map(|_| Hist::new()).collect(),
        }
    }
}

/// The per-pool observatory: pre-sized histogram shards, the trace
/// sampling config, and the slow-request ring. One per [`EnginePool`];
/// shared by reactor threads, lanes, and the registry via `Arc`.
///
/// [`EnginePool`]: crate::coordinator::EnginePool
pub struct Obs {
    shards: Vec<Shard>,
    started: Instant,
    trace_slow_ms: f64,
    trace_sample: u64,
    trace_seq: AtomicU64,
    slow_ring: Mutex<VecDeque<TraceEntry>>,
}

impl Obs {
    /// `trace_slow_ms`: completed traces at/above this total land in
    /// the ring (and on stderr). `trace_sample`: every Nth engine
    /// submission carries a trace; `0` disables tracing entirely.
    pub fn new(trace_slow_ms: f64, trace_sample: u64) -> Obs {
        Obs {
            shards: (0..N_SHARDS).map(|_| Shard::new()).collect(),
            started: Instant::now(),
            trace_slow_ms,
            trace_sample,
            trace_seq: AtomicU64::new(0),
            slow_ring: Mutex::new(VecDeque::with_capacity(SLOW_RING_CAP)),
        }
    }

    /// Seconds since this observatory (== its pool) was built.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn trace_slow_ms(&self) -> f64 {
        self.trace_slow_ms
    }

    /// Record one stage duration. Alloc-free and lock-free: a
    /// thread-local shard pick plus two relaxed atomic adds.
    #[inline]
    pub fn record(&self, stage: Stage, op: OpClass, temp: Temp, dur: Duration) {
        self.record_ns(stage, op, temp, dur.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// [`Obs::record`] with a raw nanosecond value.
    #[inline]
    pub fn record_ns(&self, stage: Stage, op: OpClass, temp: Temp, ns: u64) {
        let shard = &self.shards[thread_slot() % self.shards.len()];
        shard.cells[cell_index(stage, op, temp)].record(ns);
    }

    /// Sampling decision for an engine submission: every
    /// `trace_sample`-th call returns a fresh boxed [`TraceState`].
    /// Allocates — cold path only (the submit it rides already does).
    pub fn maybe_trace(&self) -> Option<Box<TraceState>> {
        if self.trace_sample == 0 {
            return None;
        }
        // ordering: sampling counter — 1-in-N selection needs no
        // cross-thread ordering, only atomicity.
        let seq = self.trace_seq.fetch_add(1, Relaxed);
        if seq % self.trace_sample != 0 {
            return None;
        }
        Some(Box::new(TraceState {
            seq,
            ..TraceState::default()
        }))
    }

    /// Fold a delivered trace into the slow ring if it crossed the
    /// threshold, dumping one structured JSON line on stderr.
    pub fn complete_trace(&self, entry: TraceEntry) {
        if entry.total_ms < self.trace_slow_ms {
            return;
        }
        eprintln!("{}", entry.to_json_line());
        let mut ring = self.slow_ring.lock().unwrap();
        if ring.len() == SLOW_RING_CAP {
            ring.pop_front();
        }
        ring.push_back(entry);
    }

    /// Current ring contents, oldest first.
    pub fn slow_traces(&self) -> Vec<TraceEntry> {
        self.slow_ring.lock().unwrap().iter().cloned().collect()
    }

    /// Merge all shards for one `(stage, op, temp)` cell.
    pub fn cell_snapshot(&self, stage: Stage, op: OpClass, temp: Temp) -> HistSnapshot {
        let idx = cell_index(stage, op, temp);
        let mut out = HistSnapshot::empty();
        for shard in &self.shards {
            out.merge(&shard.cells[idx].snapshot());
        }
        out
    }

    /// The full merged read-side view: every non-empty cell of every
    /// stage, shards combined, quantiles extracted. Allocates freely —
    /// this backs the cold `metrics` op.
    pub fn stage_summaries(&self) -> Vec<StageSummary> {
        let mut stages = Vec::new();
        for stage in Stage::ALL {
            let mut cells = Vec::new();
            for op in OpClass::ALL {
                for temp in [Temp::Warm, Temp::Cold] {
                    let snap = self.cell_snapshot(stage, op, temp);
                    if snap.count == 0 {
                        continue;
                    }
                    cells.push(CellSummary::from_snapshot(op.name(), temp.name(), &snap));
                }
            }
            if !cells.is_empty() {
                stages.push(StageSummary {
                    stage: stage.name(),
                    cells,
                });
            }
        }
        stages
    }
}

const NS_PER_MS: f64 = 1e6;

/// One stage's non-empty cells, as served by the `metrics` op.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSummary {
    pub stage: &'static str,
    pub cells: Vec<CellSummary>,
}

/// One `(op, temp)` histogram cell: exact count/sum, bucketed
/// quantiles, and the sparse bucket table itself (so clients — e.g.
/// `repro loadgen` — can diff two snapshots and re-extract quantiles
/// for the window between them).
#[derive(Debug, Clone, PartialEq)]
pub struct CellSummary {
    pub op: &'static str,
    pub temp: &'static str,
    pub count: u64,
    pub sum_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    /// Upper bound of the highest non-empty bucket (bucketed, not
    /// exact — see `docs/OBSERVABILITY.md`).
    pub max_ms: f64,
    /// Sparse `(bucket index, count)` pairs, ascending.
    pub buckets: Vec<(u32, u64)>,
}

impl CellSummary {
    pub fn from_snapshot(op: &'static str, temp: &'static str, snap: &HistSnapshot) -> CellSummary {
        CellSummary {
            op,
            temp,
            count: snap.count,
            sum_ms: snap.sum_ns as f64 / NS_PER_MS,
            p50_ms: snap.quantile_ns(0.50) as f64 / NS_PER_MS,
            p90_ms: snap.quantile_ns(0.90) as f64 / NS_PER_MS,
            p99_ms: snap.quantile_ns(0.99) as f64 / NS_PER_MS,
            max_ms: snap.max_ns() as f64 / NS_PER_MS,
            buckets: snap.buckets.clone(),
        }
    }
}

/// Everything the `metrics` op returns: process uptime, flat gauges
/// (filled by the router from the engine stats + registry), the merged
/// per-stage histograms, and the slow-trace ring.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub uptime_s: f64,
    /// `(name, value)` pairs, **byte-sorted by name** (the encoder
    /// emits them in order).
    pub gauges: Vec<(&'static str, f64)>,
    pub stages: Vec<StageSummary>,
    pub slow: Vec<TraceEntry>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_index_is_a_bijection_over_the_cube() {
        let mut seen = vec![false; N_CELLS];
        for stage in Stage::ALL {
            for op in OpClass::ALL {
                for temp in [Temp::Warm, Temp::Cold] {
                    let idx = cell_index(stage, op, temp);
                    assert!(!seen[idx], "collision at {stage:?}/{op:?}/{temp:?}");
                    seen[idx] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn record_lands_in_the_right_cell_and_merges_across_threads() {
        let obs = std::sync::Arc::new(Obs::new(250.0, 1));
        obs.record(Stage::Parse, OpClass::Predict, Temp::Warm, Duration::from_micros(5));
        // other cells stay empty
        assert_eq!(obs.cell_snapshot(Stage::Parse, OpClass::Predict, Temp::Cold).count, 0);
        assert_eq!(obs.cell_snapshot(Stage::Execute, OpClass::Predict, Temp::Warm).count, 0);
        assert_eq!(obs.cell_snapshot(Stage::Parse, OpClass::Predict, Temp::Warm).count, 1);

        // 4 threads × 100 records merge losslessly regardless of which
        // shard each thread landed on
        let mut handles = Vec::new();
        for _ in 0..4 {
            let o = obs.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    o.record_ns(Stage::Execute, OpClass::Recommend, Temp::Cold, 1_000 + i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = obs.cell_snapshot(Stage::Execute, OpClass::Recommend, Temp::Cold);
        assert_eq!(snap.count, 400);
        assert_eq!(snap.sum_ns, 4 * (100 * 1_000 + (0..100).sum::<u64>()));

        let stages = obs.stage_summaries();
        assert_eq!(stages.len(), 2, "only non-empty stages are emitted");
        assert_eq!(stages[0].stage, "parse");
        assert_eq!(stages[1].stage, "execute");
        let cell = &stages[1].cells[0];
        assert_eq!((cell.op, cell.temp, cell.count), ("recommend", "cold", 400));
        assert!(cell.p50_ms > 0.0 && cell.p99_ms >= cell.p50_ms);
        assert!(!cell.buckets.is_empty());
    }

    #[test]
    fn trace_sampling_and_slow_ring_semantics() {
        let obs = Obs::new(5.0, 3);
        // every 3rd submission is sampled, starting with the first
        let picks: Vec<bool> = (0..9).map(|_| obs.maybe_trace().is_some()).collect();
        assert_eq!(picks, [true, false, false, true, false, false, true, false, false]);

        // below-threshold traces never enter the ring
        let fast = TraceEntry::from_state("predict", "cold", 1.0, &TraceState::default());
        obs.complete_trace(fast);
        assert!(obs.slow_traces().is_empty());

        // slow ones do, newest-wins at capacity
        for i in 0..(SLOW_RING_CAP + 5) {
            let st = TraceState {
                seq: i as u64,
                ..TraceState::default()
            };
            obs.complete_trace(TraceEntry::from_state("recommend", "cold", 10.0, &st));
        }
        let ring = obs.slow_traces();
        assert_eq!(ring.len(), SLOW_RING_CAP);
        assert_eq!(ring.first().unwrap().seq, 5, "oldest entries evicted");
        assert_eq!(ring.last().unwrap().seq, (SLOW_RING_CAP + 4) as u64);

        // sample = 0 disables tracing
        let off = Obs::new(0.0, 0);
        assert!(off.maybe_trace().is_none());
    }

    #[test]
    fn uptime_is_monotone() {
        let obs = Obs::new(250.0, 1);
        let a = obs.uptime_s();
        std::thread::sleep(Duration::from_millis(2));
        assert!(obs.uptime_s() > a);
        assert!(a >= 0.0);
    }
}
