//! Mergeable log-linear latency histograms.
//!
//! The bucket table is **fixed and global**: base-2 log-linear with
//! [`SUB`] (= 8) linear sub-buckets per power-of-two octave, indexed in
//! nanoseconds. Values below 8 ns get one bucket per nanosecond
//! (indices 0..8); a value `v >= 8` with `e = floor(log2 v)` lands in
//! index `(e - 2) * 8 + m` where `m` is the top three mantissa bits
//! below the leading one. Octaves above [`MAX_EXP`] (2^36 ns ≈ 68.7 s)
//! collapse into the top bucket, so anything slower than ~137 s
//! saturates there — durations, not timestamps, so the cap is generous.
//!
//! The scheme gives every bucket a relative width of `1/(8+m) <= 1/8`,
//! so quoting a bucket **midpoint** as a quantile is within **12.5 %**
//! of the exact order statistic (typically half that); the property
//! tests below enforce the bound against the exact sorted-sample
//! reference ([`crate::util::quantile`]).
//!
//! [`Hist`] is the live, lock-free recording cell (a flat array of
//! relaxed atomics — recording is two `fetch_add`s and never
//! allocates). [`HistSnapshot`] is the frozen, sparse, *mergeable*
//! read-side value: merging is pointwise addition of bucket counts, so
//! it is associative and commutative and per-thread shards can be
//! combined in any order on read.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// log2 of the number of linear sub-buckets per octave.
const SUB_BITS: u32 = 3;
/// Linear sub-buckets per power-of-two octave.
pub const SUB: u64 = 1 << SUB_BITS;
/// Largest fully-resolved exponent: values in `[2^36, 2^37)` ns fill
/// the last octave; anything `>= 2^37` ns saturates into its top
/// bucket.
const MAX_EXP: u32 = 36;
/// Total bucket count (indices `0 .. N_BUCKETS`).
pub const N_BUCKETS: usize = ((MAX_EXP - 2) as usize) * (SUB as usize) + (SUB as usize);
/// Documented relative-error bound for bucketed quantiles.
pub const QUANTILE_REL_ERROR: f64 = 1.0 / SUB as f64;

/// Bucket index for a duration in nanoseconds. Total and monotone
/// non-decreasing over `u64`.
#[inline]
pub fn bucket_index(ns: u64) -> usize {
    if ns < SUB {
        return ns as usize;
    }
    let e = 63 - ns.leading_zeros();
    if e > MAX_EXP {
        return N_BUCKETS - 1;
    }
    let m = (ns >> (e - SUB_BITS)) & (SUB - 1);
    ((e - 2) as usize) * (SUB as usize) + m as usize
}

/// `[lower, upper)` bounds of a bucket, nanoseconds. The top bucket's
/// upper bound is its nominal octave edge — saturated values above it
/// are still *counted* there (their `sum_ns` stays exact).
#[inline]
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    debug_assert!(idx < N_BUCKETS);
    if idx < SUB as usize {
        return (idx as u64, idx as u64 + 1);
    }
    let e = (idx as u64 / SUB) + 2;
    let m = idx as u64 % SUB;
    let lo = (1u64 << e) + (m << (e - SUB_BITS as u64));
    (lo, lo + (1u64 << (e - SUB_BITS as u64)))
}

/// Midpoint representative quoted for quantiles in a bucket.
#[inline]
pub fn bucket_mid(idx: usize) -> u64 {
    let (lo, hi) = bucket_bounds(idx);
    lo + (hi - lo) / 2
}

/// One live histogram cell: fixed bucket table of relaxed atomics plus
/// an exact running sum. Recording never allocates and never locks.
pub struct Hist {
    buckets: Vec<AtomicU64>,
    sum_ns: AtomicU64,
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one duration (nanoseconds). Two relaxed `fetch_add`s.
    #[inline]
    pub fn record(&self, ns: u64) {
        // ordering: per-bucket tallies are independent monotonic counters;
        // snapshots tolerate tearing across buckets by design.
        self.buckets[bucket_index(ns)].fetch_add(1, Relaxed);
        self.sum_ns.fetch_add(ns, Relaxed);
    }

    /// Cheap emptiness probe without building a snapshot.
    pub fn is_empty(&self) -> bool {
        // ordering: advisory probe; a racing record may flip the answer
        // either way, and callers only use it to skip empty cells.
        self.sum_ns.load(Relaxed) == 0 && self.buckets.iter().all(|b| b.load(Relaxed) == 0)
    }

    /// Freeze the current counts into a sparse snapshot.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut out = HistSnapshot::empty();
        // ordering: snapshot reads race with recording threads; each cell
        // is read once and small cross-bucket skew is acceptable.
        for (idx, b) in self.buckets.iter().enumerate() {
            let c = b.load(Relaxed);
            if c > 0 {
                out.buckets.push((idx as u32, c));
                out.count += c;
            }
        }
        out.sum_ns = self.sum_ns.load(Relaxed); // ordering: same snapshot contract
        out
    }
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

/// Frozen sparse histogram: `(bucket index, count)` pairs sorted by
/// index, plus exact totals. Merging is pointwise addition —
/// associative and commutative — so shards combine in any order.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistSnapshot {
    /// Non-empty buckets, sorted by index.
    pub buckets: Vec<(u32, u64)>,
    /// Total recorded samples (sum of bucket counts).
    pub count: u64,
    /// Exact sum of recorded nanoseconds (not bucketed).
    pub sum_ns: u64,
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot::default()
    }

    /// Pointwise-add `other` into `self`.
    pub fn merge(&mut self, other: &HistSnapshot) {
        if other.buckets.is_empty() {
            self.sum_ns += other.sum_ns;
            self.count += other.count;
            return;
        }
        let mut merged = Vec::with_capacity(self.buckets.len() + other.buckets.len());
        let (mut i, mut j) = (0, 0);
        while i < self.buckets.len() && j < other.buckets.len() {
            let (ai, ac) = self.buckets[i];
            let (bi, bc) = other.buckets[j];
            if ai < bi {
                merged.push((ai, ac));
                i += 1;
            } else if bi < ai {
                merged.push((bi, bc));
                j += 1;
            } else {
                merged.push((ai, ac + bc));
                i += 1;
                j += 1;
            }
        }
        merged.extend_from_slice(&self.buckets[i..]);
        merged.extend_from_slice(&other.buckets[j..]);
        self.buckets = merged;
        self.count += other.count;
        self.sum_ns += other.sum_ns;
    }

    /// Bucketed quantile: walk cumulative counts to the 0-based rank
    /// `round(q * (count - 1))` and quote that bucket's midpoint.
    /// Within [`QUANTILE_REL_ERROR`] of the exact order statistic.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for &(idx, c) in &self.buckets {
            seen += c;
            if seen > rank {
                return bucket_mid(idx as usize);
            }
        }
        bucket_mid(self.buckets.last().map(|&(i, _)| i as usize).unwrap_or(0))
    }

    /// Upper bound of the highest non-empty bucket (0 if empty) — an
    /// upper estimate of the maximum recorded value, except for
    /// saturated samples which may exceed it.
    pub fn max_ns(&self) -> u64 {
        self.buckets
            .last()
            .map(|&(i, _)| bucket_bounds(i as usize).1)
            .unwrap_or(0)
    }

    /// Mean in nanoseconds (exact, from `sum_ns`).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Pointwise `self - earlier` (saturating), for before/after deltas
    /// over a monotone counter source (e.g. `repro loadgen` bracketing
    /// a run with two `metrics` snapshots).
    pub fn diff_from(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut out = HistSnapshot::empty();
        let (mut i, mut j) = (0, 0);
        while i < self.buckets.len() {
            let (ai, ac) = self.buckets[i];
            let mut c = ac;
            while j < earlier.buckets.len() && earlier.buckets[j].0 < ai {
                j += 1;
            }
            if j < earlier.buckets.len() && earlier.buckets[j].0 == ai {
                c = ac.saturating_sub(earlier.buckets[j].1);
            }
            if c > 0 {
                out.buckets.push((ai, c));
                out.count += c;
            }
            i += 1;
        }
        out.sum_ns = self.sum_ns.saturating_sub(earlier.sum_ns);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quantile;

    /// Deterministic 64-bit LCG (MMIX constants) for seeded fuzzing.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self
                .0
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            self.0
        }
        fn uniform01(&mut self) -> f64 {
            (self.next() >> 11) as f64 / (1u64 << 53) as f64
        }
    }

    #[test]
    fn index_is_total_monotone_and_bounds_contain() {
        let mut probes: Vec<u64> = (0..4096).collect();
        for e in 3..63u32 {
            let p = 1u64 << e;
            probes.extend_from_slice(&[p - 1, p, p + 1, p + (p >> 1)]);
        }
        probes.extend_from_slice(&[u64::MAX - 1, u64::MAX]);
        probes.sort_unstable();
        let mut prev = 0usize;
        for &v in &probes {
            let idx = bucket_index(v);
            assert!(idx < N_BUCKETS, "idx {idx} out of range for {v}");
            assert!(idx >= prev, "index not monotone at {v}");
            prev = idx;
            let (lo, hi) = bucket_bounds(idx);
            if idx < N_BUCKETS - 1 {
                assert!(lo <= v && v < hi, "{v} outside [{lo},{hi}) idx {idx}");
            } else {
                assert!(v >= lo, "top bucket lower bound broken for {v}");
            }
        }
        // every bucket index round-trips through its own lower bound
        for idx in 0..N_BUCKETS {
            let (lo, hi) = bucket_bounds(idx);
            assert!(lo < hi);
            assert_eq!(bucket_index(lo), idx);
            assert_eq!(bucket_index(hi - 1), idx);
        }
    }

    #[test]
    fn bucketed_quantiles_match_exact_reference_within_documented_error() {
        // three seeded shapes: log-uniform (1 µs .. 1 s), uniform
        // (0.1 .. 10 ms), and a bimodal warm/cold mixture
        for (seed, shape) in [(11u64, 0), (42, 1), (1234, 2)] {
            let mut rng = Lcg(seed);
            let h = Hist::new();
            let mut exact: Vec<f64> = Vec::new();
            for _ in 0..512 {
                let u = rng.uniform01();
                let ns = match shape {
                    0 => (1e3 * (1e6f64).powf(u)) as u64,
                    1 => (1e5 + u * 9.9e6) as u64,
                    _ => {
                        if rng.uniform01() < 0.8 {
                            (5e4 + u * 1e5) as u64
                        } else {
                            (2e7 + u * 3e8) as u64
                        }
                    }
                };
                h.record(ns);
                exact.push(ns as f64);
            }
            let snap = h.snapshot();
            assert_eq!(snap.count, 512);
            for q in [0.5, 0.9, 0.99] {
                let approx = snap.quantile_ns(q) as f64;
                let reference = quantile(&exact, q);
                let rel = (approx - reference).abs() / reference;
                assert!(
                    rel <= QUANTILE_REL_ERROR,
                    "seed {seed} shape {shape} q{q}: {approx} vs {reference} (rel {rel:.4})"
                );
            }
            // mean is exact, not bucketed
            let mean_ref = exact.iter().sum::<f64>() / exact.len() as f64;
            assert!((snap.mean_ns() - mean_ref).abs() < 1.0);
        }
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mut rng = Lcg(7);
        let mk = |rng: &mut Lcg| {
            let h = Hist::new();
            for _ in 0..200 {
                h.record(rng.next() % 1_000_000_000);
            }
            h.snapshot()
        };
        let (a, b, c) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge not commutative");

        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge not associative");
        assert_eq!(ab_c.count, a.count + b.count + c.count);
        assert_eq!(ab_c.sum_ns, a.sum_ns + b.sum_ns + c.sum_ns);
    }

    #[test]
    fn top_bucket_saturates_without_losing_counts_or_sums() {
        let h = Hist::new();
        let big = 1u64 << 40; // ~18 min, far past the 2^37 ns octave edge
        h.record(big);
        h.record(u64::MAX / 2);
        h.record(100);
        let snap = h.snapshot();
        assert_eq!(snap.count, 3);
        assert_eq!(snap.sum_ns, big + u64::MAX / 2 + 100);
        let top = snap.buckets.last().unwrap();
        assert_eq!(top.0 as usize, N_BUCKETS - 1);
        assert_eq!(top.1, 2, "both oversized samples share the top bucket");
        // p99 lands in the top bucket and quotes its midpoint
        assert_eq!(snap.quantile_ns(0.99), bucket_mid(N_BUCKETS - 1));
        assert_eq!(snap.max_ns(), bucket_bounds(N_BUCKETS - 1).1);
    }

    #[test]
    fn diff_from_recovers_a_window() {
        let h = Hist::new();
        for ns in [100u64, 5_000, 5_000] {
            h.record(ns);
        }
        let before = h.snapshot();
        for ns in [100u64, 70_000] {
            h.record(ns);
        }
        let after = h.snapshot();
        let window = after.diff_from(&before);
        assert_eq!(window.count, 2);
        assert_eq!(window.sum_ns, 70_100);
        assert_eq!(window.buckets.len(), 2);
        // empty window when nothing moved
        assert_eq!(after.diff_from(&after), HistSnapshot::empty());
    }
}
