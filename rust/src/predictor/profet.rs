//! The end-to-end PROFET facade (Fig 3): holds the fitted feature space,
//! every cross-instance ensemble, and the per-instance batch/pixel models;
//! persists to / loads from a model directory.
//!
//! # Model directory layout
//!
//! [`Profet::save`] writes one JSON file per component plus a
//! `manifest.json` inventory:
//!
//! ```text
//! models/
//!   manifest.json           # expected cross pairs + scale instances
//!   feature_space.json      # fitted op-name clustering / vectorizer
//!   cross_<a>_<t>.json      # one per (anchor, target) ensemble
//!   scale_<g>.json          # one per-instance batch/pixel model
//! ```
//!
//! [`Profet::load`] checks the directory against the manifest and fails
//! **at load time** with a structured [`MissingModels`] error when a
//! listed component file is absent — a registry candidate with a deleted
//! or half-copied model dir is rejected before it can serve a single
//! request (the old behavior deferred the failure to the first `predict`
//! for the missing pair). Directories written before the manifest existed
//! load as before (no completeness information to check against).

use super::batch_pixel::BatchPixelModel;
use super::cross_instance::{CrossInstanceModel, EnsembleConfig, Member};
use crate::data::Corpus;
use crate::features::FeatureSpace;
use crate::gpu::Instance;
use crate::runtime::Runtime;
use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// Training options for the full system.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// Anchor instances to train models *from*.
    pub anchors: Vec<Instance>,
    /// Target instances to train models *to*.
    pub targets: Vec<Instance>,
    /// Operation-name clustering on/off (Fig 13 ablation).
    pub clustering: bool,
    /// Polynomial order for the batch/pixel phase (Fig 12 ablation).
    pub poly_order: usize,
    /// Ensemble member hyper-parameters.
    pub n_trees: usize,
    pub dnn_epochs: usize,
    pub seed: u64,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            anchors: Instance::CORE.to_vec(),
            targets: Instance::CORE.to_vec(),
            clustering: true,
            poly_order: 2,
            n_trees: 100,
            dnn_epochs: 120,
            seed: 0xC0FFEE,
        }
    }
}

/// Structured load-time completeness failure: the model directory's
/// `manifest.json` lists components whose files are missing or unreadable.
/// Carried inside the `anyhow` error chain ([`Profet::load`]) so callers —
/// notably the coordinator's model-registry validation gate — can
/// `downcast_ref::<MissingModels>()` and enumerate exactly which pairs are
/// gone instead of pattern-matching an error string.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MissingModels {
    /// Missing cross-instance ensembles, `(anchor, target)`.
    pub cross: Vec<(Instance, Instance)>,
    /// Missing per-instance batch/pixel models.
    pub scale: Vec<Instance>,
}

impl MissingModels {
    pub fn is_empty(&self) -> bool {
        self.cross.is_empty() && self.scale.is_empty()
    }
}

impl fmt::Display for MissingModels {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "model dir is missing ")?;
        let mut sep = "";
        if !self.cross.is_empty() {
            let pairs: Vec<String> = self
                .cross
                .iter()
                .map(|(a, t)| format!("{a}->{t}"))
                .collect();
            write!(
                f,
                "{} cross-instance model(s): {}",
                self.cross.len(),
                pairs.join(", ")
            )?;
            sep = "; ";
        }
        if !self.scale.is_empty() {
            let insts: Vec<&str> = self.scale.iter().map(|g| g.key()).collect();
            write!(
                f,
                "{sep}{} batch/pixel model(s): {}",
                self.scale.len(),
                insts.join(", ")
            )?;
        }
        Ok(())
    }
}

impl std::error::Error for MissingModels {}

/// The trained system. `Clone` is cheap relative to training (the models
/// are plain data) and is what the coordinator's registry leans on to
/// build an onboarding candidate next to the live epoch
/// ([`Profet::retrain_pairs`]).
#[derive(Clone)]
pub struct Profet {
    pub feature_space: FeatureSpace,
    pub cross: BTreeMap<(Instance, Instance), CrossInstanceModel>,
    pub scale: BTreeMap<Instance, BatchPixelModel>,
}

impl Profet {
    /// Train everything from corpus entries `train_idx`.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use repro::data::Corpus;
    /// use repro::gpu::Instance;
    /// use repro::predictor::{Profet, TrainOptions};
    ///
    /// let rt = repro::runtime::load_default()?;
    /// let corpus = Corpus::generate(&Instance::ALL);
    /// let (train_idx, _test_idx) = corpus.split_random(0.2, 7);
    /// let profet = Profet::train(&rt, &corpus, &train_idx, &TrainOptions::default())?;
    /// assert!(!profet.cross.is_empty());
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn train(
        rt: &Runtime,
        corpus: &Corpus,
        train_idx: &[usize],
        opts: &TrainOptions,
    ) -> Result<Profet> {
        // feature space from the *training* vocabulary only
        let keep: std::collections::BTreeSet<usize> = train_idx.iter().copied().collect();
        let mut vocab_set = std::collections::BTreeSet::new();
        for (i, e) in corpus.entries.iter().enumerate() {
            if !keep.contains(&i) {
                continue;
            }
            for run in e.runs.values() {
                for op in run.profile.keys() {
                    vocab_set.insert(op.as_str());
                }
            }
        }
        let vocab: Vec<&str> = vocab_set.into_iter().collect();
        let feature_space = FeatureSpace::fit(&vocab, opts.clustering, rt.meta.d_feat)?;

        let mut cross = BTreeMap::new();
        for &a in &opts.anchors {
            for &t in &opts.targets {
                if a == t {
                    continue;
                }
                let m = CrossInstanceModel::fit(
                    rt,
                    &feature_space,
                    corpus,
                    train_idx,
                    a,
                    t,
                    EnsembleConfig {
                        n_trees: opts.n_trees,
                        dnn_epochs: opts.dnn_epochs,
                        seed: opts.seed ^ crate::util::seed_of(&[a.key(), t.key()]),
                    },
                )
                .with_context(|| format!("cross model {a}->{t}"))?;
                cross.insert((a, t), m);
            }
        }

        let mut scale = BTreeMap::new();
        for &g in opts.anchors.iter().chain(&opts.targets) {
            if scale.contains_key(&g) {
                continue;
            }
            if let Ok(m) = BatchPixelModel::fit(corpus, train_idx, g, opts.poly_order) {
                scale.insert(g, m);
            }
        }

        Ok(Profet {
            feature_space,
            cross,
            scale,
        })
    }

    /// Phase-1 prediction: latency of the profiled workload on `target`.
    pub fn predict_cross(
        &self,
        rt: &Runtime,
        anchor: Instance,
        target: Instance,
        profile: &BTreeMap<String, f64>,
        anchor_latency_ms: f64,
    ) -> Result<(f64, Member)> {
        let model = self
            .cross
            .get(&(anchor, target))
            .ok_or_else(|| anyhow!("no model for {anchor}->{target}"))?;
        let x = self.feature_space.vectorize(profile);
        model.predict(rt, &x, anchor_latency_ms)
    }

    /// Phase-2 prediction: latency at batch `b` on `instance`, given
    /// min/max-batch latencies (measured or phase-1-predicted) — Fig 11.
    pub fn predict_batch_size(
        &self,
        instance: Instance,
        b: usize,
        t_min: f64,
        t_max: f64,
    ) -> Result<f64> {
        let m = self
            .scale
            .get(&instance)
            .ok_or_else(|| anyhow!("no batch/pixel model for {instance}"))?;
        Ok(m.predict_batch(b, t_min, t_max))
    }

    /// Phase-2 prediction for input pixel size.
    pub fn predict_pixel_size(
        &self,
        instance: Instance,
        p: usize,
        t_min: f64,
        t_max: f64,
    ) -> Result<f64> {
        let m = self
            .scale
            .get(&instance)
            .ok_or_else(|| anyhow!("no batch/pixel model for {instance}"))?;
        Ok(m.predict_pixels(p, t_min, t_max))
    }

    /// Full two-phase scenario (Fig 11 "Predict"): profiles of the min- and
    /// max-batch workloads on the anchor → latency at batch `b` on target.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_scenario(
        &self,
        rt: &Runtime,
        anchor: Instance,
        target: Instance,
        profile_min: &BTreeMap<String, f64>,
        anchor_lat_min: f64,
        profile_max: &BTreeMap<String, f64>,
        anchor_lat_max: f64,
        b: usize,
    ) -> Result<f64> {
        let (t_min, _) = self.predict_cross(rt, anchor, target, profile_min, anchor_lat_min)?;
        let (t_max, _) = self.predict_cross(rt, anchor, target, profile_max, anchor_lat_max)?;
        self.predict_batch_size(target, b, t_min, t_max)
    }

    /// Retrain the given `(anchor, target)` cross-instance ensembles from
    /// `corpus` and return a **new** `Profet` that is this one plus the
    /// refitted pairs — the online-onboarding path behind the
    /// coordinator's `onboard` op.
    ///
    /// The existing [`FeatureSpace`] is reused verbatim (op names the
    /// frozen vocabulary has never seen vectorize to zero, exactly as they
    /// would at predict time), so the refitted pairs stay compatible with
    /// every model already in the registry. Per-pair hyper-parameters and
    /// seed derivation match [`Profet::train`] exactly. Instances that
    /// appear in `pairs` but have no batch/pixel interpolation model yet
    /// get one fitted from `corpus` when it contains the min/max-batch
    /// observations that fit needs; instances that already have one keep
    /// it (the staged onboarding corpus is typically far smaller than the
    /// corpus the existing model was fitted on).
    ///
    /// `self` is untouched: on any error the caller still holds the old,
    /// fully working model set — which is what lets the registry keep the
    /// previous epoch serving when onboarding fails.
    pub fn retrain_pairs(
        &self,
        rt: &Runtime,
        corpus: &Corpus,
        train_idx: &[usize],
        pairs: &[(Instance, Instance)],
        opts: &TrainOptions,
    ) -> Result<Profet> {
        anyhow::ensure!(!pairs.is_empty(), "no (anchor, target) pairs to retrain");
        let mut next = self.clone();
        for &(a, t) in pairs {
            anyhow::ensure!(a != t, "cannot retrain identity pair {a}->{t}");
            let m = CrossInstanceModel::fit(
                rt,
                &next.feature_space,
                corpus,
                train_idx,
                a,
                t,
                EnsembleConfig {
                    n_trees: opts.n_trees,
                    dnn_epochs: opts.dnn_epochs,
                    seed: opts.seed ^ crate::util::seed_of(&[a.key(), t.key()]),
                },
            )
            .with_context(|| format!("retraining cross model {a}->{t}"))?;
            next.cross.insert((a, t), m);
        }
        for &(a, t) in pairs {
            for g in [a, t] {
                if next.scale.contains_key(&g) {
                    continue;
                }
                if let Ok(m) = BatchPixelModel::fit(corpus, train_idx, g, opts.poly_order) {
                    next.scale.insert(g, m);
                }
            }
        }
        Ok(next)
    }

    /// Save to a directory: one JSON per component plus a `manifest.json`
    /// inventory that [`Profet::load`] verifies the directory against.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use repro::predictor::Profet;
    ///
    /// let profet = Profet::load("models")?;
    /// profet.save("models_backup")?;
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            dir.join("feature_space.json"),
            self.feature_space.to_json().to_string(),
        )?;
        for ((a, t), m) in &self.cross {
            std::fs::write(
                dir.join(format!("cross_{}_{}.json", a.key(), t.key())),
                m.to_json().to_string(),
            )?;
        }
        for (g, m) in &self.scale {
            std::fs::write(
                dir.join(format!("scale_{}.json", g.key())),
                m.to_json().to_string(),
            )?;
        }
        std::fs::write(dir.join("manifest.json"), self.manifest_json().to_string())?;
        Ok(())
    }

    /// The `manifest.json` payload: every component this model set expects
    /// its directory to contain.
    fn manifest_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "cross",
            Json::Arr(
                self.cross
                    .keys()
                    .map(|(a, t)| {
                        Json::Arr(vec![
                            Json::Str(a.key().into()),
                            Json::Str(t.key().into()),
                        ])
                    })
                    .collect(),
            ),
        );
        o.set(
            "scale",
            Json::Arr(
                self.scale
                    .keys()
                    .map(|g| Json::Str(g.key().into()))
                    .collect(),
            ),
        );
        o
    }

    /// Load a previously saved model directory.
    ///
    /// When the directory carries a `manifest.json` (every directory
    /// written by [`Profet::save`] since the registry work does), the
    /// loaded components are checked against it and any gap is surfaced
    /// **now** as a structured [`MissingModels`] error — not at the first
    /// predict for the missing pair. A directory with no cross-instance
    /// models at all is likewise rejected. This check is what the serving
    /// registry's validation gate leans on before publishing an epoch.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use repro::predictor::{MissingModels, Profet};
    ///
    /// match Profet::load("models") {
    ///     Ok(profet) => println!("{} cross models", profet.cross.len()),
    ///     Err(e) => match e.downcast_ref::<MissingModels>() {
    ///         Some(gap) => eprintln!("incomplete dir: {gap}"),
    ///         None => eprintln!("unreadable dir: {e:#}"),
    ///     },
    /// }
    /// ```
    pub fn load(dir: impl AsRef<Path>) -> Result<Profet> {
        let dir = dir.as_ref();
        let fs_json = Json::parse(&std::fs::read_to_string(dir.join("feature_space.json"))?)?;
        let feature_space = FeatureSpace::from_json(&fs_json)?;
        let mut cross = BTreeMap::new();
        let mut scale = BTreeMap::new();
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if name.starts_with("cross_") && name.ends_with(".json") {
                let j = Json::parse(&std::fs::read_to_string(&path)?)?;
                let m = CrossInstanceModel::from_json(&j)
                    .with_context(|| format!("loading {name}"))?;
                cross.insert((m.anchor, m.target), m);
            } else if name.starts_with("scale_") && name.ends_with(".json") {
                let j = Json::parse(&std::fs::read_to_string(&path)?)?;
                let m = BatchPixelModel::from_json(&j)?;
                scale.insert(m.instance, m);
            }
        }
        let manifest_path = dir.join("manifest.json");
        if manifest_path.exists() {
            let manifest = Json::parse(&std::fs::read_to_string(&manifest_path)?)
                .context("parsing manifest.json")?;
            let gap = manifest_gap(&manifest, &cross, &scale)?;
            if !gap.is_empty() {
                return Err(anyhow::Error::new(gap)
                    .context(format!("loading {}", dir.display())));
            }
        }
        anyhow::ensure!(
            !cross.is_empty(),
            "model dir {} contains no cross-instance models — run `repro train` first",
            dir.display()
        );
        Ok(Profet {
            feature_space,
            cross,
            scale,
        })
    }
}

/// Diff a parsed `manifest.json` against the components actually loaded.
/// Pure over its inputs (unit-tested without any trained model on disk).
fn manifest_gap(
    manifest: &Json,
    cross: &BTreeMap<(Instance, Instance), CrossInstanceModel>,
    scale: &BTreeMap<Instance, BatchPixelModel>,
) -> Result<MissingModels> {
    let mut gap = MissingModels::default();
    for entry in manifest.req_arr("cross").context("manifest.json")? {
        let pair = entry
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| anyhow!("manifest.json: malformed cross pair"))?;
        let inst = |j: &Json| -> Result<Instance> {
            j.as_str()
                .and_then(Instance::from_key)
                .ok_or_else(|| anyhow!("manifest.json: unknown instance in cross pair"))
        };
        let (a, t) = (inst(&pair[0])?, inst(&pair[1])?);
        if !cross.contains_key(&(a, t)) {
            gap.cross.push((a, t));
        }
    }
    for entry in manifest.req_arr("scale").context("manifest.json")? {
        let g = entry
            .as_str()
            .and_then(Instance::from_key)
            .ok_or_else(|| anyhow!("manifest.json: unknown instance in scale list"))?;
        if !scale.contains_key(&g) {
            gap.scale.push(g);
        }
    }
    Ok(gap)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest(cross: &[(&str, &str)], scale: &[&str]) -> Json {
        let mut o = Json::obj();
        o.set(
            "cross",
            Json::Arr(
                cross
                    .iter()
                    .map(|(a, t)| {
                        Json::Arr(vec![Json::Str((*a).into()), Json::Str((*t).into())])
                    })
                    .collect(),
            ),
        );
        o.set(
            "scale",
            Json::Arr(scale.iter().map(|g| Json::Str((*g).into())).collect()),
        );
        o
    }

    #[test]
    fn manifest_gap_lists_every_missing_component() {
        // nothing loaded, three components expected
        let m = manifest(&[("g4dn", "p3"), ("g4dn", "p2")], &["p3"]);
        let gap = manifest_gap(&m, &BTreeMap::new(), &BTreeMap::new()).unwrap();
        assert_eq!(
            gap.cross,
            vec![
                (Instance::G4dn, Instance::P3),
                (Instance::G4dn, Instance::P2)
            ]
        );
        assert_eq!(gap.scale, vec![Instance::P3]);
        assert!(!gap.is_empty());
        // the Display form names each missing pair (what the structured
        // wire error and log lines show operators)
        let msg = gap.to_string();
        assert!(msg.contains("g4dn->p3"), "{msg}");
        assert!(msg.contains("g4dn->p2"), "{msg}");
        assert!(msg.contains("p3"), "{msg}");
    }

    #[test]
    fn manifest_gap_empty_when_complete() {
        let m = manifest(&[], &[]);
        let gap = manifest_gap(&m, &BTreeMap::new(), &BTreeMap::new()).unwrap();
        assert!(gap.is_empty());
    }

    #[test]
    fn manifest_gap_rejects_malformed_manifests() {
        // unknown instance key
        let m = manifest(&[("warp9", "p3")], &[]);
        assert!(manifest_gap(&m, &BTreeMap::new(), &BTreeMap::new()).is_err());
        // missing the cross field entirely
        let empty = Json::obj();
        assert!(manifest_gap(&empty, &BTreeMap::new(), &BTreeMap::new()).is_err());
    }
}
