//! The end-to-end PROFET facade (Fig 3): holds the fitted feature space,
//! every cross-instance ensemble, and the per-instance batch/pixel models;
//! persists to / loads from a model directory.
//!
//! # Model directory layout
//!
//! [`Profet::save`] writes one JSON file per component plus a
//! `manifest.json` inventory:
//!
//! ```text
//! models/
//!   manifest.json           # expected cross pairs + scale instances
//!   feature_space.json      # fitted op-name clustering / vectorizer
//!   cross_<a>_<t>.json      # one per (anchor, target) ensemble
//!   scale_<g>.json          # one per-instance batch/pixel model
//! ```
//!
//! [`Profet::load`] checks the directory against the manifest and fails
//! **at load time** with a structured [`MissingModels`] error when a
//! listed component file is absent — a registry candidate with a deleted
//! or half-copied model dir is rejected before it can serve a single
//! request (the old behavior deferred the failure to the first `predict`
//! for the missing pair). Directories written before the manifest existed
//! load as before (no completeness information to check against). A
//! component file that exists but cannot be read or parsed fails with a
//! structured [`CorruptModel`] error naming the offending file.
//!
//! # Crash safety
//!
//! [`Profet::save`] never writes into the serving directory in place.
//! Every file is staged into a unique temp sibling
//! (`<dir>.tmp.<pid>.<seq>`, same filesystem so `rename(2)` is atomic)
//! and fsynced there; then either the whole staged directory is renamed
//! over a not-yet-existing target, or — for a live target — each
//! component file is renamed in individually with `manifest.json`
//! renamed **strictly last** and the directory fsynced around it. Any
//! crash therefore leaves one of exactly two states: the old directory
//! untouched (plus an orphaned temp sibling), or a directory whose old
//! manifest still describes a loadable set while new component files
//! wait unreferenced. [`sweep_orphaned_saves`] removes leftover temp
//! siblings; the serving registry runs it at open and before every
//! reload. The single-writer invariant (only the trainer lane saves)
//! is what makes the sweep safe to run there. Chaos coverage:
//! `rust/tests/chaos.rs` drives the `registry.save.{stage,commit,
//! finalize}` failpoints through every step of this protocol.

use super::batch_pixel::BatchPixelModel;
use super::cross_instance::{CrossInstanceModel, EnsembleConfig, Member};
use crate::data::Corpus;
use crate::features::FeatureSpace;
use crate::gpu::Instance;
use crate::runtime::Runtime;
use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// Training options for the full system.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// Anchor instances to train models *from*.
    pub anchors: Vec<Instance>,
    /// Target instances to train models *to*.
    pub targets: Vec<Instance>,
    /// Operation-name clustering on/off (Fig 13 ablation).
    pub clustering: bool,
    /// Polynomial order for the batch/pixel phase (Fig 12 ablation).
    pub poly_order: usize,
    /// Ensemble member hyper-parameters.
    pub n_trees: usize,
    pub dnn_epochs: usize,
    pub seed: u64,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            anchors: Instance::CORE.to_vec(),
            targets: Instance::CORE.to_vec(),
            clustering: true,
            poly_order: 2,
            n_trees: 100,
            dnn_epochs: 120,
            seed: 0xC0FFEE,
        }
    }
}

/// Structured load-time completeness failure: the model directory's
/// `manifest.json` lists components whose files are missing or unreadable.
/// Carried inside the `anyhow` error chain ([`Profet::load`]) so callers —
/// notably the coordinator's model-registry validation gate — can
/// `downcast_ref::<MissingModels>()` and enumerate exactly which pairs are
/// gone instead of pattern-matching an error string.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MissingModels {
    /// Missing cross-instance ensembles, `(anchor, target)`.
    pub cross: Vec<(Instance, Instance)>,
    /// Missing per-instance batch/pixel models.
    pub scale: Vec<Instance>,
}

impl MissingModels {
    pub fn is_empty(&self) -> bool {
        self.cross.is_empty() && self.scale.is_empty()
    }
}

impl fmt::Display for MissingModels {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "model dir is missing ")?;
        let mut sep = "";
        if !self.cross.is_empty() {
            let pairs: Vec<String> = self
                .cross
                .iter()
                .map(|(a, t)| format!("{a}->{t}"))
                .collect();
            write!(
                f,
                "{} cross-instance model(s): {}",
                self.cross.len(),
                pairs.join(", ")
            )?;
            sep = "; ";
        }
        if !self.scale.is_empty() {
            let insts: Vec<&str> = self.scale.iter().map(|g| g.key()).collect();
            write!(
                f,
                "{sep}{} batch/pixel model(s): {}",
                self.scale.len(),
                insts.join(", ")
            )?;
        }
        Ok(())
    }
}

impl std::error::Error for MissingModels {}

/// Structured load-time corruption failure: a model component file
/// exists but cannot be read or parsed (torn write, truncation, disk
/// fault). Carried inside the `anyhow` chain from [`Profet::load`] so
/// callers can `downcast_ref::<CorruptModel>()` and learn exactly which
/// file to restore instead of pattern-matching an opaque parse error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorruptModel {
    /// The offending file, as resolved under the loaded directory.
    pub file: std::path::PathBuf,
    /// What went wrong reading or parsing it.
    pub detail: String,
}

impl fmt::Display for CorruptModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "corrupt or unreadable model file {}: {} — restore the file or re-run `repro train`",
            self.file.display(),
            self.detail
        )
    }
}

impl std::error::Error for CorruptModel {}

/// Wrap a per-file failure as a [`CorruptModel`] anyhow error.
fn corrupt(path: &Path, detail: String) -> anyhow::Error {
    anyhow::Error::new(CorruptModel {
        file: path.to_path_buf(),
        detail,
    })
}

/// Read + parse one model component file, mapping every failure to a
/// structured [`CorruptModel`] naming the file.
fn read_model_json(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path).map_err(|e| corrupt(path, e.to_string()))?;
    Json::parse(&text).map_err(|e| corrupt(path, format!("{e:#}")))
}

/// The trained system. `Clone` is cheap relative to training (the models
/// are plain data) and is what the coordinator's registry leans on to
/// build an onboarding candidate next to the live epoch
/// ([`Profet::retrain_pairs`]).
#[derive(Clone)]
pub struct Profet {
    pub feature_space: FeatureSpace,
    pub cross: BTreeMap<(Instance, Instance), CrossInstanceModel>,
    pub scale: BTreeMap<Instance, BatchPixelModel>,
}

impl Profet {
    /// Train everything from corpus entries `train_idx`.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use repro::data::Corpus;
    /// use repro::gpu::Instance;
    /// use repro::predictor::{Profet, TrainOptions};
    ///
    /// let rt = repro::runtime::load_default()?;
    /// let corpus = Corpus::generate(&Instance::ALL);
    /// let (train_idx, _test_idx) = corpus.split_random(0.2, 7);
    /// let profet = Profet::train(&rt, &corpus, &train_idx, &TrainOptions::default())?;
    /// assert!(!profet.cross.is_empty());
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn train(
        rt: &Runtime,
        corpus: &Corpus,
        train_idx: &[usize],
        opts: &TrainOptions,
    ) -> Result<Profet> {
        // feature space from the *training* vocabulary only
        let keep: std::collections::BTreeSet<usize> = train_idx.iter().copied().collect();
        let mut vocab_set = std::collections::BTreeSet::new();
        for (i, e) in corpus.entries.iter().enumerate() {
            if !keep.contains(&i) {
                continue;
            }
            for run in e.runs.values() {
                for op in run.profile.keys() {
                    vocab_set.insert(op.as_str());
                }
            }
        }
        let vocab: Vec<&str> = vocab_set.into_iter().collect();
        let feature_space = FeatureSpace::fit(&vocab, opts.clustering, rt.meta.d_feat)?;

        let mut cross = BTreeMap::new();
        for &a in &opts.anchors {
            for &t in &opts.targets {
                if a == t {
                    continue;
                }
                let m = CrossInstanceModel::fit(
                    rt,
                    &feature_space,
                    corpus,
                    train_idx,
                    a,
                    t,
                    EnsembleConfig {
                        n_trees: opts.n_trees,
                        dnn_epochs: opts.dnn_epochs,
                        seed: opts.seed ^ crate::util::seed_of(&[a.key(), t.key()]),
                    },
                )
                .with_context(|| format!("cross model {a}->{t}"))?;
                cross.insert((a, t), m);
            }
        }

        let mut scale = BTreeMap::new();
        for &g in opts.anchors.iter().chain(&opts.targets) {
            if scale.contains_key(&g) {
                continue;
            }
            if let Ok(m) = BatchPixelModel::fit(corpus, train_idx, g, opts.poly_order) {
                scale.insert(g, m);
            }
        }

        Ok(Profet {
            feature_space,
            cross,
            scale,
        })
    }

    /// Phase-1 prediction: latency of the profiled workload on `target`.
    pub fn predict_cross(
        &self,
        rt: &Runtime,
        anchor: Instance,
        target: Instance,
        profile: &BTreeMap<String, f64>,
        anchor_latency_ms: f64,
    ) -> Result<(f64, Member)> {
        let model = self
            .cross
            .get(&(anchor, target))
            .ok_or_else(|| anyhow!("no model for {anchor}->{target}"))?;
        let x = self.feature_space.vectorize(profile);
        model.predict(rt, &x, anchor_latency_ms)
    }

    /// Phase-2 prediction: latency at batch `b` on `instance`, given
    /// min/max-batch latencies (measured or phase-1-predicted) — Fig 11.
    pub fn predict_batch_size(
        &self,
        instance: Instance,
        b: usize,
        t_min: f64,
        t_max: f64,
    ) -> Result<f64> {
        let m = self
            .scale
            .get(&instance)
            .ok_or_else(|| anyhow!("no batch/pixel model for {instance}"))?;
        Ok(m.predict_batch(b, t_min, t_max))
    }

    /// Phase-2 prediction for input pixel size.
    pub fn predict_pixel_size(
        &self,
        instance: Instance,
        p: usize,
        t_min: f64,
        t_max: f64,
    ) -> Result<f64> {
        let m = self
            .scale
            .get(&instance)
            .ok_or_else(|| anyhow!("no batch/pixel model for {instance}"))?;
        Ok(m.predict_pixels(p, t_min, t_max))
    }

    /// Full two-phase scenario (Fig 11 "Predict"): profiles of the min- and
    /// max-batch workloads on the anchor → latency at batch `b` on target.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_scenario(
        &self,
        rt: &Runtime,
        anchor: Instance,
        target: Instance,
        profile_min: &BTreeMap<String, f64>,
        anchor_lat_min: f64,
        profile_max: &BTreeMap<String, f64>,
        anchor_lat_max: f64,
        b: usize,
    ) -> Result<f64> {
        let (t_min, _) = self.predict_cross(rt, anchor, target, profile_min, anchor_lat_min)?;
        let (t_max, _) = self.predict_cross(rt, anchor, target, profile_max, anchor_lat_max)?;
        self.predict_batch_size(target, b, t_min, t_max)
    }

    /// Retrain the given `(anchor, target)` cross-instance ensembles from
    /// `corpus` and return a **new** `Profet` that is this one plus the
    /// refitted pairs — the online-onboarding path behind the
    /// coordinator's `onboard` op.
    ///
    /// The existing [`FeatureSpace`] is reused verbatim (op names the
    /// frozen vocabulary has never seen vectorize to zero, exactly as they
    /// would at predict time), so the refitted pairs stay compatible with
    /// every model already in the registry. Per-pair hyper-parameters and
    /// seed derivation match [`Profet::train`] exactly. Instances that
    /// appear in `pairs` but have no batch/pixel interpolation model yet
    /// get one fitted from `corpus` when it contains the min/max-batch
    /// observations that fit needs; instances that already have one keep
    /// it (the staged onboarding corpus is typically far smaller than the
    /// corpus the existing model was fitted on).
    ///
    /// `self` is untouched: on any error the caller still holds the old,
    /// fully working model set — which is what lets the registry keep the
    /// previous epoch serving when onboarding fails.
    pub fn retrain_pairs(
        &self,
        rt: &Runtime,
        corpus: &Corpus,
        train_idx: &[usize],
        pairs: &[(Instance, Instance)],
        opts: &TrainOptions,
    ) -> Result<Profet> {
        anyhow::ensure!(!pairs.is_empty(), "no (anchor, target) pairs to retrain");
        let mut next = self.clone();
        for &(a, t) in pairs {
            anyhow::ensure!(a != t, "cannot retrain identity pair {a}->{t}");
            let m = CrossInstanceModel::fit(
                rt,
                &next.feature_space,
                corpus,
                train_idx,
                a,
                t,
                EnsembleConfig {
                    n_trees: opts.n_trees,
                    dnn_epochs: opts.dnn_epochs,
                    seed: opts.seed ^ crate::util::seed_of(&[a.key(), t.key()]),
                },
            )
            .with_context(|| format!("retraining cross model {a}->{t}"))?;
            next.cross.insert((a, t), m);
        }
        for &(a, t) in pairs {
            for g in [a, t] {
                if next.scale.contains_key(&g) {
                    continue;
                }
                if let Ok(m) = BatchPixelModel::fit(corpus, train_idx, g, opts.poly_order) {
                    next.scale.insert(g, m);
                }
            }
        }
        Ok(next)
    }

    /// Save to a directory: one JSON per component plus a `manifest.json`
    /// inventory that [`Profet::load`] verifies the directory against.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use repro::predictor::Profet;
    ///
    /// let profet = Profet::load("models")?;
    /// profet.save("models_backup")?;
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        let tmp = temp_sibling(dir)?;
        std::fs::create_dir_all(&tmp)
            .with_context(|| format!("creating staging dir {}", tmp.display()))?;
        let result = self
            .save_via(&tmp, dir)
            .with_context(|| format!("saving {}", dir.display()));
        if result.is_err() {
            // a *failed* save cleans its own staging dir; only a crash
            // (panic/kill) leaves one behind, and the recovery sweep
            // removes those at the next open/reload
            let _ = std::fs::remove_dir_all(&tmp);
        }
        result
    }

    /// Stage every component into `tmp` (written + fsynced), then
    /// publish into `dir` with atomic renames, manifest strictly last —
    /// see the module docs for the crash-safety argument.
    fn save_via(&self, tmp: &Path, dir: &Path) -> Result<()> {
        let mut files: Vec<(String, String)> = Vec::new();
        files.push((
            "feature_space.json".to_string(),
            self.feature_space.to_json().to_string(),
        ));
        for ((a, t), m) in &self.cross {
            files.push((
                format!("cross_{}_{}.json", a.key(), t.key()),
                m.to_json().to_string(),
            ));
        }
        for (g, m) in &self.scale {
            files.push((format!("scale_{}.json", g.key()), m.to_json().to_string()));
        }
        // stage: a crash anywhere in here touches only the temp dir
        for (name, contents) in &files {
            stage_file(&tmp.join(name), contents.as_bytes())?;
        }
        stage_file(
            &tmp.join("manifest.json"),
            self.manifest_json().to_string().as_bytes(),
        )?;
        fsync_dir(tmp)?;
        // fresh target: one whole-directory rename publishes everything
        if !dir.exists() {
            if crate::fp!("registry.save.finalize").is_some() {
                anyhow::bail!("failpoint registry.save.finalize: injected commit failure");
            }
            std::fs::rename(tmp, dir)
                .with_context(|| format!("publishing {}", dir.display()))?;
            if let Some(parent) = nonempty_parent(dir) {
                fsync_dir(parent)?;
            }
            return Ok(());
        }
        // live target: rename components in one by one — any crash
        // prefix plus the OLD manifest still describes a loadable set —
        // then flip the manifest last (the commit point)
        for (name, _) in &files {
            if crate::fp!("registry.save.commit").is_some() {
                anyhow::bail!("failpoint registry.save.commit: injected commit failure");
            }
            std::fs::rename(tmp.join(name), dir.join(name))
                .with_context(|| format!("publishing {name}"))?;
        }
        fsync_dir(dir)?;
        if crate::fp!("registry.save.finalize").is_some() {
            anyhow::bail!("failpoint registry.save.finalize: injected commit failure");
        }
        std::fs::rename(tmp.join("manifest.json"), dir.join("manifest.json"))
            .context("publishing manifest.json")?;
        fsync_dir(dir)?;
        // post-commit hygiene, both best-effort: the emptied staging dir
        // goes away, and component files the new manifest no longer
        // lists are dropped (stale extras never fail a load, so a crash
        // here is harmless)
        let _ = std::fs::remove_dir_all(tmp);
        let keep: std::collections::BTreeSet<&str> =
            files.iter().map(|(n, _)| n.as_str()).collect();
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                let fname = entry.file_name();
                let Some(fname) = fname.to_str() else { continue };
                let component = fname.starts_with("cross_") || fname.starts_with("scale_");
                if component && fname.ends_with(".json") && !keep.contains(fname) {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        Ok(())
    }

    /// The `manifest.json` payload: every component this model set expects
    /// its directory to contain.
    fn manifest_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "cross",
            Json::Arr(
                self.cross
                    .keys()
                    .map(|(a, t)| {
                        Json::Arr(vec![
                            Json::Str(a.key().into()),
                            Json::Str(t.key().into()),
                        ])
                    })
                    .collect(),
            ),
        );
        o.set(
            "scale",
            Json::Arr(
                self.scale
                    .keys()
                    .map(|g| Json::Str(g.key().into()))
                    .collect(),
            ),
        );
        o
    }

    /// Load a previously saved model directory.
    ///
    /// When the directory carries a `manifest.json` (every directory
    /// written by [`Profet::save`] since the registry work does), the
    /// loaded components are checked against it and any gap is surfaced
    /// **now** as a structured [`MissingModels`] error — not at the first
    /// predict for the missing pair. A directory with no cross-instance
    /// models at all is likewise rejected. This check is what the serving
    /// registry's validation gate leans on before publishing an epoch.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use repro::predictor::{MissingModels, Profet};
    ///
    /// match Profet::load("models") {
    ///     Ok(profet) => println!("{} cross models", profet.cross.len()),
    ///     Err(e) => match e.downcast_ref::<MissingModels>() {
    ///         Some(gap) => eprintln!("incomplete dir: {gap}"),
    ///         None => eprintln!("unreadable dir: {e:#}"),
    ///     },
    /// }
    /// ```
    pub fn load(dir: impl AsRef<Path>) -> Result<Profet> {
        let dir = dir.as_ref();
        let fs_path = dir.join("feature_space.json");
        let feature_space = FeatureSpace::from_json(&read_model_json(&fs_path)?)
            .map_err(|e| corrupt(&fs_path, format!("{e:#}")))?;
        let mut cross = BTreeMap::new();
        let mut scale = BTreeMap::new();
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if name.starts_with("cross_") && name.ends_with(".json") {
                let j = read_model_json(&path)?;
                let m = CrossInstanceModel::from_json(&j)
                    .map_err(|e| corrupt(&path, format!("{e:#}")))?;
                cross.insert((m.anchor, m.target), m);
            } else if name.starts_with("scale_") && name.ends_with(".json") {
                let j = read_model_json(&path)?;
                let m = BatchPixelModel::from_json(&j)
                    .map_err(|e| corrupt(&path, format!("{e:#}")))?;
                scale.insert(m.instance, m);
            }
        }
        let manifest_path = dir.join("manifest.json");
        if manifest_path.exists() {
            let manifest = read_model_json(&manifest_path)?;
            let gap = manifest_gap(&manifest, &cross, &scale)?;
            if !gap.is_empty() {
                return Err(anyhow::Error::new(gap)
                    .context(format!("loading {}", dir.display())));
            }
        }
        anyhow::ensure!(
            !cross.is_empty(),
            "model dir {} contains no cross-instance models — run `repro train` first",
            dir.display()
        );
        Ok(Profet {
            feature_space,
            cross,
            scale,
        })
    }
}

/// Marker infix in staged-save directory names; the recovery sweep
/// matches on it (`<dir>.tmp.<pid>.<seq>`).
const TEMP_INFIX: &str = ".tmp.";

/// Unique temp sibling of `dir`, in the same parent directory (and
/// therefore on the same filesystem, which keeps `rename(2)` atomic).
fn temp_sibling(dir: &Path) -> Result<std::path::PathBuf> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    // ordering: uniqueness counter only — any interleaving of the
    // increments yields distinct staging names.
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let name = dir
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| anyhow!("model dir path {} has no directory name", dir.display()))?;
    Ok(dir.with_file_name(format!(
        "{name}{TEMP_INFIX}{}.{seq}",
        std::process::id()
    )))
}

/// `dir.parent()`, with the empty path (relative single-component dirs
/// like `models`) normalized to `.` so it can be opened and listed.
fn nonempty_parent(dir: &Path) -> Option<&Path> {
    match dir.parent() {
        Some(p) if !p.as_os_str().is_empty() => Some(p),
        Some(_) => Some(Path::new(".")),
        None => None,
    }
}

/// Write one staged file: full contents + fsync, honoring the
/// `registry.save.stage` failpoint (`partial-write(n)` leaves a torn
/// file in the staging dir, simulating a crash mid-write).
fn stage_file(path: &Path, bytes: &[u8]) -> Result<()> {
    use crate::util::failpoint::Hit;
    use std::io::Write;
    let truncate_at = match crate::fp!("registry.save.stage") {
        Some(Hit::ReturnErr) => {
            anyhow::bail!("failpoint registry.save.stage: injected write failure")
        }
        Some(Hit::PartialWrite(n)) => Some(n.min(bytes.len())),
        None => None,
    };
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    if let Some(n) = truncate_at {
        f.write_all(&bytes[..n])?;
        let _ = f.sync_all();
        anyhow::bail!("failpoint registry.save.stage: torn write after {n} bytes");
    }
    f.write_all(bytes)
        .with_context(|| format!("writing {}", path.display()))?;
    f.sync_all()
        .with_context(|| format!("fsync {}", path.display()))?;
    Ok(())
}

/// fsync a directory so freshly created/renamed entries are durable.
fn fsync_dir(dir: &Path) -> Result<()> {
    let d = std::fs::File::open(dir)
        .with_context(|| format!("opening {} for fsync", dir.display()))?;
    d.sync_all()
        .with_context(|| format!("fsync {}", dir.display()))?;
    Ok(())
}

/// Remove orphaned staging directories (`<dir>.tmp.<pid>.<seq>`) left
/// next to `dir` by a save that crashed before committing. Returns how
/// many were removed; unreadable parents count zero (nothing to sweep).
/// Only call while no save can be in flight — in the serving stack that
/// is the trainer lane's single-writer invariant (the registry sweeps
/// at open and before each reload).
pub fn sweep_orphaned_saves(dir: impl AsRef<Path>) -> usize {
    let dir = dir.as_ref();
    let (Some(parent), Some(name)) = (
        nonempty_parent(dir),
        dir.file_name().and_then(|n| n.to_str()),
    ) else {
        return 0;
    };
    let prefix = format!("{name}{TEMP_INFIX}");
    let Ok(entries) = std::fs::read_dir(parent) else {
        return 0;
    };
    let mut removed = 0;
    for entry in entries.flatten() {
        let fname = entry.file_name();
        let Some(fname) = fname.to_str() else { continue };
        if fname.starts_with(&prefix)
            && entry.path().is_dir()
            && std::fs::remove_dir_all(entry.path()).is_ok()
        {
            removed += 1;
        }
    }
    removed
}

/// Diff a parsed `manifest.json` against the components actually loaded.
/// Pure over its inputs (unit-tested without any trained model on disk).
fn manifest_gap(
    manifest: &Json,
    cross: &BTreeMap<(Instance, Instance), CrossInstanceModel>,
    scale: &BTreeMap<Instance, BatchPixelModel>,
) -> Result<MissingModels> {
    let mut gap = MissingModels::default();
    for entry in manifest.req_arr("cross").context("manifest.json")? {
        let pair = entry
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| anyhow!("manifest.json: malformed cross pair"))?;
        let inst = |j: &Json| -> Result<Instance> {
            j.as_str()
                .and_then(Instance::from_key)
                .ok_or_else(|| anyhow!("manifest.json: unknown instance in cross pair"))
        };
        let (a, t) = (inst(&pair[0])?, inst(&pair[1])?);
        if !cross.contains_key(&(a, t)) {
            gap.cross.push((a, t));
        }
    }
    for entry in manifest.req_arr("scale").context("manifest.json")? {
        let g = entry
            .as_str()
            .and_then(Instance::from_key)
            .ok_or_else(|| anyhow!("manifest.json: unknown instance in scale list"))?;
        if !scale.contains_key(&g) {
            gap.scale.push(g);
        }
    }
    Ok(gap)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest(cross: &[(&str, &str)], scale: &[&str]) -> Json {
        let mut o = Json::obj();
        o.set(
            "cross",
            Json::Arr(
                cross
                    .iter()
                    .map(|(a, t)| {
                        Json::Arr(vec![Json::Str((*a).into()), Json::Str((*t).into())])
                    })
                    .collect(),
            ),
        );
        o.set(
            "scale",
            Json::Arr(scale.iter().map(|g| Json::Str((*g).into())).collect()),
        );
        o
    }

    #[test]
    fn manifest_gap_lists_every_missing_component() {
        // nothing loaded, three components expected
        let m = manifest(&[("g4dn", "p3"), ("g4dn", "p2")], &["p3"]);
        let gap = manifest_gap(&m, &BTreeMap::new(), &BTreeMap::new()).unwrap();
        assert_eq!(
            gap.cross,
            vec![
                (Instance::G4dn, Instance::P3),
                (Instance::G4dn, Instance::P2)
            ]
        );
        assert_eq!(gap.scale, vec![Instance::P3]);
        assert!(!gap.is_empty());
        // the Display form names each missing pair (what the structured
        // wire error and log lines show operators)
        let msg = gap.to_string();
        assert!(msg.contains("g4dn->p3"), "{msg}");
        assert!(msg.contains("g4dn->p2"), "{msg}");
        assert!(msg.contains("p3"), "{msg}");
    }

    #[test]
    fn manifest_gap_empty_when_complete() {
        let m = manifest(&[], &[]);
        let gap = manifest_gap(&m, &BTreeMap::new(), &BTreeMap::new()).unwrap();
        assert!(gap.is_empty());
    }

    #[test]
    fn manifest_gap_rejects_malformed_manifests() {
        // unknown instance key
        let m = manifest(&[("warp9", "p3")], &[]);
        assert!(manifest_gap(&m, &BTreeMap::new(), &BTreeMap::new()).is_err());
        // missing the cross field entirely
        let empty = Json::obj();
        assert!(manifest_gap(&empty, &BTreeMap::new(), &BTreeMap::new()).is_err());
    }

    // ---- crash-safe save + corruption reporting ----

    use crate::util::failpoint;

    /// Serializes the tests below that arm the process-global
    /// `registry.save.*` failpoints (lib tests run in parallel).
    static FP_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn fp_lock() -> std::sync::MutexGuard<'static, ()> {
        FP_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn temp_model_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "repro_profet_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// A component-less (but saveable) system: enough to exercise the
    /// staging/rename protocol without a trained model.
    fn tiny_profet() -> Profet {
        Profet {
            feature_space: FeatureSpace::fit(&[], false, 4).unwrap(),
            cross: BTreeMap::new(),
            scale: BTreeMap::new(),
        }
    }

    /// No `<dir>.tmp.*` staging sibling left next to `dir`.
    fn no_temp_sibling(dir: &Path) -> bool {
        let parent = nonempty_parent(dir).unwrap();
        let prefix = format!(
            "{}{TEMP_INFIX}",
            dir.file_name().unwrap().to_str().unwrap()
        );
        std::fs::read_dir(parent).unwrap().flatten().all(|e| {
            !e.file_name().to_str().unwrap_or("").starts_with(&prefix)
        })
    }

    #[test]
    fn save_publishes_atomically_and_cleans_its_staging_dir() {
        let _g = fp_lock();
        let root = temp_model_dir("atomic_save");
        let dir = root.join("models");
        let p = tiny_profet();
        // fresh target: whole-directory rename
        p.save(&dir).unwrap();
        assert!(dir.join("feature_space.json").is_file());
        assert!(dir.join("manifest.json").is_file());
        assert!(no_temp_sibling(&dir));
        // live target: per-file renames, manifest last
        p.save(&dir).unwrap();
        assert!(no_temp_sibling(&dir));
        let m = Json::parse(&std::fs::read_to_string(dir.join("manifest.json")).unwrap());
        assert!(m.is_ok(), "manifest must stay parseable after a re-save");
    }

    #[test]
    fn injected_crash_at_every_save_step_leaves_the_old_state_loadable() {
        let _g = fp_lock();
        let root = temp_model_dir("save_crash_matrix");
        let dir = root.join("models");
        tiny_profet().save(&dir).unwrap();
        let actions = [
            ("registry.save.stage", failpoint::Action::ReturnErr),
            ("registry.save.stage", failpoint::Action::PartialWrite(4)),
            ("registry.save.commit", failpoint::Action::ReturnErr),
            ("registry.save.finalize", failpoint::Action::ReturnErr),
        ];
        for (point, action) in actions {
            failpoint::configure(point, action);
            let err = tiny_profet().save(&dir);
            failpoint::clear(point);
            assert!(err.is_err(), "{point} must fail the save");
            assert!(no_temp_sibling(&dir), "{point} left a staging dir");
            // the serving state survives: manifest + feature space are
            // intact and mutually consistent (old or fully-new set)
            let m = Json::parse(&std::fs::read_to_string(dir.join("manifest.json")).unwrap());
            assert!(m.is_ok(), "{point} corrupted manifest.json");
            let fs_json =
                Json::parse(&std::fs::read_to_string(dir.join("feature_space.json")).unwrap())
                    .unwrap();
            assert!(FeatureSpace::from_json(&fs_json).is_ok(), "{point}");
        }
        // a crash before the fresh-target publish leaves no target at all
        let fresh = root.join("models_fresh");
        failpoint::configure("registry.save.finalize", failpoint::Action::ReturnErr);
        assert!(tiny_profet().save(&fresh).is_err());
        failpoint::clear("registry.save.finalize");
        assert!(!fresh.exists(), "aborted fresh save must not half-create the dir");
        assert!(no_temp_sibling(&fresh));
    }

    #[test]
    fn sweep_removes_only_matching_orphan_dirs() {
        let root = temp_model_dir("sweep");
        let dir = root.join("m");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::create_dir_all(root.join("m.tmp.1.2")).unwrap();
        std::fs::create_dir_all(root.join("m.tmp.99.0")).unwrap();
        std::fs::create_dir_all(root.join("m2")).unwrap(); // different dir
        std::fs::create_dir_all(root.join("mother.tmp.1.0")).unwrap(); // different dir's orphan
        std::fs::write(root.join("m.tmp.file"), b"not a dir").unwrap();
        assert_eq!(sweep_orphaned_saves(&dir), 2);
        assert!(dir.is_dir());
        assert!(root.join("m2").is_dir());
        assert!(root.join("mother.tmp.1.0").is_dir());
        assert!(root.join("m.tmp.file").is_file());
        assert!(!root.join("m.tmp.1.2").exists());
        assert!(!root.join("m.tmp.99.0").exists());
        // nothing left to sweep; missing parents sweep zero
        assert_eq!(sweep_orphaned_saves(&dir), 0);
        assert_eq!(sweep_orphaned_saves(root.join("gone").join("m")), 0);
    }

    #[test]
    fn load_names_the_corrupt_file_in_a_structured_error() {
        let _g = fp_lock();
        let root = temp_model_dir("corrupt_load");
        let dir = root.join("models");
        tiny_profet().save(&dir).unwrap();
        // a truncated cross-instance (forest ensemble) file: the exact
        // torn-write shape the atomic save protocol prevents, planted
        // here to prove load degrades to a structured error
        std::fs::write(dir.join("cross_g4dn_p3.json"), "{\"forest\": [").unwrap();
        let err = Profet::load(&dir).expect_err("truncated cross file must fail the load");
        let corrupt = err
            .downcast_ref::<CorruptModel>()
            .unwrap_or_else(|| panic!("expected CorruptModel, got: {err:#}"));
        assert!(
            corrupt.file.ends_with("cross_g4dn_p3.json"),
            "error must name the offending file: {corrupt}"
        );
        assert!(corrupt.to_string().contains("cross_g4dn_p3.json"));

        // same for a torn feature space
        std::fs::remove_file(dir.join("cross_g4dn_p3.json")).unwrap();
        std::fs::write(dir.join("feature_space.json"), "{\"vocab\"").unwrap();
        let err = Profet::load(&dir).expect_err("truncated feature space must fail the load");
        let corrupt = err
            .downcast_ref::<CorruptModel>()
            .unwrap_or_else(|| panic!("expected CorruptModel, got: {err:#}"));
        assert!(corrupt.file.ends_with("feature_space.json"), "{corrupt}");
    }
}
