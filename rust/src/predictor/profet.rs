//! The end-to-end PROFET facade (Fig 3): holds the fitted feature space,
//! every cross-instance ensemble, and the per-instance batch/pixel models;
//! persists to / loads from a model directory.

use super::batch_pixel::BatchPixelModel;
use super::cross_instance::{CrossInstanceModel, EnsembleConfig, Member};
use crate::data::Corpus;
use crate::features::FeatureSpace;
use crate::gpu::Instance;
use crate::runtime::Runtime;
use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Training options for the full system.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// Anchor instances to train models *from*.
    pub anchors: Vec<Instance>,
    /// Target instances to train models *to*.
    pub targets: Vec<Instance>,
    /// Operation-name clustering on/off (Fig 13 ablation).
    pub clustering: bool,
    /// Polynomial order for the batch/pixel phase (Fig 12 ablation).
    pub poly_order: usize,
    /// Ensemble member hyper-parameters.
    pub n_trees: usize,
    pub dnn_epochs: usize,
    pub seed: u64,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            anchors: Instance::CORE.to_vec(),
            targets: Instance::CORE.to_vec(),
            clustering: true,
            poly_order: 2,
            n_trees: 100,
            dnn_epochs: 120,
            seed: 0xC0FFEE,
        }
    }
}

/// The trained system.
pub struct Profet {
    pub feature_space: FeatureSpace,
    pub cross: BTreeMap<(Instance, Instance), CrossInstanceModel>,
    pub scale: BTreeMap<Instance, BatchPixelModel>,
}

impl Profet {
    /// Train everything from corpus entries `train_idx`.
    pub fn train(
        rt: &Runtime,
        corpus: &Corpus,
        train_idx: &[usize],
        opts: &TrainOptions,
    ) -> Result<Profet> {
        // feature space from the *training* vocabulary only
        let keep: std::collections::BTreeSet<usize> = train_idx.iter().copied().collect();
        let mut vocab_set = std::collections::BTreeSet::new();
        for (i, e) in corpus.entries.iter().enumerate() {
            if !keep.contains(&i) {
                continue;
            }
            for run in e.runs.values() {
                for op in run.profile.keys() {
                    vocab_set.insert(op.as_str());
                }
            }
        }
        let vocab: Vec<&str> = vocab_set.into_iter().collect();
        let feature_space = FeatureSpace::fit(&vocab, opts.clustering, rt.meta.d_feat)?;

        let mut cross = BTreeMap::new();
        for &a in &opts.anchors {
            for &t in &opts.targets {
                if a == t {
                    continue;
                }
                let m = CrossInstanceModel::fit(
                    rt,
                    &feature_space,
                    corpus,
                    train_idx,
                    a,
                    t,
                    EnsembleConfig {
                        n_trees: opts.n_trees,
                        dnn_epochs: opts.dnn_epochs,
                        seed: opts.seed ^ crate::util::seed_of(&[a.key(), t.key()]),
                    },
                )
                .with_context(|| format!("cross model {a}->{t}"))?;
                cross.insert((a, t), m);
            }
        }

        let mut scale = BTreeMap::new();
        for &g in opts.anchors.iter().chain(&opts.targets) {
            if scale.contains_key(&g) {
                continue;
            }
            if let Ok(m) = BatchPixelModel::fit(corpus, train_idx, g, opts.poly_order) {
                scale.insert(g, m);
            }
        }

        Ok(Profet {
            feature_space,
            cross,
            scale,
        })
    }

    /// Phase-1 prediction: latency of the profiled workload on `target`.
    pub fn predict_cross(
        &self,
        rt: &Runtime,
        anchor: Instance,
        target: Instance,
        profile: &BTreeMap<String, f64>,
        anchor_latency_ms: f64,
    ) -> Result<(f64, Member)> {
        let model = self
            .cross
            .get(&(anchor, target))
            .ok_or_else(|| anyhow!("no model for {anchor}->{target}"))?;
        let x = self.feature_space.vectorize(profile);
        model.predict(rt, &x, anchor_latency_ms)
    }

    /// Phase-2 prediction: latency at batch `b` on `instance`, given
    /// min/max-batch latencies (measured or phase-1-predicted) — Fig 11.
    pub fn predict_batch_size(
        &self,
        instance: Instance,
        b: usize,
        t_min: f64,
        t_max: f64,
    ) -> Result<f64> {
        let m = self
            .scale
            .get(&instance)
            .ok_or_else(|| anyhow!("no batch/pixel model for {instance}"))?;
        Ok(m.predict_batch(b, t_min, t_max))
    }

    /// Phase-2 prediction for input pixel size.
    pub fn predict_pixel_size(
        &self,
        instance: Instance,
        p: usize,
        t_min: f64,
        t_max: f64,
    ) -> Result<f64> {
        let m = self
            .scale
            .get(&instance)
            .ok_or_else(|| anyhow!("no batch/pixel model for {instance}"))?;
        Ok(m.predict_pixels(p, t_min, t_max))
    }

    /// Full two-phase scenario (Fig 11 "Predict"): profiles of the min- and
    /// max-batch workloads on the anchor → latency at batch `b` on target.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_scenario(
        &self,
        rt: &Runtime,
        anchor: Instance,
        target: Instance,
        profile_min: &BTreeMap<String, f64>,
        anchor_lat_min: f64,
        profile_max: &BTreeMap<String, f64>,
        anchor_lat_max: f64,
        b: usize,
    ) -> Result<f64> {
        let (t_min, _) = self.predict_cross(rt, anchor, target, profile_min, anchor_lat_min)?;
        let (t_max, _) = self.predict_cross(rt, anchor, target, profile_max, anchor_lat_max)?;
        self.predict_batch_size(target, b, t_min, t_max)
    }

    /// Save to a directory (one JSON per component).
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            dir.join("feature_space.json"),
            self.feature_space.to_json().to_string(),
        )?;
        for ((a, t), m) in &self.cross {
            std::fs::write(
                dir.join(format!("cross_{}_{}.json", a.key(), t.key())),
                m.to_json().to_string(),
            )?;
        }
        for (g, m) in &self.scale {
            std::fs::write(
                dir.join(format!("scale_{}.json", g.key())),
                m.to_json().to_string(),
            )?;
        }
        Ok(())
    }

    /// Load a previously saved model directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Profet> {
        let dir = dir.as_ref();
        let fs_json = Json::parse(&std::fs::read_to_string(dir.join("feature_space.json"))?)?;
        let feature_space = FeatureSpace::from_json(&fs_json)?;
        let mut cross = BTreeMap::new();
        let mut scale = BTreeMap::new();
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if name.starts_with("cross_") && name.ends_with(".json") {
                let j = Json::parse(&std::fs::read_to_string(&path)?)?;
                let m = CrossInstanceModel::from_json(&j)
                    .with_context(|| format!("loading {name}"))?;
                cross.insert((m.anchor, m.target), m);
            } else if name.starts_with("scale_") && name.ends_with(".json") {
                let j = Json::parse(&std::fs::read_to_string(&path)?)?;
                let m = BatchPixelModel::from_json(&j)?;
                scale.insert(m.instance, m);
            }
        }
        Ok(Profet {
            feature_space,
            cross,
            scale,
        })
    }
}
