//! Batch / input-pixel-size latency model (paper Sec III-C2, Fig 7):
//! per-instance min-max-scaled order-2 polynomial + Eq. 1 denormalization.

use crate::data::Corpus;
use crate::gpu::Instance;
use crate::ml::{MinMaxScaler, PolyRegression};
use crate::sim::workload::{BATCHES, PIXELS};
use crate::util::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Normalize a batch size to [0,1] over the paper's [16, 256] range.
pub fn norm_batch(b: usize) -> f64 {
    (b as f64 - BATCHES[0] as f64) / (BATCHES[4] as f64 - BATCHES[0] as f64)
}

/// Normalize a pixel size to [0,1] over the paper's [32, 256] range.
pub fn norm_pixels(p: usize) -> f64 {
    (p as f64 - PIXELS[0] as f64) / (PIXELS[4] as f64 - PIXELS[0] as f64)
}

/// Per-instance polynomial scalers for batch and pixel interpolation.
#[derive(Clone)]
pub struct BatchPixelModel {
    pub instance: Instance,
    pub batch_poly: PolyRegression,
    pub pixel_poly: PolyRegression,
    pub order: usize,
}

impl BatchPixelModel {
    /// Fit from the corpus restricted to `idx` entries on `instance`.
    ///
    /// Training input (Fig 7): for each (model, pixels) group with
    /// observations at min AND max batch, normalize every observed latency
    /// by that group's min/max-batch latencies and regress T_N over the
    /// normalized batch size (pixels analogous).
    pub fn fit(corpus: &Corpus, idx: &[usize], instance: Instance, order: usize) -> Result<BatchPixelModel> {
        let mut bx = Vec::new();
        let mut by = Vec::new();
        let mut px = Vec::new();
        let mut py = Vec::new();

        // group latency lookup: (model, pixels) -> batch -> latency
        let mut by_batch: BTreeMap<(String, usize), BTreeMap<usize, f64>> = BTreeMap::new();
        let mut by_pixel: BTreeMap<(String, usize), BTreeMap<usize, f64>> = BTreeMap::new();
        for &i in idx {
            let e = &corpus.entries[i];
            let Some(run) = e.runs.get(&instance) else {
                continue;
            };
            by_batch
                .entry((e.workload.model.name().into(), e.workload.pixels))
                .or_default()
                .insert(e.workload.batch, run.latency_ms);
            by_pixel
                .entry((e.workload.model.name().into(), e.workload.batch))
                .or_default()
                .insert(e.workload.pixels, run.latency_ms);
        }

        let bmin = BATCHES[0];
        let bmax = BATCHES[4];
        for latencies in by_batch.values() {
            let (Some(&tmin), Some(&tmax)) = (latencies.get(&bmin), latencies.get(&bmax)) else {
                continue;
            };
            let sc = MinMaxScaler::from_bounds(tmin, tmax);
            for (&b, &t) in latencies {
                bx.push(norm_batch(b));
                by.push(sc.transform(t));
            }
        }
        let pmin = PIXELS[0];
        let pmax = PIXELS[4];
        for latencies in by_pixel.values() {
            let (Some(&tmin), Some(&tmax)) = (latencies.get(&pmin), latencies.get(&pmax)) else {
                continue;
            };
            let sc = MinMaxScaler::from_bounds(tmin, tmax);
            for (&p, &t) in latencies {
                px.push(norm_pixels(p));
                py.push(sc.transform(t));
            }
        }

        anyhow::ensure!(bx.len() > order && px.len() > order, "too few groups on {instance}");
        Ok(BatchPixelModel {
            instance,
            batch_poly: PolyRegression::fit(&bx, &by, order)?,
            pixel_poly: PolyRegression::fit(&px, &py, order)?,
            order,
        })
    }

    /// Predict latency at batch `b` given the min/max-batch latencies
    /// (true-measured or cross-instance-predicted) — Eq. 1.
    pub fn predict_batch(&self, b: usize, t_min: f64, t_max: f64) -> f64 {
        let tn = self.batch_poly.predict(norm_batch(b));
        MinMaxScaler::from_bounds(t_min, t_max).inverse(tn)
    }

    /// Predict latency at pixel size `p` given min/max-pixel latencies.
    pub fn predict_pixels(&self, p: usize, t_min: f64, t_max: f64) -> f64 {
        let tn = self.pixel_poly.predict(norm_pixels(p));
        MinMaxScaler::from_bounds(t_min, t_max).inverse(tn)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("instance", Json::Str(self.instance.key().into()));
        o.set("batch_poly", self.batch_poly.to_json());
        o.set("pixel_poly", self.pixel_poly.to_json());
        o.set("order", Json::Num(self.order as f64));
        o
    }

    pub fn from_json(j: &Json) -> Result<BatchPixelModel> {
        Ok(BatchPixelModel {
            instance: Instance::from_key(j.req_str("instance")?)
                .ok_or_else(|| anyhow!("bad instance"))?,
            batch_poly: PolyRegression::from_json(
                j.get("batch_poly").ok_or_else(|| anyhow!("batch_poly"))?,
            )?,
            pixel_poly: PolyRegression::from_json(
                j.get("pixel_poly").ok_or_else(|| anyhow!("pixel_poly"))?,
            )?,
            order: j.req_usize("order")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        assert_eq!(norm_batch(16), 0.0);
        assert_eq!(norm_batch(256), 1.0);
        assert!((norm_batch(136) - 0.5).abs() < 1e-12);
        assert_eq!(norm_pixels(32), 0.0);
        assert_eq!(norm_pixels(256), 1.0);
    }

    #[test]
    fn endpoints_recover_bounds_exactly_in_theory() {
        // a model fitted on perfectly normalized data maps 0->t_min, 1->t_max
        let bx = [0.0, 0.25, 0.5, 0.75, 1.0];
        let by = [0.0, 0.2, 0.45, 0.7, 1.0];
        let poly = PolyRegression::fit(&bx, &by, 2).unwrap();
        let m = BatchPixelModel {
            instance: Instance::P3,
            batch_poly: poly.clone(),
            pixel_poly: poly,
            order: 2,
        };
        let p16 = m.predict_batch(16, 100.0, 900.0);
        let p256 = m.predict_batch(256, 100.0, 900.0);
        assert!((p16 - 100.0).abs() < 30.0, "{p16}");
        assert!((p256 - 900.0).abs() < 30.0, "{p256}");
        // interior strictly between
        let p64 = m.predict_batch(64, 100.0, 900.0);
        assert!(p64 > 100.0 && p64 < 900.0);
    }
}
