//! Cross-instance latency model f_{g_a → g_t} (paper Sec III-C1, Fig 6):
//! a median ensemble of {linear (on anchor batch latency), random forest,
//! DNN (HLO-driven)} trained on D_{g_a → g_t}.

use crate::data::Corpus;
use crate::dnn::{DnnRegressor, TrainConfig};
use crate::features::FeatureSpace;
use crate::gpu::Instance;
use crate::ml::{FeatureMatrix, LinearRegression, RandomForest};
use crate::runtime::Runtime;
use crate::util::Json;
use anyhow::{anyhow, Result};

/// Which ensemble member supplied the median (Fig 10's pick-rate stats).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Member {
    Linear,
    Forest,
    Dnn,
}

impl Member {
    pub fn name(self) -> &'static str {
        match self {
            Member::Linear => "Linear",
            Member::Forest => "RandomForest",
            Member::Dnn => "DNN",
        }
    }

    /// Inverse of [`Member::name`] (the wire `hint` op carries a member
    /// by name).
    pub fn from_name(name: &str) -> Option<Member> {
        match name {
            "Linear" => Some(Member::Linear),
            "RandomForest" => Some(Member::Forest),
            "DNN" => Some(Member::Dnn),
            _ => None,
        }
    }
}

/// The per-(anchor, target) ensemble.
#[derive(Clone)]
pub struct CrossInstanceModel {
    pub anchor: Instance,
    pub target: Instance,
    pub linear: LinearRegression,
    pub forest: RandomForest,
    pub dnn: DnnRegressor,
}

/// Hyper-parameters for ensemble training.
#[derive(Debug, Clone, Copy)]
pub struct EnsembleConfig {
    pub n_trees: usize,
    pub dnn_epochs: usize,
    pub seed: u64,
}

impl Default for EnsembleConfig {
    fn default() -> Self {
        Self {
            n_trees: 100,
            dnn_epochs: 60,
            seed: 0x9e37,
        }
    }
}

impl CrossInstanceModel {
    /// Assemble the columnar training matrix D_{g_a → g_t} from corpus
    /// entries (indices) that have observations on both instances.
    pub fn training_rows(
        fs: &FeatureSpace,
        corpus: &Corpus,
        idx: &[usize],
        anchor: Instance,
        target: Instance,
    ) -> Result<(FeatureMatrix, Vec<f64>, Vec<f64>)> {
        let mut rows = Vec::new();
        let mut anchor_lat = Vec::new();
        let mut y = Vec::new();
        for &i in idx {
            let e = &corpus.entries[i];
            let (Some(a), Some(t)) = (e.runs.get(&anchor), e.runs.get(&target)) else {
                continue;
            };
            rows.push(fs.vectorize(&a.profile));
            anchor_lat.push(a.latency_ms);
            y.push(t.latency_ms);
        }
        Ok((FeatureMatrix::from_rows(&rows)?, anchor_lat, y))
    }

    /// Fit all three members.
    pub fn fit(
        rt: &Runtime,
        fs: &FeatureSpace,
        corpus: &Corpus,
        train_idx: &[usize],
        anchor: Instance,
        target: Instance,
        cfg: EnsembleConfig,
    ) -> Result<CrossInstanceModel> {
        let (x, anchor_lat, y) = Self::training_rows(fs, corpus, train_idx, anchor, target)?;
        anyhow::ensure!(
            x.n_rows() >= 20,
            "too few paired observations ({}) for {anchor}->{target}",
            x.n_rows()
        );
        let linear = LinearRegression::fit(&FeatureMatrix::from_col(&anchor_lat), &y)?;
        let forest = RandomForest::fit(&x, &y, cfg.n_trees, cfg.seed)?;
        let dnn = DnnRegressor::fit(
            rt,
            &x,
            &y,
            TrainConfig {
                epochs: cfg.dnn_epochs,
                seed: cfg.seed,
            },
        )?;
        Ok(CrossInstanceModel {
            anchor,
            target,
            linear,
            forest,
            dnn,
        })
    }

    /// Median-ensemble prediction for one workload.
    pub fn predict(
        &self,
        rt: &Runtime,
        features: &[f64],
        anchor_latency_ms: f64,
    ) -> Result<(f64, Member)> {
        let l = self.linear.predict_one(&[anchor_latency_ms]);
        let f = self.forest.predict_one(features);
        let d = self.dnn.predict_one(rt, features)?;
        Ok(median3(l, f, d))
    }

    /// Batched median-ensemble prediction (one DNN artifact call per
    /// `b_pred` rows, one cache-hot forest pass — the serving hot path).
    pub fn predict_batch(
        &self,
        rt: &Runtime,
        features: &FeatureMatrix,
        anchor_latency_ms: &[f64],
    ) -> Result<Vec<(f64, Member)>> {
        anyhow::ensure!(features.n_rows() == anchor_latency_ms.len(), "len mismatch");
        let d = self.dnn.predict(rt, features)?;
        let f = self.forest.predict_batch(features);
        Ok(anchor_latency_ms
            .iter()
            .zip(f)
            .zip(d)
            .map(|((&al, fv), dv)| {
                let l = self.linear.predict_one(&[al]);
                median3(l, fv, dv)
            })
            .collect())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("anchor", Json::Str(self.anchor.key().into()));
        o.set("target", Json::Str(self.target.key().into()));
        o.set("linear", self.linear.to_json());
        o.set("forest", self.forest.to_json());
        o.set("dnn", self.dnn.to_json());
        o
    }

    pub fn from_json(j: &Json) -> Result<CrossInstanceModel> {
        let inst = |k: &str| -> Result<Instance> {
            Instance::from_key(j.req_str(k)?).ok_or_else(|| anyhow!("bad instance"))
        };
        Ok(CrossInstanceModel {
            anchor: inst("anchor")?,
            target: inst("target")?,
            linear: LinearRegression::from_json(j.get("linear").ok_or_else(|| anyhow!("linear"))?)?,
            forest: RandomForest::from_json(j.get("forest").ok_or_else(|| anyhow!("forest"))?)?,
            dnn: DnnRegressor::from_json(j.get("dnn").ok_or_else(|| anyhow!("dnn"))?)?,
        })
    }
}

/// Median of three values, tagged with its source.
fn median3(l: f64, f: f64, d: f64) -> (f64, Member) {
    let mut v = [(l, Member::Linear), (f, Member::Forest), (d, Member::Dnn)];
    v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    v[1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median3_picks_middle() {
        assert_eq!(median3(1.0, 2.0, 3.0), (2.0, Member::Forest));
        assert_eq!(median3(5.0, 2.0, 3.0), (3.0, Member::Dnn));
        assert_eq!(median3(5.0, 2.0, 4.0), (4.0, Member::Dnn));
        assert_eq!(median3(2.0, 9.0, 1.0), (2.0, Member::Linear));
    }

    #[test]
    fn median3_robust_to_one_outlier() {
        // the ensemble's whole point: one wild member can't hurt
        let (v, _) = median3(1e9, 10.0, 12.0);
        assert!(v <= 12.0);
    }
}
