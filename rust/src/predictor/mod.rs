//! The PROFET predictor (paper Sec III-C): cross-instance median-ensemble
//! models, batch/pixel-size polynomial models, and the end-to-end facade.

mod batch_pixel;
mod cross_instance;
mod profet;

pub use batch_pixel::BatchPixelModel;
pub use cross_instance::{CrossInstanceModel, EnsembleConfig, Member};
pub use profet::{sweep_orphaned_saves, CorruptModel, MissingModels, Profet, TrainOptions};
