//! Minimal readiness-polling shim over the platform's native poller —
//! epoll on Linux, `poll(2)` on other Unixes — declared directly against
//! the C library so the crate stays dependency-light (std already links
//! libc; no new crates).
//!
//! The API is deliberately tiny: a [`Poller`] owns one kernel readiness
//! set; sockets are registered with a `u64` token and an [`Interest`]
//! mask, and [`Poller::wait`] fills a reusable [`Event`] vector. A
//! [`Waker`] (a nonblocking self-pipe) lets other threads interrupt a
//! blocked `wait` — the completion hand-back path from engine lanes to
//! reactor threads rides on it.
//!
//! Everything here is **level-triggered**: an event keeps firing while
//! the condition holds, so callers must either consume the readiness
//! (read/write until `WouldBlock`) or drop the interest bit. The
//! connection reactor ([`crate::coordinator::reactor`]) does both.

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// Which readiness a registration asks for. `NONE` keeps the fd
/// registered (hangup/error are always reported by the kernel) without
/// read/write interest — the reactor parks connections this way while an
/// engine job is in flight.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const NONE: Interest = Interest { readable: false, writable: false };
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const WRITE: Interest = Interest { readable: false, writable: true };

    pub fn with_writable(self, writable: bool) -> Interest {
        Interest { writable, ..self }
    }
}

/// One readiness report. `hangup` covers peer hangup *and* error
/// conditions (EPOLLHUP/EPOLLERR and their `poll(2)` twins) — both mean
/// "this socket needs attention even if no interest bit was set".
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

pub use sys::{Poller, Waker};

/// Clamp an optional wait to the C poller's `int` milliseconds
/// (`None` → -1 = block forever; sub-millisecond waits round up so a
/// positive timeout never busy-loops as 0).
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            if d.is_zero() {
                0
            } else {
                d.as_millis().clamp(1, i32::MAX as u128) as i32
            }
        }
    }
}

#[cfg(target_os = "linux")]
mod sys {
    use super::{timeout_ms, Event, Interest};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const O_NONBLOCK: i32 = 0o4000;
    const O_CLOEXEC: i32 = 0o2000000;
    const EINTR: i32 = 4;

    /// Mirrors glibc's `struct epoll_event`, which is declared packed —
    /// matching the layout exactly is what makes the raw declarations
    /// below safe without the libc crate.
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn pipe2(fds: *mut i32, flags: i32) -> i32;
        fn close(fd: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = 0;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    /// One epoll instance. Not `Sync` by use: each reactor thread owns
    /// its own poller; cross-thread signaling goes through [`Waker`].
    pub struct Poller {
        epfd: RawFd,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            // SAFETY: no pointer arguments; the returned fd is checked
            // for errors before being stored.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let mut ev = EpollEvent { events: mask(interest), data: token };
            // SAFETY: `ev` is a live stack value the kernel only reads;
            // epfd is the owned epoll fd and the result is checked.
            if unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn del(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, Interest::NONE)
        }

        /// Block up to `timeout` (forever when `None`) and append every
        /// ready event to `out` (cleared first). EINTR retries
        /// internally.
        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let mut buf = [EpollEvent { events: 0, data: 0 }; 256];
            loop {
                // SAFETY: `buf` is a live stack array and the length
                // passed is exactly `buf.len()`, so the kernel writes
                // at most that many events; epfd is the owned epoll fd.
                let n = unsafe {
                    epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as i32, timeout_ms(timeout))
                };
                if n < 0 {
                    let err = io::Error::last_os_error();
                    if err.raw_os_error() == Some(EINTR) {
                        continue;
                    }
                    return Err(err);
                }
                for ev in &buf[..n as usize] {
                    // copy packed fields by value (no references into a
                    // packed struct)
                    let bits = ev.events;
                    let token = ev.data;
                    out.push(Event {
                        token,
                        readable: bits & EPOLLIN != 0,
                        writable: bits & EPOLLOUT != 0,
                        hangup: bits & (EPOLLHUP | EPOLLERR) != 0,
                    });
                }
                return Ok(());
            }
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: epfd was returned by `epoll_create1` in `new`,
            // is owned exclusively by this Poller, and is closed once.
            unsafe { close(self.epfd) };
        }
    }

    /// Self-pipe waker: `wake()` is safe from any thread; the read end
    /// is registered with the owning poller and drained on wakeup.
    pub struct Waker {
        rfd: RawFd,
        wfd: RawFd,
    }

    impl Waker {
        pub fn new() -> io::Result<Waker> {
            let mut fds = [0i32; 2];
            // SAFETY: `pipe2` writes exactly two fds into the provided
            // 2-element array; the result is checked before use.
            if unsafe { pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Waker { rfd: fds[0], wfd: fds[1] })
        }

        /// Interrupt the poller. A full pipe means a wake is already
        /// pending, so the failed write is deliberately ignored.
        pub fn wake(&self) {
            let b = 1u8;
            // SAFETY: writes 1 byte from a live stack variable to the
            // owned pipe write end; EAGAIN on a full pipe is ignored
            // (a wake is already pending).
            unsafe { write(self.wfd, &b, 1) };
        }

        /// Consume pending wake bytes (level-triggered: the readable
        /// event repeats until the pipe is empty).
        pub fn drain(&self) {
            let mut buf = [0u8; 64];
            loop {
                // SAFETY: `buf` is a live stack array and `buf.len()`
                // bounds the write; rfd is the owned O_NONBLOCK pipe
                // read end, so a short/failed read just ends the loop.
                let n = unsafe { read(self.rfd, buf.as_mut_ptr(), buf.len()) };
                if n < buf.len() as isize {
                    return;
                }
            }
        }

        /// The fd to register with the poller (read end).
        pub fn fd(&self) -> RawFd {
            self.rfd
        }
    }

    impl Drop for Waker {
        fn drop(&mut self) {
            // SAFETY: both fds were returned by `pipe2` in `new`, are
            // owned exclusively by this Waker, and are closed once.
            unsafe {
                close(self.rfd);
                close(self.wfd);
            }
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    //! `poll(2)` fallback for non-Linux Unixes: same API, with the
    //! interest set tracked in user space and rebuilt per wait. Fine for
    //! portability/testing; the Linux epoll backend is the serving path.

    use super::{timeout_ms, Event, Interest};
    use std::cell::RefCell;
    use std::collections::BTreeMap;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const F_SETFL: i32 = 4;
    // BSD/darwin O_NONBLOCK (differs from Linux's 0o4000)
    const O_NONBLOCK: i32 = 0x4;
    const EINTR: i32 = 4;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
        fn pipe(fds: *mut i32) -> i32;
        fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
        fn close(fd: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    }

    pub struct Poller {
        registered: RefCell<BTreeMap<RawFd, (u64, Interest)>>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { registered: RefCell::new(BTreeMap::new()) })
        }

        pub fn add(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.registered.borrow_mut().insert(fd, (token, interest));
            Ok(())
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.registered.borrow_mut().insert(fd, (token, interest));
            Ok(())
        }

        pub fn del(&self, fd: RawFd) -> io::Result<()> {
            self.registered.borrow_mut().remove(&fd);
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            let (mut fds, tokens): (Vec<PollFd>, Vec<u64>) = {
                let reg = self.registered.borrow();
                let fds = reg
                    .iter()
                    .map(|(&fd, &(_, i))| PollFd {
                        fd,
                        events: if i.readable { POLLIN } else { 0 }
                            | if i.writable { POLLOUT } else { 0 },
                        revents: 0,
                    })
                    .collect();
                let tokens = reg.values().map(|&(t, _)| t).collect();
                (fds, tokens)
            };
            loop {
                // SAFETY: `fds` is a live Vec rebuilt above; the length
                // passed is exactly `fds.len()`, and the kernel only
                // mutates `revents` within those bounds.
                let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as u32, timeout_ms(timeout)) };
                if n < 0 {
                    let err = io::Error::last_os_error();
                    if err.raw_os_error() == Some(EINTR) {
                        continue;
                    }
                    return Err(err);
                }
                for (pfd, &token) in fds.iter().zip(&tokens) {
                    if pfd.revents != 0 {
                        out.push(Event {
                            token,
                            readable: pfd.revents & POLLIN != 0,
                            writable: pfd.revents & POLLOUT != 0,
                            hangup: pfd.revents & (POLLHUP | POLLERR) != 0,
                        });
                    }
                }
                return Ok(());
            }
        }
    }

    pub struct Waker {
        rfd: RawFd,
        wfd: RawFd,
    }

    impl Waker {
        pub fn new() -> io::Result<Waker> {
            let mut fds = [0i32; 2];
            // SAFETY: `pipe` writes exactly two fds into the provided
            // 2-element array; the result is checked before use.
            if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
                return Err(io::Error::last_os_error());
            }
            for fd in fds {
                // SAFETY: plain fcntl flag set on an fd we just
                // created; no pointers involved.
                if unsafe { fcntl(fd, F_SETFL, O_NONBLOCK) } < 0 {
                    return Err(io::Error::last_os_error());
                }
            }
            Ok(Waker { rfd: fds[0], wfd: fds[1] })
        }

        pub fn wake(&self) {
            let b = 1u8;
            // SAFETY: writes 1 byte from a live stack variable to the
            // owned pipe write end; EAGAIN on a full pipe is ignored
            // (a wake is already pending).
            unsafe { write(self.wfd, &b, 1) };
        }

        pub fn drain(&self) {
            let mut buf = [0u8; 64];
            loop {
                // SAFETY: `buf` is a live stack array and `buf.len()`
                // bounds the write; rfd is the owned nonblocking pipe
                // read end, so a short/failed read just ends the loop.
                let n = unsafe { read(self.rfd, buf.as_mut_ptr(), buf.len()) };
                if n < buf.len() as isize {
                    return;
                }
            }
        }

        pub fn fd(&self) -> RawFd {
            self.rfd
        }
    }

    impl Drop for Waker {
        fn drop(&mut self) {
            // SAFETY: both fds were returned by `pipe` in `new`, are
            // owned exclusively by this Waker, and are closed once.
            unsafe {
                close(self.rfd);
                close(self.wfd);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    #[test]
    fn waker_wakes_a_blocked_wait() {
        let poller = Poller::new().unwrap();
        let waker = std::sync::Arc::new(Waker::new().unwrap());
        poller.add(waker.fd(), 42, Interest::READ).unwrap();
        let w2 = waker.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w2.wake();
        });
        let mut events = Vec::new();
        // would block forever without the wake
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        assert!(events.iter().any(|e| e.token == 42 && e.readable));
        waker.drain();
        // drained: a zero-timeout wait reports nothing
        poller.wait(&mut events, Some(Duration::ZERO)).unwrap();
        assert!(events.is_empty(), "{events:?}");
        t.join().unwrap();
    }

    #[test]
    fn socket_readability_and_interest_changes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let fd = server.as_raw_fd();

        let poller = Poller::new().unwrap();
        poller.add(fd, 7, Interest::READ).unwrap();
        let mut events = Vec::new();

        // nothing to read yet
        poller.wait(&mut events, Some(Duration::ZERO)).unwrap();
        assert!(!events.iter().any(|e| e.token == 7 && e.readable));

        client.write_all(b"x").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable), "{events:?}");

        // dropping read interest silences the (unconsumed, level-triggered)
        // readable condition
        poller.modify(fd, 7, Interest::NONE).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(!events.iter().any(|e| e.token == 7 && e.readable), "{events:?}");

        // a write-interested, unfull socket reports writable immediately
        poller.modify(fd, 7, Interest::WRITE).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.writable), "{events:?}");

        poller.del(fd).unwrap();
    }
}
