//! Small shared utilities: deterministic RNG, stable hashing, math helpers.
//!
//! Everything in the repo that needs randomness (simulator measurement
//! noise, forest bootstraps, DNN init, dataset splits) goes through
//! [`Rng64`] seeded from explicit values, so every experiment is exactly
//! reproducible run-to-run.

pub mod failpoint;
pub mod json;
pub mod json_stream;
pub mod poll;
mod rng;

pub use json::Json;
pub use rng::Rng64;

/// FNV-1a 64-bit hash over a byte slice — stable across runs/platforms,
/// used to derive per-(workload, gpu, op) noise seeds.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash several string/number parts into one seed.
pub fn seed_of(parts: &[&str]) -> u64 {
    let joined = parts.join("\u{1f}");
    fnv1a(joined.as_bytes())
}

/// NaN-tolerant ordering for f64 scores (NaN compares `Equal`; callers
/// filter non-finite values upstream). One shared definition so ranking,
/// frontier, and planner tie semantics can never drift apart.
pub fn cmp_f64(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b).unwrap_or(std::cmp::Ordering::Equal)
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance of a slice.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Median (by value) of a slice; NaNs sort last. 0.0 for empty.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Quantile via linear interpolation (q in [0,1]).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_stable() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a(b"Conv2D"), fnv1a(b"Conv2d"));
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_variance() {
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((variance(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }
}
