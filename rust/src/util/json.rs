//! Minimal JSON parser/serializer (no external crates in this offline env).
//!
//! Supports the full JSON grammar minus exotic escapes (lone \uXXXX
//! escapes are decoded; surrogate pairs degrade to U+FFFD). Used for
//! `artifacts/meta.json` and model persistence — the coordinator's
//! line-delimited protocol now runs on the allocation-free streaming
//! layer in [`crate::util::json_stream`] and only uses this DOM on cold
//! paths (and as the reference decoder in the differential wire tests).
//!
//! Numbers are rendered by the shared shortest-round-trip formatter
//! ([`crate::util::json_stream::push_f64`]): every finite value parses
//! back bitwise-equal (including `-0.0`), and non-finite values — which
//! have no JSON representation — serialize as `null` instead of the
//! unparseable `NaN`/`inf` tokens this serializer used to emit.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if self is not an object).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("missing/invalid number field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing/invalid string field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing/invalid array field `{key}`"))
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => crate::util::json_stream::push_f64(out, *n),
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn to_f64s(&self) -> Result<Vec<f64>> {
        match self {
            Json::Arr(a) => a
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-number in array")))
                .collect(),
            _ => bail!("not an array"),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow!("short \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected , or ] at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected , or }} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_meta_like() {
        let text = r#"{"d_feat": 48, "adam": {"lr": 0.001}, "hidden": [128, 64, 32, 16, 1], "name": "a\"b"}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req_usize("d_feat").unwrap(), 48);
        assert_eq!(v.get("adam").unwrap().req_f64("lr").unwrap(), 0.001);
        assert_eq!(v.req_arr("hidden").unwrap().len(), 5);
        assert_eq!(v.req_str("name").unwrap(), "a\"b");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
        // integer-valued floats serialize without decimal point
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null_not_garbage() {
        // NaN/inf have no JSON representation; the old serializer emitted
        // unparseable `NaN`/`inf` tokens
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        let mut o = Json::obj();
        o.set("x", Json::Num(f64::NAN));
        assert!(Json::parse(&o.to_string()).is_ok());
        // -0.0 keeps its sign bit through a round trip
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        let back = Json::parse("-0").unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn control_chars_escape_and_round_trip() {
        let nasty: String = (0u8..0x20).map(|b| b as char).collect();
        let tok = Json::Str(nasty.clone()).to_string();
        assert!(tok.bytes().all(|b| b >= 0x20), "{tok:?}");
        let re = Json::parse(&tok).unwrap();
        assert_eq!(re.as_str(), Some(nasty.as_str()));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""aéb""#).unwrap().as_str(),
            Some("aéb")
        );
    }

    #[test]
    fn f64s_helpers() {
        let j = Json::from_f64s(&[1.0, 2.5]);
        assert_eq!(j.to_f64s().unwrap(), vec![1.0, 2.5]);
    }
}
