//! Deterministic fault injection: named failpoints.
//!
//! A failpoint is a named hook compiled into a code path (`registry.
//! save.stage`, `lane.execute`, ...) that normally does nothing. Chaos
//! tests — and operators reproducing an incident — arm points at
//! runtime with an action:
//!
//! | action             | effect at the hook                            |
//! |--------------------|-----------------------------------------------|
//! | `off`              | disarmed (same as never configured)           |
//! | `return-err`       | the caller takes its error path               |
//! | `panic`            | `panic!` unwinds from the hook                |
//! | `partial-write(n)` | the caller truncates the write to `n` bytes   |
//! | `delay(ms)`        | the hook sleeps `ms` milliseconds, then no-op |
//!
//! Configuration comes from the `REPRO_FAILPOINTS` environment variable
//! or the `repro serve --failpoints` flag, both in the same syntax:
//! `name=action;name=action` (e.g.
//! `registry.save.finalize=panic;reactor.write=delay(25)`). The full
//! catalogue of compiled-in points lives in `docs/RESILIENCE.md`.
//!
//! **Hot-path cost.** [`check`] is a single relaxed atomic load and a
//! predictable branch while no point is armed — no lock, no allocation,
//! no syscall — so the reactor's zero-allocation warm predict path
//! (`tests/wire_alloc.rs`) is unaffected by failpoints being compiled
//! in. The slow path (a `Mutex` + `BTreeMap` lookup) only runs while at
//! least one point is armed, which never happens in production unless
//! an operator asked for it.
//!
//! Dependency-free by design (std only): this module must be usable
//! from every layer, including `util` itself.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// What an armed failpoint does when its hook is reached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Disarmed — identical to the point never being configured.
    Off,
    /// The caller takes its error path (injected I/O or logic failure).
    ReturnErr,
    /// `panic!` unwinds from the hook (crash/kill simulation).
    Panic,
    /// The caller truncates the write to this many bytes, then errors
    /// (torn-write simulation).
    PartialWrite(usize),
    /// Sleep this many milliseconds at the hook, then continue
    /// (stall/slow-disk simulation).
    Delay(u64),
}

/// What [`check`] asks the *caller* to do. `Panic` and `Delay` are
/// executed inside `check` itself and never surface here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hit {
    /// Take the error path now.
    ReturnErr,
    /// Truncate the pending write to this many bytes, then error.
    PartialWrite(usize),
}

/// Number of currently armed (non-`Off`) points. The hot path reads
/// this once and branches; all mutation happens under [`REGISTRY`]'s
/// lock, which recomputes the count before releasing.
static ARMED: AtomicU32 = AtomicU32::new(0);

struct Registry {
    actions: BTreeMap<String, Action>,
    hits: BTreeMap<String, u64>,
}

static REGISTRY: Mutex<Registry> = Mutex::new(Registry {
    actions: BTreeMap::new(),
    hits: BTreeMap::new(),
});

/// Evaluate the named failpoint. Disarmed points (the production case)
/// cost one relaxed load and a branch. Armed points record a hit and
/// apply their action: `Panic`/`Delay` execute here; `ReturnErr`/
/// `PartialWrite` are returned for the caller to act on.
#[inline]
pub fn check(name: &str) -> Option<Hit> {
    // ordering: advisory arming flag — a configure racing with this
    // load may miss one in-flight hit, which chaos tests tolerate by
    // configuring before issuing traffic. No data is guarded by it.
    if ARMED.load(Ordering::Relaxed) == 0 {
        return None;
    }
    check_armed(name)
}

#[cold]
fn check_armed(name: &str) -> Option<Hit> {
    let action = {
        let mut reg = REGISTRY.lock().unwrap();
        let Some(action) = reg.actions.get(name).copied() else {
            return None;
        };
        if action == Action::Off {
            return None;
        }
        *reg.hits.entry(name.to_string()).or_insert(0) += 1;
        action
    };
    match action {
        Action::Off => None,
        Action::ReturnErr => Some(Hit::ReturnErr),
        Action::PartialWrite(n) => Some(Hit::PartialWrite(n)),
        Action::Panic => panic!("failpoint `{name}` fired: injected panic"),
        Action::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
    }
}

/// Recompute [`ARMED`] from the action table. Call with the lock held.
fn rearm(reg: &Registry) {
    let n = reg.actions.values().filter(|a| **a != Action::Off).count() as u32;
    // ordering: published count is advisory (see `check`); the registry
    // lock already serializes configuration itself.
    ARMED.store(n, Ordering::Relaxed);
}

/// Arm (or disarm, with [`Action::Off`]) one named point.
pub fn configure(name: &str, action: Action) {
    let mut reg = REGISTRY.lock().unwrap();
    reg.actions.insert(name.to_string(), action);
    rearm(&reg);
}

/// Disarm one point and forget its hit counter.
pub fn clear(name: &str) {
    let mut reg = REGISTRY.lock().unwrap();
    reg.actions.remove(name);
    reg.hits.remove(name);
    rearm(&reg);
}

/// Disarm every point and forget all hit counters.
pub fn clear_all() {
    let mut reg = REGISTRY.lock().unwrap();
    reg.actions.clear();
    reg.hits.clear();
    rearm(&reg);
}

/// How many times the named point fired while armed (any action,
/// including `off`-masked points never count).
pub fn hit_count(name: &str) -> u64 {
    REGISTRY
        .lock()
        .unwrap()
        .hits
        .get(name)
        .copied()
        .unwrap_or(0)
}

/// Parse one action in the configuration syntax.
pub fn parse_action(s: &str) -> Result<Action, String> {
    let s = s.trim();
    if let Some(rest) = s.strip_prefix("partial-write(") {
        let n = rest
            .strip_suffix(')')
            .and_then(|v| v.trim().parse::<usize>().ok())
            .ok_or_else(|| format!("bad partial-write argument in `{s}`"))?;
        return Ok(Action::PartialWrite(n));
    }
    if let Some(rest) = s.strip_prefix("delay(") {
        let ms = rest
            .strip_suffix(')')
            .and_then(|v| v.trim().parse::<u64>().ok())
            .ok_or_else(|| format!("bad delay argument in `{s}`"))?;
        return Ok(Action::Delay(ms));
    }
    match s {
        "off" => Ok(Action::Off),
        "return-err" => Ok(Action::ReturnErr),
        "panic" => Ok(Action::Panic),
        other => Err(format!(
            "unknown failpoint action `{other}` \
             (expected off|return-err|panic|partial-write(N)|delay(MS))"
        )),
    }
}

/// Configure a whole `name=action;name=action` spec (the
/// `REPRO_FAILPOINTS` / `--failpoints` syntax). Empty segments are
/// ignored so trailing `;` is fine.
pub fn configure_from_str(spec: &str) -> Result<(), String> {
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, action) = part
            .split_once('=')
            .ok_or_else(|| format!("expected name=action, got `{part}`"))?;
        let name = name.trim();
        if name.is_empty() {
            return Err(format!("empty failpoint name in `{part}`"));
        }
        configure(name, parse_action(action)?);
    }
    Ok(())
}

/// Arm points from the `REPRO_FAILPOINTS` environment variable, if set.
pub fn init_from_env() -> Result<(), String> {
    match std::env::var("REPRO_FAILPOINTS") {
        Ok(spec) => configure_from_str(&spec),
        Err(_) => Ok(()),
    }
}

/// `fp!("name")` — the hook form used at injection sites; expands to
/// [`check`] so a disarmed site stays a relaxed-load branch.
#[macro_export]
macro_rules! fp {
    ($name:literal) => {
        $crate::util::failpoint::check($name)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // every test uses its own `test.<name>.*` point names: the registry
    // is process-global and lib tests run in parallel.

    #[test]
    fn disarmed_points_are_invisible() {
        assert_eq!(check("test.invisible.never-configured"), None);
        configure("test.invisible.off", Action::Off);
        assert_eq!(check("test.invisible.off"), None);
        assert_eq!(hit_count("test.invisible.off"), 0);
        clear("test.invisible.off");
    }

    #[test]
    fn return_err_and_partial_write_surface_to_the_caller() {
        configure("test.surface.err", Action::ReturnErr);
        configure("test.surface.partial", Action::PartialWrite(7));
        assert_eq!(check("test.surface.err"), Some(Hit::ReturnErr));
        assert_eq!(check("test.surface.partial"), Some(Hit::PartialWrite(7)));
        assert_eq!(hit_count("test.surface.err"), 1);
        assert_eq!(check("test.surface.err"), Some(Hit::ReturnErr));
        assert_eq!(hit_count("test.surface.err"), 2);
        clear("test.surface.err");
        clear("test.surface.partial");
        assert_eq!(check("test.surface.err"), None);
        assert_eq!(hit_count("test.surface.err"), 0);
    }

    #[test]
    fn panic_action_unwinds_from_the_hook() {
        configure("test.panic.point", Action::Panic);
        let r = std::panic::catch_unwind(|| check("test.panic.point"));
        clear("test.panic.point");
        assert!(r.is_err(), "panic action must unwind");
        assert_eq!(check("test.panic.point"), None, "cleared after the test");
    }

    #[test]
    fn delay_action_sleeps_then_continues() {
        configure("test.delay.point", Action::Delay(20));
        let t0 = std::time::Instant::now();
        assert_eq!(check("test.delay.point"), None);
        assert!(t0.elapsed() >= Duration::from_millis(15));
        assert_eq!(hit_count("test.delay.point"), 1);
        clear("test.delay.point");
    }

    #[test]
    fn spec_syntax_round_trips() {
        assert_eq!(parse_action("off"), Ok(Action::Off));
        assert_eq!(parse_action("return-err"), Ok(Action::ReturnErr));
        assert_eq!(parse_action("panic"), Ok(Action::Panic));
        assert_eq!(parse_action("partial-write(12)"), Ok(Action::PartialWrite(12)));
        assert_eq!(parse_action("delay(250)"), Ok(Action::Delay(250)));
        assert!(parse_action("explode").is_err());
        assert!(parse_action("partial-write(x)").is_err());
        assert!(parse_action("delay()").is_err());

        configure_from_str(
            "test.spec.a=return-err; test.spec.b=delay(1);; test.spec.c=off;",
        )
        .unwrap();
        assert_eq!(check("test.spec.a"), Some(Hit::ReturnErr));
        assert_eq!(check("test.spec.c"), None);
        assert!(configure_from_str("no-equals-sign").is_err());
        assert!(configure_from_str("=panic").is_err());
        clear("test.spec.a");
        clear("test.spec.b");
        clear("test.spec.c");
    }

    #[test]
    fn fp_macro_expands_to_check() {
        configure("test.macro.point", Action::ReturnErr);
        assert_eq!(crate::fp!("test.macro.point"), Some(Hit::ReturnErr));
        clear("test.macro.point");
        assert_eq!(crate::fp!("test.macro.point"), None);
    }
}
