//! Zero-allocation streaming JSON layer for the serving hot loop.
//!
//! The DOM [`crate::util::Json`] materializes a `BTreeMap<String, Json>`
//! plus one heap `String` per key for every request *and* response line —
//! fine for model persistence and `artifacts/meta.json`, but pure per-line
//! overhead on the wire. This module replaces it on the hot path with:
//!
//! * [`LineScratch::scan`] — a single-pass pull decoder over one request
//!   line. Strings are **borrowed** `&str` slices of the line when they
//!   contain no escapes; escaped ones are cow'd into one reusable
//!   per-connection scratch `String`. Top-level fields, flat number/string
//!   arrays, and flat `{op: ms}` profile objects are indexed into reusable
//!   `Vec`s ([`RawVal`]/[`RawElem`]/[`RawPair`]) — a warm scan allocates
//!   nothing. The accepted grammar and every error message (including byte
//!   offsets) deliberately mirror the DOM parser, so the two decoders are
//!   interchangeable (enforced by the differential fuzz test in
//!   `tests/wire_differential.rs`). One hardening divergence: nesting is
//!   capped at [`MAX_DEPTH`] instead of recursing until the stack dies.
//! * [`JsonWriter`] — a direct-to-buffer encoder writing into a reusable
//!   `Vec<u8>` that is handed straight to the socket write. No
//!   intermediate `Json` values, no `String`s.
//! * [`write_f64`] — a hand-rolled Grisu2 shortest-round-trip `f64`
//!   formatter (no external crates in this offline env). Every emitted
//!   number parses back **bitwise-equal** (`-0.0` included); the output is
//!   verified by re-parsing and falls back to the std formatter on any
//!   disagreement, so a formatter bug can only cost nanoseconds, never
//!   correctness. Non-finite values serialize as `null` — the one JSON
//!   token that cannot silently corrupt a stream (satellite fix shared
//!   with the DOM serializer).
//!
//! The protocol layer (`coordinator/protocol.rs`) builds its DOM-free
//! request parsing and response encoding on these primitives.

use anyhow::{anyhow, bail, Result};

/// Nesting cap for the streaming decoder. The DOM parser recurses
/// unboundedly (a `[[[[…` line could exhaust the stack); the streaming
/// path fails with a structured error instead. Protocol requests nest at
/// most 2 deep, so the cap is unobservable for well-formed traffic.
pub const MAX_DEPTH: u32 = 96;

// ---------------------------------------------------------------------------
// f64 formatting: Grisu2 shortest round-trip digits + layout
// ---------------------------------------------------------------------------

/// Grisu scaling window: after multiplying by the cached power of ten the
/// binary exponent must land in `[ALPHA, GAMMA]` (Loitsch 2010).
const ALPHA: i32 = -60;
const GAMMA: i32 = -32;

#[derive(Debug, Clone, Copy)]
struct DiyFp {
    f: u64,
    e: i32,
}

fn normalize(mut x: DiyFp) -> DiyFp {
    while x.f & (1 << 63) == 0 {
        x.f <<= 1;
        x.e -= 1;
    }
    x
}

fn mul(x: DiyFp, y: DiyFp) -> DiyFp {
    let p = (x.f as u128) * (y.f as u128);
    DiyFp {
        f: ((p >> 64) as u64).wrapping_add((p as u64) >> 63),
        e: x.e + y.e + 64,
    }
}

/// (normalized v, lower boundary, upper boundary) — boundaries share the
/// upper's exponent.
fn boundaries(v: f64) -> (DiyFp, DiyFp, DiyFp) {
    let bits = v.to_bits();
    let be = ((bits >> 52) & 0x7ff) as i32;
    let frac = bits & ((1u64 << 52) - 1);
    let (f, e) = if be == 0 {
        (frac, -1074)
    } else {
        (frac + (1u64 << 52), be - 1075)
    };
    let plus = normalize(DiyFp { f: 2 * f + 1, e: e - 1 });
    // at a power of two the lower neighbour is twice as close
    let u_minus = if frac == 0 && be > 1 {
        DiyFp { f: 4 * f - 1, e: e - 2 }
    } else {
        DiyFp { f: 2 * f - 1, e: e - 1 }
    };
    let minus = DiyFp {
        f: u_minus.f << (u_minus.e - plus.e),
        e: plus.e,
    };
    (normalize(DiyFp { f, e }), minus, plus)
}

/// Cached powers of ten `10^k = f × 2^e` (64-bit significands, k in
/// −348..=340 step 8). Generated with exact integer arithmetic; the first
/// entry matches double-conversion's published table.
#[rustfmt::skip]
const POW10_CACHE: [(u64, i32, i32); 87] = [
    (0xfa8fd5a0081c0288, -1220, -348), (0xbaaee17fa23ebf76, -1193, -340), (0x8b16fb203055ac76, -1166, -332),
    (0xcf42894a5dce35ea, -1140, -324), (0x9a6bb0aa55653b2d, -1113, -316), (0xe61acf033d1a45df, -1087, -308),
    (0xab70fe17c79ac6ca, -1060, -300), (0xff77b1fcbebcdc4f, -1034, -292), (0xbe5691ef416bd60c, -1007, -284),
    (0x8dd01fad907ffc3c, -980, -276), (0xd3515c2831559a83, -954, -268), (0x9d71ac8fada6c9b5, -927, -260),
    (0xea9c227723ee8bcb, -901, -252), (0xaecc49914078536d, -874, -244), (0x823c12795db6ce57, -847, -236),
    (0xc21094364dfb5637, -821, -228), (0x9096ea6f3848984f, -794, -220), (0xd77485cb25823ac7, -768, -212),
    (0xa086cfcd97bf97f4, -741, -204), (0xef340a98172aace5, -715, -196), (0xb23867fb2a35b28e, -688, -188),
    (0x84c8d4dfd2c63f3b, -661, -180), (0xc5dd44271ad3cdba, -635, -172), (0x936b9fcebb25c996, -608, -164),
    (0xdbac6c247d62a584, -582, -156), (0xa3ab66580d5fdaf6, -555, -148), (0xf3e2f893dec3f126, -529, -140),
    (0xb5b5ada8aaff80b8, -502, -132), (0x87625f056c7c4a8b, -475, -124), (0xc9bcff6034c13053, -449, -116),
    (0x964e858c91ba2655, -422, -108), (0xdff9772470297ebd, -396, -100), (0xa6dfbd9fb8e5b88f, -369, -92),
    (0xf8a95fcf88747d94, -343, -84), (0xb94470938fa89bcf, -316, -76), (0x8a08f0f8bf0f156b, -289, -68),
    (0xcdb02555653131b6, -263, -60), (0x993fe2c6d07b7fac, -236, -52), (0xe45c10c42a2b3b06, -210, -44),
    (0xaa242499697392d3, -183, -36), (0xfd87b5f28300ca0e, -157, -28), (0xbce5086492111aeb, -130, -20),
    (0x8cbccc096f5088cc, -103, -12), (0xd1b71758e219652c, -77, -4), (0x9c40000000000000, -50, 4),
    (0xe8d4a51000000000, -24, 12), (0xad78ebc5ac620000, 3, 20), (0x813f3978f8940984, 30, 28),
    (0xc097ce7bc90715b3, 56, 36), (0x8f7e32ce7bea5c70, 83, 44), (0xd5d238a4abe98068, 109, 52),
    (0x9f4f2726179a2245, 136, 60), (0xed63a231d4c4fb27, 162, 68), (0xb0de65388cc8ada8, 189, 76),
    (0x83c7088e1aab65db, 216, 84), (0xc45d1df942711d9a, 242, 92), (0x924d692ca61be758, 269, 100),
    (0xda01ee641a708dea, 295, 108), (0xa26da3999aef774a, 322, 116), (0xf209787bb47d6b85, 348, 124),
    (0xb454e4a179dd1877, 375, 132), (0x865b86925b9bc5c2, 402, 140), (0xc83553c5c8965d3d, 428, 148),
    (0x952ab45cfa97a0b3, 455, 156), (0xde469fbd99a05fe3, 481, 164), (0xa59bc234db398c25, 508, 172),
    (0xf6c69a72a3989f5c, 534, 180), (0xb7dcbf5354e9bece, 561, 188), (0x88fcf317f22241e2, 588, 196),
    (0xcc20ce9bd35c78a5, 614, 204), (0x98165af37b2153df, 641, 212), (0xe2a0b5dc971f303a, 667, 220),
    (0xa8d9d1535ce3b396, 694, 228), (0xfb9b7cd9a4a7443c, 720, 236), (0xbb764c4ca7a44410, 747, 244),
    (0x8bab8eefb6409c1a, 774, 252), (0xd01fef10a657842c, 800, 260), (0x9b10a4e5e9913129, 827, 268),
    (0xe7109bfba19c0c9d, 853, 276), (0xac2820d9623bf429, 880, 284), (0x80444b5e7aa7cf85, 907, 292),
    (0xbf21e44003acdd2d, 933, 300), (0x8e679c2f5e44ff8f, 960, 308), (0xd433179d9c8cb841, 986, 316),
    (0x9e19db92b4e31ba9, 1013, 324), (0xeb96bf6ebadf77d9, 1039, 332), (0xaf87023b9bf0ee6b, 1066, 340),
];

/// Smallest cached power whose product with a significand of binary
/// exponent `e_plus` lands at or above [`ALPHA`] (and, because table
/// entries are ~26.6 bits apart while the window is 28 wide, at or below
/// [`GAMMA`]).
fn cached_power(e_plus: i32) -> (DiyFp, i32) {
    let (mut lo, mut hi) = (0usize, POW10_CACHE.len() - 1);
    while lo < hi {
        let mid = (lo + hi) / 2;
        if e_plus + POW10_CACHE[mid].1 + 64 >= ALPHA {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let (f, e, k) = POW10_CACHE[lo];
    debug_assert!((ALPHA..=GAMMA).contains(&(e_plus + e + 64)));
    (DiyFp { f, e }, k)
}

/// (digit count, 10^(count-1)) for a nonzero u32.
fn largest_pow10(n: u32) -> (i32, u32) {
    const POW: [u32; 10] = [
        1, 10, 100, 1_000, 10_000, 100_000, 1_000_000, 10_000_000, 100_000_000, 1_000_000_000,
    ];
    for i in (0..POW.len()).rev() {
        if n >= POW[i] {
            return (i as i32 + 1, POW[i]);
        }
    }
    (1, 1)
}

fn grisu_round(buf: &mut [u8], len: usize, dist: u64, delta: u64, mut rest: u64, ten_k: u64) {
    while rest < dist
        && delta - rest >= ten_k
        && (rest + ten_k < dist || dist - rest > rest + ten_k - dist)
    {
        buf[len - 1] -= 1;
        rest += ten_k;
    }
}

/// Digit generation: shortest digits of the value whose boundaries scale
/// to `minus`/`plus` (all sharing one exponent in `[ALPHA, GAMMA]`).
fn digit_gen(minus: DiyFp, w: DiyFp, plus: DiyFp, buf: &mut [u8; 24], len: &mut usize) -> i32 {
    let mut delta = plus.f.wrapping_sub(minus.f);
    let mut dist = plus.f.wrapping_sub(w.f);
    let e = plus.e; // in [-60, -32]
    let one_f = 1u64 << -e;
    let mut p1 = (plus.f >> -e) as u32;
    let mut p2 = plus.f & (one_f - 1);
    let mut exp10 = 0i32;
    let (k, mut pow10) = largest_pow10(p1);
    let mut n = k;
    while n > 0 {
        let d = p1 / pow10;
        p1 %= pow10;
        buf[*len] = b'0' + d as u8;
        *len += 1;
        n -= 1;
        let rest = ((p1 as u64) << -e) + p2;
        if rest <= delta {
            exp10 += n;
            grisu_round(buf, *len, dist, delta, rest, (pow10 as u64) << -e);
            return exp10;
        }
        pow10 /= 10;
    }
    loop {
        p2 = p2.wrapping_mul(10);
        delta = delta.wrapping_mul(10);
        dist = dist.wrapping_mul(10);
        buf[*len] = b'0' + (p2 >> -e) as u8;
        *len += 1;
        p2 &= one_f - 1;
        exp10 -= 1;
        if p2 <= delta {
            grisu_round(buf, *len, dist, delta, p2, one_f);
            return exp10;
        }
    }
}

/// Shortest digits + decimal exponent for a finite positive double:
/// `value = digits × 10^exp10`.
fn grisu2(v: f64, buf: &mut [u8; 24]) -> (usize, i32) {
    let (w, minus, plus) = boundaries(v);
    let (c, ck) = cached_power(plus.e);
    let w2 = mul(w, c);
    let mut m2 = mul(minus, c);
    let mut p2 = mul(plus, c);
    // tighten by 1 ulp against the rounding of `mul`
    m2.f += 1;
    p2.f -= 1;
    let mut len = 0usize;
    let e10 = digit_gen(m2, w2, p2, buf, &mut len);
    (len, e10 - ck)
}

/// Fixed-size text buffer the formatter renders into (stack only; also
/// the target of the std-formatter fallback, so no path allocates).
struct FloatBuf {
    buf: [u8; 40],
    len: usize,
}

impl FloatBuf {
    fn new() -> FloatBuf {
        FloatBuf { buf: [0; 40], len: 0 }
    }

    fn push(&mut self, b: u8) {
        self.buf[self.len] = b;
        self.len += 1;
    }

    fn extend(&mut self, bytes: &[u8]) {
        self.buf[self.len..self.len + bytes.len()].copy_from_slice(bytes);
        self.len += bytes.len();
    }

    fn as_str(&self) -> &str {
        // only ASCII digits/signs/dots are ever written
        std::str::from_utf8(&self.buf[..self.len]).unwrap_or("0")
    }
}

impl std::fmt::Write for FloatBuf {
    fn write_str(&mut self, s: &str) -> std::fmt::Result {
        if self.len + s.len() > self.buf.len() {
            return Err(std::fmt::Error);
        }
        self.extend(s.as_bytes());
        Ok(())
    }
}

fn push_u64(out: &mut FloatBuf, mut m: u64) {
    let mut tmp = [0u8; 20];
    let mut i = tmp.len();
    loop {
        i -= 1;
        tmp[i] = b'0' + (m % 10) as u8;
        m /= 10;
        if m == 0 {
            break;
        }
    }
    out.extend(&tmp[i..]);
}

/// Digits → number token. Fixed notation for "human" magnitudes,
/// scientific for the extremes; every branch is a valid JSON number.
fn layout(out: &mut FloatBuf, digits: &[u8], e10: i32) {
    let n = digits.len() as i32;
    let dot = n + e10; // decimal point position relative to digits[0]
    if (1..=17).contains(&dot) {
        if dot >= n {
            out.extend(digits);
            for _ in 0..dot - n {
                out.push(b'0');
            }
        } else {
            out.extend(&digits[..dot as usize]);
            out.push(b'.');
            out.extend(&digits[dot as usize..]);
        }
    } else if (-4..=0).contains(&dot) {
        out.extend(b"0.");
        for _ in 0..-dot {
            out.push(b'0');
        }
        out.extend(digits);
    } else {
        out.push(digits[0]);
        if digits.len() > 1 {
            out.push(b'.');
            out.extend(&digits[1..]);
        }
        out.push(b'e');
        if dot - 1 < 0 {
            out.push(b'-');
        }
        push_u64(out, (dot - 1).unsigned_abs() as u64);
    }
}

/// Render `v` as the canonical wire number token:
///
/// * non-finite → `null` (NaN/∞ have no JSON representation; `null` is
///   the only token that cannot corrupt the stream),
/// * `-0.0` → `-0` (parses back bitwise-equal),
/// * integer-valued `|v| < 9e15` → plain integer (matches the DOM
///   serializer's historical behavior),
/// * otherwise Grisu2 shortest digits, re-parse-verified with a std
///   formatter fallback — the emitted token always parses back to
///   exactly `v`'s bit pattern.
fn format_f64(out: &mut FloatBuf, v: f64) {
    if !v.is_finite() {
        out.extend(b"null");
        return;
    }
    if v == 0.0 {
        if v.to_bits() >> 63 == 1 {
            out.push(b'-');
        }
        out.push(b'0');
        return;
    }
    if v.fract() == 0.0 && v.abs() < 9e15 {
        if v < 0.0 {
            out.push(b'-');
        }
        push_u64(out, v.abs() as u64);
        return;
    }
    if v < 0.0 {
        out.push(b'-');
    }
    let mut digits = [0u8; 24];
    let (mut len, mut e10) = grisu2(v.abs(), &mut digits);
    while len > 1 && digits[len - 1] == b'0' {
        len -= 1;
        e10 += 1;
    }
    layout(out, &digits[..len], e10);
    // belt and braces: a formatter bug may cost a fallback, never a wrong
    // wire value
    let ok = out.as_str().parse::<f64>().map(f64::to_bits) == Ok(v.to_bits());
    if !ok {
        out.len = 0;
        use std::fmt::Write as _;
        let _ = write!(out, "{v:e}");
    }
}

/// `format_f64` into a byte buffer (the streaming encoder's sink).
pub fn write_f64(out: &mut Vec<u8>, v: f64) {
    let mut b = FloatBuf::new();
    format_f64(&mut b, v);
    out.extend_from_slice(&b.buf[..b.len]);
}

/// `format_f64` into a `String` (the DOM serializer's sink — both
/// serializers share one float formatter so their outputs agree).
pub fn push_f64(out: &mut String, v: f64) {
    let mut b = FloatBuf::new();
    format_f64(&mut b, v);
    out.push_str(b.as_str());
}

// ---------------------------------------------------------------------------
// String escaping (shared by both serializers)
// ---------------------------------------------------------------------------

/// Write `s` as a JSON string token. Escapes `"` `\` `\n` `\r` `\t` and
/// every other control char < 0x20 as `\u00xx` (the DOM serializer uses
/// the same rules; raw control bytes never reach the wire).
pub fn write_json_str(out: &mut Vec<u8>, s: &str) {
    out.push(b'"');
    let bytes = s.as_bytes();
    let mut run = 0usize;
    for (i, &b) in bytes.iter().enumerate() {
        let esc: &[u8] = match b {
            b'"' => b"\\\"",
            b'\\' => b"\\\\",
            b'\n' => b"\\n",
            b'\r' => b"\\r",
            b'\t' => b"\\t",
            0x00..=0x1f => b"",
            _ => continue,
        };
        out.extend_from_slice(&bytes[run..i]);
        if esc.is_empty() {
            const HEX: &[u8; 16] = b"0123456789abcdef";
            out.extend_from_slice(b"\\u00");
            out.push(HEX[(b >> 4) as usize]);
            out.push(HEX[(b & 0xf) as usize]);
        } else {
            out.extend_from_slice(esc);
        }
        run = i + 1;
    }
    out.extend_from_slice(&bytes[run..]);
    out.push(b'"');
}

// ---------------------------------------------------------------------------
// Direct-to-buffer encoder
// ---------------------------------------------------------------------------

/// Comma/colon-tracking JSON writer over a caller-owned `Vec<u8>`.
/// Purely additive: never clears the buffer, never allocates beyond the
/// buffer's own growth (zero once the buffer is warm). Nesting is capped
/// at 63 levels (a `u64` bitmask tracks "first member emitted" per depth)
/// — far beyond any protocol shape.
pub struct JsonWriter<'a> {
    out: &'a mut Vec<u8>,
    depth: u32,
    started: u64,
    keyed: bool,
}

impl<'a> JsonWriter<'a> {
    pub fn new(out: &'a mut Vec<u8>) -> JsonWriter<'a> {
        JsonWriter { out, depth: 0, started: 0, keyed: false }
    }

    fn value_prefix(&mut self) {
        if self.keyed {
            self.keyed = false;
            return;
        }
        if self.started & (1 << self.depth) != 0 {
            self.out.push(b',');
        } else {
            self.started |= 1 << self.depth;
        }
    }

    pub fn begin_obj(&mut self) -> &mut Self {
        self.value_prefix();
        self.out.push(b'{');
        self.depth += 1;
        debug_assert!(self.depth < 64);
        self.started &= !(1 << self.depth);
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        self.out.push(b'}');
        self.depth -= 1;
        self
    }

    pub fn begin_arr(&mut self) -> &mut Self {
        self.value_prefix();
        self.out.push(b'[');
        self.depth += 1;
        debug_assert!(self.depth < 64);
        self.started &= !(1 << self.depth);
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        self.out.push(b']');
        self.depth -= 1;
        self
    }

    /// Object member key (emits the separating comma when needed).
    pub fn key(&mut self, k: &str) -> &mut Self {
        if self.started & (1 << self.depth) != 0 {
            self.out.push(b',');
        } else {
            self.started |= 1 << self.depth;
        }
        write_json_str(self.out, k);
        self.out.push(b':');
        self.keyed = true;
        self
    }

    pub fn str_(&mut self, s: &str) -> &mut Self {
        self.value_prefix();
        write_json_str(self.out, s);
        self
    }

    pub fn num(&mut self, v: f64) -> &mut Self {
        self.value_prefix();
        write_f64(self.out, v);
        self
    }

    pub fn bool_(&mut self, b: bool) -> &mut Self {
        self.value_prefix();
        self.out.extend_from_slice(if b { b"true" } else { b"false" });
        self
    }

    pub fn null(&mut self) -> &mut Self {
        self.value_prefix();
        self.out.extend_from_slice(b"null");
        self
    }
}

// ---------------------------------------------------------------------------
// Pull decoder: one-pass scan of a request line into reusable indices
// ---------------------------------------------------------------------------

/// A string slice of either the request line or the unescape scratch.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    off: u32,
    len: u32,
    in_scratch: bool,
}

/// A classified top-level field value. Containers index into the
/// scratch's `elems`/`pairs` stores; anything deeper than the flat
/// protocol shapes is validated, then represented by [`RawElem::Other`]
/// or a [`RawPair`] with `bad = true` (exactly the granularity the
/// per-op validation needs to reproduce the DOM parser's errors).
#[derive(Debug, Clone, Copy)]
pub enum RawVal {
    Null,
    Bool(bool),
    Num(f64),
    Str(Span),
    Arr { start: u32, len: u32 },
    Obj { start: u32, len: u32 },
}

/// One element of a top-level array field.
#[derive(Debug, Clone, Copy)]
pub enum RawElem {
    Num(f64),
    Str(Span),
    /// A structurally valid value that is neither a number nor a string.
    Other,
}

/// One member of a flat top-level object field (a profile). `bad` marks
/// a structurally valid value that is not a number.
#[derive(Debug, Clone, Copy)]
pub struct RawPair {
    pub key: Span,
    pub val: f64,
    pub bad: bool,
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", b as char, self.pos)
        }
    }
}

/// Reusable per-connection decode state. `scan` clears and refills the
/// index vectors and the unescape buffer; their capacities persist, so a
/// steady-state scan performs zero heap allocations.
#[derive(Default)]
pub struct LineScratch {
    fields: Vec<(Span, RawVal)>,
    elems: Vec<RawElem>,
    pairs: Vec<RawPair>,
    unescape: String,
}

impl LineScratch {
    /// Scan one line. Mirrors the DOM parser's grammar and error strings
    /// exactly (the differential fuzz test keeps them locked together);
    /// on success the top-level fields are queryable via [`Self::field`].
    pub fn scan(&mut self, line: &str) -> Result<()> {
        anyhow::ensure!(line.len() <= u32::MAX as usize, "line too large to index");
        self.fields.clear();
        self.elems.clear();
        self.pairs.clear();
        self.unescape.clear();
        let mut cur = Cursor { bytes: line.as_bytes(), pos: 0 };
        cur.skip_ws();
        if cur.peek() == Some(b'{') {
            // top-level object: index its fields (any other top-level
            // value is validated and leaves the field table empty, so
            // the op lookup fails with the DOM's error)
            cur.pos += 1;
            cur.skip_ws();
            if cur.peek() == Some(b'}') {
                cur.pos += 1;
            } else {
                loop {
                    cur.skip_ws();
                    let key = self.read_string(&mut cur)?;
                    cur.skip_ws();
                    cur.expect(b':')?;
                    let val = self.classify_value(&mut cur, 1)?;
                    self.fields.push((key, val));
                    cur.skip_ws();
                    match cur.peek() {
                        Some(b',') => cur.pos += 1,
                        Some(b'}') => {
                            cur.pos += 1;
                            break;
                        }
                        _ => bail!("expected , or }} at byte {}", cur.pos),
                    }
                }
            }
        } else {
            // any other top-level value: validate fully (the op lookup
            // will fail with the DOM's "missing/invalid `op`" error)
            self.skip_value(&mut cur, 0)?;
        }
        cur.skip_ws();
        if cur.pos != cur.bytes.len() {
            bail!("trailing data at byte {}", cur.pos);
        }
        Ok(())
    }

    /// Last occurrence of a top-level field (the DOM's `BTreeMap` insert
    /// makes duplicate keys last-wins; lookup from the end mirrors it).
    pub fn field(&self, line: &str, name: &str) -> Option<RawVal> {
        self.fields
            .iter()
            .rev()
            .find(|(k, _)| self.str_of(line, *k) == name)
            .map(|(_, v)| *v)
    }

    /// Resolve a span against the line / the unescape scratch.
    pub fn str_of<'a>(&'a self, line: &'a str, s: Span) -> &'a str {
        let src = if s.in_scratch { self.unescape.as_str() } else { line };
        &src[s.off as usize..(s.off + s.len) as usize]
    }

    pub fn elems(&self, start: u32, len: u32) -> &[RawElem] {
        &self.elems[start as usize..(start + len) as usize]
    }

    pub fn pairs(&self, start: u32, len: u32) -> &[RawPair] {
        &self.pairs[start as usize..(start + len) as usize]
    }

    /// Stable-sort a pair range by key (byte-lexicographic — the same
    /// order a `BTreeMap<String, _>` iterates) and drop duplicate keys
    /// keeping the last occurrence (the DOM's insert semantics). Returns
    /// the compacted length; the range keeps its start.
    pub fn sort_dedup_pairs(&mut self, line: &str, start: u32, len: u32) -> u32 {
        fn resolve<'a>(line: &'a str, unescape: &'a str, s: Span) -> &'a str {
            let src = if s.in_scratch { unescape } else { line };
            &src[s.off as usize..(s.off + s.len) as usize]
        }
        let unescape: &str = &self.unescape;
        let range = &mut self.pairs[start as usize..(start + len) as usize];
        // stable insertion sort, in place: std's stable `sort_by` heap-
        // allocates a merge buffer once the slice outgrows its insertion
        // threshold (~20), which would silently break the zero-allocation
        // guarantee for realistic 30–60-op profiles. Profiles are small,
        // so O(n²) insertion is also the fast choice here. Equal keys are
        // never swapped, so duplicate keys keep wire order (last-wins
        // dedup below stays correct).
        for i in 1..range.len() {
            let mut j = i;
            while j > 0
                && resolve(line, unescape, range[j - 1].key)
                    > resolve(line, unescape, range[j].key)
            {
                range.swap(j - 1, j);
                j -= 1;
            }
        }
        let mut w = 0usize;
        for r in 0..range.len() {
            let last_of_run = r + 1 == range.len()
                || resolve(line, unescape, range[r + 1].key)
                    != resolve(line, unescape, range[r].key);
            if last_of_run {
                range[w] = range[r];
                w += 1;
            }
        }
        w as u32
    }

    /// Parse a string token. Escape-free strings are borrowed from the
    /// line; escaped ones are unescaped into the shared scratch (one
    /// append-only buffer per line — offsets stay stable).
    fn read_string(&mut self, cur: &mut Cursor) -> Result<Span> {
        cur.expect(b'"')?;
        let start = cur.pos;
        // fast path: find the closing quote with no escapes in between
        while let Some(b) = cur.peek() {
            match b {
                b'"' => {
                    let span = Span {
                        off: start as u32,
                        len: (cur.pos - start) as u32,
                        in_scratch: false,
                    };
                    cur.pos += 1;
                    return Ok(span);
                }
                b'\\' => break,
                _ => cur.pos += 1,
            }
        }
        if cur.peek().is_none() {
            bail!("unterminated string");
        }
        // slow path: cow the prefix into the scratch and keep unescaping
        let scratch_start = self.unescape.len();
        // the prefix is valid UTF-8 (token boundaries are ASCII)
        self.unescape
            .push_str(std::str::from_utf8(&cur.bytes[start..cur.pos])?);
        loop {
            match cur.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    cur.pos += 1;
                    return Ok(Span {
                        off: scratch_start as u32,
                        len: (self.unescape.len() - scratch_start) as u32,
                        in_scratch: true,
                    });
                }
                Some(b'\\') => {
                    cur.pos += 1;
                    match cur.peek() {
                        Some(b'"') => self.unescape.push('"'),
                        Some(b'\\') => self.unescape.push('\\'),
                        Some(b'/') => self.unescape.push('/'),
                        Some(b'n') => self.unescape.push('\n'),
                        Some(b't') => self.unescape.push('\t'),
                        Some(b'r') => self.unescape.push('\r'),
                        Some(b'b') => self.unescape.push('\u{8}'),
                        Some(b'f') => self.unescape.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                cur.bytes
                                    .get(cur.pos + 1..cur.pos + 5)
                                    .ok_or_else(|| anyhow!("short \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.unescape.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            cur.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    cur.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (valid UTF-8 by input type)
                    let run_start = cur.pos;
                    while let Some(b) = cur.peek() {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        cur.pos += 1;
                    }
                    self.unescape
                        .push_str(std::str::from_utf8(&cur.bytes[run_start..cur.pos])?);
                }
            }
        }
    }

    fn read_number(&mut self, cur: &mut Cursor) -> Result<f64> {
        let start = cur.pos;
        while let Some(c) = cur.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                cur.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&cur.bytes[start..cur.pos])?;
        s.parse::<f64>().map_err(|e| anyhow!("{e}"))
    }

    fn read_literal(&mut self, cur: &mut Cursor, word: &str) -> Result<()> {
        if cur.bytes[cur.pos..].starts_with(word.as_bytes()) {
            cur.pos += word.len();
            Ok(())
        } else {
            bail!("bad literal at byte {}", cur.pos)
        }
    }

    /// Classify one field-level value: scalars inline, arrays/objects one
    /// level deep into the element/pair stores, anything deeper validated
    /// and recorded as `Other`/`bad`.
    fn classify_value(&mut self, cur: &mut Cursor, depth: u32) -> Result<RawVal> {
        cur.skip_ws();
        match cur.peek() {
            Some(b'{') => {
                let start = self.pairs.len() as u32;
                cur.pos += 1;
                cur.skip_ws();
                if cur.peek() == Some(b'}') {
                    cur.pos += 1;
                    return Ok(RawVal::Obj { start, len: 0 });
                }
                loop {
                    cur.skip_ws();
                    let key = self.read_string(cur)?;
                    cur.skip_ws();
                    cur.expect(b':')?;
                    cur.skip_ws();
                    let pair = match cur.peek() {
                        Some(c) if c == b'-' || c.is_ascii_digit() => RawPair {
                            key,
                            val: self.read_number(cur)?,
                            bad: false,
                        },
                        _ => {
                            self.skip_value(cur, depth + 1)?;
                            RawPair { key, val: 0.0, bad: true }
                        }
                    };
                    self.pairs.push(pair);
                    cur.skip_ws();
                    match cur.peek() {
                        Some(b',') => cur.pos += 1,
                        Some(b'}') => {
                            cur.pos += 1;
                            return Ok(RawVal::Obj { start, len: self.pairs.len() as u32 - start });
                        }
                        _ => bail!("expected , or }} at byte {}", cur.pos),
                    }
                }
            }
            Some(b'[') => {
                let start = self.elems.len() as u32;
                cur.pos += 1;
                cur.skip_ws();
                if cur.peek() == Some(b']') {
                    cur.pos += 1;
                    return Ok(RawVal::Arr { start, len: 0 });
                }
                loop {
                    cur.skip_ws();
                    let elem = match cur.peek() {
                        Some(c) if c == b'-' || c.is_ascii_digit() => {
                            RawElem::Num(self.read_number(cur)?)
                        }
                        Some(b'"') => RawElem::Str(self.read_string(cur)?),
                        _ => {
                            self.skip_value(cur, depth + 1)?;
                            RawElem::Other
                        }
                    };
                    self.elems.push(elem);
                    cur.skip_ws();
                    match cur.peek() {
                        Some(b',') => cur.pos += 1,
                        Some(b']') => {
                            cur.pos += 1;
                            return Ok(RawVal::Arr { start, len: self.elems.len() as u32 - start });
                        }
                        _ => bail!("expected , or ] at byte {}", cur.pos),
                    }
                }
            }
            Some(b'"') => Ok(RawVal::Str(self.read_string(cur)?)),
            Some(b't') => {
                self.read_literal(cur, "true")?;
                Ok(RawVal::Bool(true))
            }
            Some(b'f') => {
                self.read_literal(cur, "false")?;
                Ok(RawVal::Bool(false))
            }
            Some(b'n') => {
                self.read_literal(cur, "null")?;
                Ok(RawVal::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => Ok(RawVal::Num(self.read_number(cur)?)),
            other => bail!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                cur.pos
            ),
        }
    }

    /// Validate (and discard) one value of any shape, with the same
    /// grammar/errors as the DOM parser, bounded by [`MAX_DEPTH`].
    fn skip_value(&mut self, cur: &mut Cursor, depth: u32) -> Result<()> {
        anyhow::ensure!(depth <= MAX_DEPTH, "nesting deeper than {MAX_DEPTH} levels");
        cur.skip_ws();
        match cur.peek() {
            Some(b'{') => {
                cur.pos += 1;
                cur.skip_ws();
                if cur.peek() == Some(b'}') {
                    cur.pos += 1;
                    return Ok(());
                }
                loop {
                    cur.skip_ws();
                    self.read_string(cur)?;
                    cur.skip_ws();
                    cur.expect(b':')?;
                    self.skip_value(cur, depth + 1)?;
                    cur.skip_ws();
                    match cur.peek() {
                        Some(b',') => cur.pos += 1,
                        Some(b'}') => {
                            cur.pos += 1;
                            return Ok(());
                        }
                        _ => bail!("expected , or }} at byte {}", cur.pos),
                    }
                }
            }
            Some(b'[') => {
                cur.pos += 1;
                cur.skip_ws();
                if cur.peek() == Some(b']') {
                    cur.pos += 1;
                    return Ok(());
                }
                loop {
                    self.skip_value(cur, depth + 1)?;
                    cur.skip_ws();
                    match cur.peek() {
                        Some(b',') => cur.pos += 1,
                        Some(b']') => {
                            cur.pos += 1;
                            return Ok(());
                        }
                        _ => bail!("expected , or ] at byte {}", cur.pos),
                    }
                }
            }
            Some(b'"') => self.read_string(cur).map(|_| ()),
            Some(b't') => self.read_literal(cur, "true"),
            Some(b'f') => self.read_literal(cur, "false"),
            Some(b'n') => self.read_literal(cur, "null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.read_number(cur).map(|_| ()),
            other => bail!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                cur.pos
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Json, Rng64};

    fn fmt(v: f64) -> String {
        let mut out = Vec::new();
        write_f64(&mut out, v);
        String::from_utf8(out).unwrap()
    }

    #[test]
    fn float_tokens_match_expectations() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(-0.0), "-0");
        assert_eq!(fmt(42.0), "42");
        assert_eq!(fmt(-42.0), "-42");
        assert_eq!(fmt(12.5), "12.5");
        assert_eq!(fmt(0.1), "0.1");
        assert_eq!(fmt(1e16), "10000000000000000");
        assert_eq!(fmt(1e300), "1e300");
        assert_eq!(fmt(5e-324), "5e-324");
        assert_eq!(fmt(f64::NAN), "null");
        assert_eq!(fmt(f64::INFINITY), "null");
        assert_eq!(fmt(f64::NEG_INFINITY), "null");
    }

    /// The satellite property test: serialize → parse is bitwise identity
    /// over a seeded sweep (specials + random bit patterns), shared by
    /// the streaming and DOM encoders (which use the same formatter —
    /// also asserted here).
    #[test]
    fn float_round_trip_is_bitwise_over_seeded_sweep() {
        let specials = [
            0.0,
            -0.0,
            1.0,
            0.1,
            1.0 / 3.0,
            2.0 / 3.0,
            1e-5,
            9e15,
            9.007199254740992e15,
            1e16,
            1e300,
            1e-300,
            5e-324,
            2.2250738585072014e-308,
            2.225073858507201e-308, // largest subnormal
            f64::MAX,
            f64::MIN_POSITIVE,
            3.141592653589793,
            1.0 + f64::EPSILON,
        ];
        let mut check = |v: f64| {
            let s = fmt(v);
            let back: f64 = s.parse().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v:?} -> {s}");
            // the DOM serializer goes through the same formatter
            assert_eq!(Json::Num(v).to_string(), s, "{v:?}");
            // and the DOM parser accepts the token back
            assert_eq!(
                Json::parse(&s).unwrap().as_f64().map(f64::to_bits),
                Some(v.to_bits())
            );
        };
        for &v in &specials {
            check(v);
            check(-v);
        }
        let mut rng = Rng64::new(0xF10A7);
        for _ in 0..20_000 {
            let v = f64::from_bits(rng.next_u64());
            if v.is_finite() {
                check(v);
            }
        }
        for _ in 0..5_000 {
            check(rng.range(-1e6, 1e6));
        }
    }

    #[test]
    fn non_finite_floats_serialize_as_null_everywhere() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(fmt(v), "null");
            assert_eq!(Json::Num(v).to_string(), "null");
            let mut s = String::new();
            push_f64(&mut s, v);
            assert_eq!(s, "null");
        }
        // and inside structures the result still parses
        let j = Json::Arr(vec![Json::Num(f64::NAN), Json::Num(1.5)]);
        assert_eq!(Json::parse(&j.to_string()).unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn control_chars_escape_and_round_trip() {
        let nasty: String = (0u8..0x20).map(|b| b as char).chain("aé\"\\b".chars()).collect();
        let mut out = Vec::new();
        write_json_str(&mut out, &nasty);
        let tok = String::from_utf8(out).unwrap();
        // no raw control bytes on the wire
        assert!(tok.bytes().all(|b| b >= 0x20), "{tok:?}");
        assert_eq!(Json::parse(&tok).unwrap().as_str(), Some(nasty.as_str()));
        // DOM serializer produces the identical token
        assert_eq!(Json::Str(nasty.clone()).to_string(), tok);
    }

    #[test]
    fn writer_nests_and_separates() {
        let mut out = Vec::new();
        let mut w = JsonWriter::new(&mut out);
        w.begin_obj();
        w.key("a").num(1.0);
        w.key("b").begin_arr();
        w.num(1.5).str_("x").bool_(true).null();
        w.begin_obj().end_obj();
        w.end_arr();
        w.key("c").begin_obj();
        w.key("d").str_("e\nf");
        w.end_obj();
        w.end_obj();
        let s = String::from_utf8(out).unwrap();
        assert_eq!(s, r#"{"a":1,"b":[1.5,"x",true,null,{}],"c":{"d":"e\nf"}}"#);
        assert_eq!(Json::parse(&s).unwrap().req_f64("a").unwrap(), 1.0);
    }

    #[test]
    fn scan_borrows_unescapes_and_indexes() {
        let line = r#"{"op":"predict","anchor_latency_ms":42.5,"profile":{"Conv2D":286.0,"abc":1.5},"flags":[1,"x",true],"spot":false,"z":null}"#;
        let mut s = LineScratch::default();
        s.scan(line).unwrap();
        let Some(RawVal::Str(op)) = s.field(line, "op") else { panic!() };
        assert_eq!(s.str_of(line, op), "predict");
        assert!(matches!(s.field(line, "anchor_latency_ms"), Some(RawVal::Num(v)) if v == 42.5));
        let Some(RawVal::Obj { start, len }) = s.field(line, "profile") else { panic!() };
        assert_eq!(len, 2);
        let n = s.sort_dedup_pairs(line, start, len);
        let pairs = s.pairs(start, n);
        assert_eq!(s.str_of(line, pairs[0].key), "Conv2D");
        assert_eq!(s.str_of(line, pairs[1].key), "abc"); // unescaped key
        assert_eq!(pairs[1].val, 1.5);
        let Some(RawVal::Arr { start, len }) = s.field(line, "flags") else { panic!() };
        let el = s.elems(start, len);
        assert!(matches!(el[0], RawElem::Num(v) if v == 1.0));
        assert!(matches!(el[1], RawElem::Str(_)));
        assert!(matches!(el[2], RawElem::Other));
        assert!(matches!(s.field(line, "spot"), Some(RawVal::Bool(false))));
        assert!(matches!(s.field(line, "z"), Some(RawVal::Null)));
        assert!(s.field(line, "nope").is_none());
    }

    #[test]
    fn scan_duplicate_fields_are_last_wins() {
        let line = r#"{"op":"a","op":"b"}"#;
        let mut s = LineScratch::default();
        s.scan(line).unwrap();
        let Some(RawVal::Str(op)) = s.field(line, "op") else { panic!() };
        assert_eq!(s.str_of(line, op), "b");
        // profile duplicate keys: last value survives sort+dedup
        let line = r#"{"p":{"A":1,"A":2,"B":3}}"#;
        s.scan(line).unwrap();
        let Some(RawVal::Obj { start, len }) = s.field(line, "p") else { panic!() };
        let n = s.sort_dedup_pairs(line, start, len);
        let pairs = s.pairs(start, n);
        assert_eq!(n, 2);
        assert_eq!((s.str_of(line, pairs[0].key), pairs[0].val), ("A", 2.0));
        assert_eq!((s.str_of(line, pairs[1].key), pairs[1].val), ("B", 3.0));
    }

    #[test]
    fn scan_rejects_what_the_dom_rejects() {
        let mut s = LineScratch::default();
        for bad in ["{", "[1,]", "12 34", "\"unterminated", "{\"a\":}", "{\"a\"1}", "nul"] {
            let mine = s.scan(bad).unwrap_err().to_string();
            let dom = Json::parse(bad).unwrap_err().to_string();
            assert_eq!(mine, dom, "{bad}");
        }
        // deep nesting: streaming fails structurally instead of blowing
        // the stack (intentional hardening divergence from the DOM)
        let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        assert!(s.scan(&deep).unwrap_err().to_string().contains("nesting"));
    }

    #[test]
    fn scan_zero_allocation_shape_reuse() {
        // capacities persist across scans; second scan of the same shape
        // must not grow anything (observable via capacity snapshots)
        let line = r#"{"op":"predict","profile":{"Conv2D":1.0,"Re\tlu":2.0},"xs":[1,2,3]}"#;
        let mut s = LineScratch::default();
        s.scan(line).unwrap();
        let caps = (
            s.fields.capacity(),
            s.elems.capacity(),
            s.pairs.capacity(),
            s.unescape.capacity(),
        );
        for _ in 0..8 {
            s.scan(line).unwrap();
        }
        assert_eq!(
            caps,
            (
                s.fields.capacity(),
                s.elems.capacity(),
                s.pairs.capacity(),
                s.unescape.capacity()
            )
        );
    }
}
