//! Deterministic xorshift64* PRNG — no external crates, stable everywhere.

/// Small, fast, deterministic PRNG (xorshift64*). Not cryptographic; used
/// for simulator noise, bootstrap sampling, splits, and DNN init.
#[derive(Debug, Clone)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    /// Seeded constructor; seed 0 is remapped to a fixed odd constant.
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9e3779b97f4a7c15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545f4914f6cdd1d)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::new(9);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let v = crate::util::variance(&xs);
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.1, "var {v}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng64::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
