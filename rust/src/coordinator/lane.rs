//! Engine lane loops: the per-replica work loops behind
//! [`crate::coordinator::EnginePool`].
//!
//! * [`predict_lane`] — the dynamic-batching loop. Greedily drains the
//!   queue first (`try_recv`), answers cheap interpolation jobs
//!   immediately, and only arms the [`BATCH_WINDOW`] coalescing wait
//!   while a phase-1 predict group is actually pending — an empty queue
//!   or an immediate-only burst never pays the window as a latency tax
//!   (the seed slept out the full 2 ms on *every* wakeup).
//! * [`advisor_lane`] — plain FIFO over long-running `recommend`/`plan`
//!   sweeps, so they serialize behind each other instead of behind (or in
//!   front of) predict traffic.
//! * [`trainer_lane`] — plain FIFO over the registry's write side
//!   (`ingest` staging appends, `onboard` retraining, `reload`). Training
//!   a new device pair takes seconds; on its own lane that cost is
//!   invisible to predict and advisor traffic, and the single-threaded
//!   loop is what serializes every write to the staging area and the
//!   model directory.
//!
//! Jobs carry the [`ModelSnapshot`] they were admitted with: a batch
//! group only ever coalesces requests pinned to the **same** registry
//! epoch (the group key includes it), so a swap landing mid-queue cannot
//! mix two model generations inside one artifact execution, and pre-swap
//! requests are answered by pre-swap models.
//!
//! All loops flush every job they have accepted before exiting on
//! shutdown/disconnect — replies are never dropped on the floor. Every
//! dequeue goes through [`admit`]: jobs past their
//! `--default-deadline-ms` queue budget are shed there with the
//! structured `deadline_exceeded` error, and the `lane.execute`
//! failpoint hooks the same spot so chaos tests can poison execution.
//! The loops themselves run under the dispatcher's supervisor — a panic
//! respawns the replica (its in-flight replies answer `internal_error`
//! via the [`Reply`] drop guard) instead of killing the lane.

use crate::advisor::{self, CacheKey, Candidate, PlanChoice, PredictionCache};
use crate::coordinator::dispatch::{EngineStats, Job, Reply};
use crate::coordinator::protocol::{PredictRequest, Response};
use crate::coordinator::registry::{ModelRegistry, ModelSnapshot, OnboardOptions, RegistryError};
use crate::gpu::Instance;
use crate::obs::{Obs, Stage};
use crate::runtime::Runtime;
use crate::sim::multigpu::ScalingTable;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching window: how long a predict lane waits to coalesce more
/// requests after a phase-1 predict group opens.
pub const BATCH_WINDOW: Duration = Duration::from_millis(2);

/// State shared by every replica of one pool.
#[derive(Clone)]
pub struct LaneCtx {
    pub cache: Arc<PredictionCache>,
    pub scaling: Arc<ScalingTable>,
    pub stats: Arc<EngineStats>,
    /// The live model registry: snapshotted by the router per request,
    /// mutated only by the trainer lane.
    pub registry: Arc<ModelRegistry>,
    /// Hyper-parameters for `onboard` retraining on the trainer lane.
    pub onboard: OnboardOptions,
    /// The pool's latency observatory: lanes record queue-wait,
    /// batch-assembly, and execute stage histograms into it.
    pub obs: Arc<Obs>,
}

/// Saturating `Duration` → nanoseconds for histogram recording.
#[inline]
fn ns_of(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

/// Admit a freshly dequeued job into execution: stamp the queue-wait
/// histogram (submit → here) and the dequeue instant later stages
/// measure from, then enforce the request deadline — a job whose queue
/// wait already exceeded its `--default-deadline-ms` budget is answered
/// with the structured `deadline_exceeded` error here and never
/// executed. Shedding at dequeue keeps an overloaded queue from burning
/// engine time on replies nobody is waiting for. Returns `None` when the
/// job was shed (or consumed by the `lane.execute` chaos hook).
fn admit(ctx: &LaneCtx, mut job: Job) -> Option<Job> {
    let now = Instant::now();
    let mut expired = false;
    if let Some(meta) = job.meta_mut() {
        let wait = ns_of(now.duration_since(meta.submitted));
        meta.dequeued = Some(now);
        meta.record(&ctx.obs, Stage::QueueWait, wait);
        expired = meta.deadline.is_some_and(|d| now > d);
    }
    if expired {
        if let Some(reply) = take_reply(job) {
            reply.send(Response::err_kind(
                "deadline_exceeded",
                "queue wait exceeded the request deadline budget",
            ));
        }
        return None;
    }
    inject_execute_fault(job)
}

/// Pull the reply out of any job kind (`Shutdown` carries none).
fn take_reply(job: Job) -> Option<Reply> {
    match job {
        Job::Predict(_, _, reply)
        | Job::BatchSize { reply, .. }
        | Job::PixelSize { reply, .. }
        | Job::Recommend { reply, .. }
        | Job::Plan { reply, .. }
        | Job::Ingest { reply, .. }
        | Job::Onboard { reply, .. }
        | Job::Reload { reply, .. } => Some(reply),
        Job::Shutdown => None,
    }
}

/// Chaos hook on every lane's execution path: an armed `lane.execute`
/// failpoint either panics inside the hook — unwinding into
/// [`supervise`](crate::coordinator::dispatch), with every in-flight
/// [`Reply`] drop guard answering `internal_error` — or, for
/// `return-err`, consumes the job with a structured `internal_error`
/// reply. `Shutdown` is never faulted (a swallowed shutdown would hang
/// the pool's drop join), and a disarmed point costs one relaxed load.
fn inject_execute_fault(job: Job) -> Option<Job> {
    if matches!(job, Job::Shutdown) || crate::fp!("lane.execute").is_none() {
        return Some(job);
    }
    if let Some(reply) = take_reply(job) {
        reply.send(Response::err_kind(
            "internal_error",
            "injected lane.execute failure",
        ));
    }
    None
}

/// Predict groups coalesce per (registry epoch, anchor, target): one
/// artifact execution per group, and never across two model generations.
type PredictGroups = BTreeMap<
    (u64, Instance, Instance),
    (ModelSnapshot, Vec<(PredictRequest, Reply)>),
>;

fn absorb(job: Job, predicts: &mut PredictGroups, immediate: &mut Vec<Job>, shutdown: &mut bool) {
    match job {
        Job::Predict(req, snap, reply) => {
            predicts
                .entry((snap.epoch, req.anchor, req.target))
                .or_insert_with(|| (snap, Vec::new()))
                .1
                .push((req, reply));
        }
        Job::Shutdown => *shutdown = true,
        other => immediate.push(other),
    }
}

/// Dynamic-batching predict loop (phase-1 `predict` + the cheap
/// interpolation ops routed round-robin by the dispatcher).
pub fn predict_lane(rt: &Runtime, rx: &Receiver<Job>, ctx: &LaneCtx) {
    loop {
        // block for the first job
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return,
        };
        let mut predicts: PredictGroups = BTreeMap::new();
        let mut immediate = Vec::new();
        let mut shutdown = false;
        if let Some(first) = admit(ctx, first) {
            absorb(first, &mut predicts, &mut immediate, &mut shutdown);
        }
        // greedy drain: take everything already queued without sleeping
        loop {
            match rx.try_recv() {
                Ok(j) => {
                    if let Some(j) = admit(ctx, j) {
                        absorb(j, &mut predicts, &mut immediate, &mut shutdown)
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        // answer cheap jobs before any coalescing wait
        for job in immediate.drain(..) {
            run_immediate(job, rt, ctx);
        }
        // the window is only armed while a predict group is pending
        if !predicts.is_empty() && !shutdown {
            let deadline = std::time::Instant::now() + BATCH_WINDOW;
            while let Some(remaining) = deadline.checked_duration_since(std::time::Instant::now())
            {
                match rx.recv_timeout(remaining) {
                    Ok(j) => {
                        if let Some(j) = admit(ctx, j) {
                            absorb(j, &mut predicts, &mut immediate, &mut shutdown);
                        }
                        // shutdown is always the queue's last job — don't
                        // wait out the rest of the window behind it
                        if shutdown {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            }
            // cheap jobs that arrived during the window
            for job in immediate.drain(..) {
                run_immediate(job, rt, ctx);
            }
        }
        run_predict_groups(predicts, rt, ctx);
        if shutdown {
            return;
        }
    }
}

/// FIFO advisor loop: one long-running sweep at a time. Handles every job
/// kind defensively (the dispatcher only routes `recommend`/`plan` here).
pub fn advisor_lane(rt: &Runtime, rx: &Receiver<Job>, ctx: &LaneCtx) {
    for job in rx {
        let Some(job) = admit(ctx, job) else { continue };
        match job {
            Job::Shutdown => return,
            Job::Predict(req, snap, reply) => {
                let mut group: PredictGroups = BTreeMap::new();
                group
                    .entry((snap.epoch, req.anchor, req.target))
                    .or_insert_with(|| (snap, Vec::new()))
                    .1
                    .push((req, reply));
                run_predict_groups(group, rt, ctx);
            }
            other => run_immediate(other, rt, ctx),
        }
    }
}

/// FIFO trainer loop: the registry's single writer. `ingest` appends are
/// sub-millisecond; `onboard`/`reload` take as long as training/loading
/// takes — which is exactly why this loop gets its own replica. Handles
/// every job kind defensively (the dispatcher only routes
/// `ingest`/`onboard`/`reload` here).
pub fn trainer_lane(rt: &Runtime, rx: &Receiver<Job>, ctx: &LaneCtx) {
    let stats = &ctx.stats;
    for job in rx {
        let Some(job) = admit(ctx, job) else { continue };
        let t0 = Instant::now();
        match job {
            Job::Shutdown => return,
            Job::Ingest { req, reply } => {
                stats.requests.fetch_add(1, Ordering::Relaxed); // ordering: stats-only counter // ordering: stats-only counter
                let (anchor, target) = (req.anchor, req.target);
                let resp = match ctx.registry.staging().append(&req) {
                    Ok(staged) => Response::Ingested {
                        anchor,
                        target,
                        staged,
                    },
                    Err(e) => Response::Err(format!("{e:#}")),
                };
                finish_with_execute(ctx, reply, resp, t0);
            }
            Job::Onboard {
                pair,
                dry_run,
                reply,
            } => {
                stats.requests.fetch_add(1, Ordering::Relaxed); // ordering: stats-only counter // ordering: stats-only counter
                let resp = if dry_run {
                    // route-tier phase 1: run the full train+validate
                    // gate but never swap — the serving epoch is
                    // untouched whatever the outcome
                    match ctx.registry.check_onboard(rt, pair, &ctx.onboard) {
                        Ok((pairs, staged)) => Response::OnboardCheck { pairs, staged },
                        Err(e) => registry_error_response(e),
                    }
                } else {
                    match ctx.registry.onboard(rt, pair, &ctx.onboard) {
                        Ok(report) => Response::Onboarded {
                            epoch: report.epoch,
                            pairs: report.pairs.len(),
                            staged: report.staged,
                        },
                        Err(e) => registry_error_response(e),
                    }
                };
                finish_with_execute(ctx, reply, resp, t0);
            }
            Job::Reload {
                only_if_changed,
                dry_run,
                reply,
            } => {
                stats.requests.fetch_add(1, Ordering::Relaxed); // ordering: stats-only counter // ordering: stats-only counter
                let resp = if dry_run {
                    // route-tier phase 1: validate what is on disk
                    // without swapping it in
                    match ctx.registry.check_reload(rt) {
                        Ok(()) => Response::ReloadCheck {
                            epoch: ctx.registry.epoch(),
                        },
                        Err(e) => registry_error_response(e),
                    }
                } else {
                    match ctx.registry.reload(rt, only_if_changed) {
                        Ok(Some(epoch)) => Response::Reloaded { epoch },
                        // watcher mode, nothing changed: report the epoch that
                        // is (still) current
                        Ok(None) => Response::Reloaded {
                            epoch: ctx.registry.epoch(),
                        },
                        Err(e) => registry_error_response(e),
                    }
                };
                finish_with_execute(ctx, reply, resp, t0);
            }
            Job::Predict(req, snap, reply) => {
                let mut group: PredictGroups = BTreeMap::new();
                group
                    .entry((snap.epoch, req.anchor, req.target))
                    .or_insert_with(|| (snap, Vec::new()))
                    .1
                    .push((req, reply));
                run_predict_groups(group, rt, ctx);
            }
            other => run_immediate(other, rt, ctx),
        }
    }
}

/// Map a refused registry mutation to its structured wire error. The
/// previous epoch is still serving in every branch — these are
/// "nothing changed" errors, never partial states.
fn registry_error_response(e: RegistryError) -> Response {
    match e {
        RegistryError::NoStagedData => Response::err_kind(
            "no_staged_data",
            "no staged measurements for the requested pair(s) — send `ingest` lines first",
        ),
        RegistryError::Rejected(err) => Response::err_kind(
            "validation_failed",
            format!("candidate rejected, previous epoch still serving: {err:#}"),
        ),
        RegistryError::Other(err) => Response::Err(format!("{err:#}")),
    }
}

/// Record the handler duration as the job's `execute` stage, then
/// deliver the response.
fn finish_with_execute(ctx: &LaneCtx, mut reply: Reply, resp: Response, t0: Instant) {
    reply.meta_mut().record(&ctx.obs, Stage::Execute, ns_of(t0.elapsed()));
    reply.send(resp);
}

/// One non-phase-1-batched job (interpolation or advisor sweep).
fn run_immediate(job: Job, rt: &Runtime, ctx: &LaneCtx) {
    let stats = &ctx.stats;
    let t0 = Instant::now();
    match job {
        Job::BatchSize {
            instance,
            batch,
            t_min,
            t_max,
            snap,
            reply,
        } => {
            stats.requests.fetch_add(1, Ordering::Relaxed); // ordering: stats-only counter
            let resp = match snap.profet.predict_batch_size(instance, batch, t_min, t_max) {
                Ok(v) => Response::Latency { latency_ms: v },
                Err(e) => Response::Err(format!("{e:#}")),
            };
            finish_with_execute(ctx, reply, resp, t0);
        }
        Job::PixelSize {
            instance,
            pixels,
            t_min,
            t_max,
            snap,
            reply,
        } => {
            stats.requests.fetch_add(1, Ordering::Relaxed); // ordering: stats-only counter
            let resp = match snap.profet.predict_pixel_size(instance, pixels, t_min, t_max) {
                Ok(v) => Response::Latency { latency_ms: v },
                Err(e) => Response::Err(format!("{e:#}")),
            };
            finish_with_execute(ctx, reply, resp, t0);
        }
        Job::Recommend {
            query,
            top_k,
            snap,
            reply,
        } => {
            stats.requests.fetch_add(1, Ordering::Relaxed); // ordering: stats-only counter
            let resp = match advisor::sweep(
                rt,
                snap.epoch,
                &snap.profet,
                &ctx.cache,
                &stats.cache,
                &ctx.scaling,
                &query,
            ) {
                Ok(cands) if cands.is_empty() => Response::err_kind(
                    "no_candidates",
                    "no feasible (target, batch, pixels, gpus) candidate",
                ),
                Ok(cands) => recommend_response(&cands, top_k),
                Err(e) => Response::Err(format!("{e:#}")),
            };
            finish_with_execute(ctx, reply, resp, t0);
        }
        Job::Plan {
            query,
            job,
            objective,
            snap,
            reply,
        } => {
            stats.requests.fetch_add(1, Ordering::Relaxed); // ordering: stats-only counter
            let resp = match advisor::sweep(
                rt,
                snap.epoch,
                &snap.profet,
                &ctx.cache,
                &stats.cache,
                &ctx.scaling,
                &query,
            ) {
                Ok(cands) if cands.is_empty() => Response::err_kind(
                    "no_candidates",
                    "no feasible (target, batch, pixels, gpus) candidate",
                ),
                Ok(cands) => match advisor::plan(&cands, &job, &objective) {
                    Some(choice) => plan_response(&cands, &choice),
                    None => Response::err_kind(
                        "infeasible",
                        "no candidate satisfies the constraint",
                    ),
                },
                Err(e) => Response::Err(format!("{e:#}")),
            };
            finish_with_execute(ctx, reply, resp, t0);
        }
        // registry jobs are routed to the trainer lane; a defensive
        // arrival here (only possible through test harnesses) answers
        // with an error instead of silently dropping the reply
        Job::Ingest { reply, .. } | Job::Onboard { reply, .. } | Job::Reload { reply, .. } => {
            reply.send(Response::Err("registry op routed off the trainer lane".into()));
        }
        Job::Predict(..) | Job::Shutdown => {}
    }
}

/// Batched phase-1 predictions: cache-first, then one artifact execution
/// per (epoch, anchor, target) group over the *unique* misses.
fn run_predict_groups(predicts: PredictGroups, rt: &Runtime, ctx: &LaneCtx) {
    let stats = &ctx.stats;
    let cache = &ctx.cache;
    for ((epoch, anchor, target), (snap, mut group)) in predicts {
        stats.requests.fetch_add(group.len() as u64, Ordering::Relaxed); // ordering: stats-only counter
        // batch assembly: lane dequeue → coalesced execution start, per
        // member (early arrivals paid more of the window than late ones)
        let exec_start = Instant::now();
        for (_, reply) in group.iter_mut() {
            let meta = reply.meta_mut();
            if let Some(dq) = meta.dequeued {
                let ns = ns_of(exec_start.duration_since(dq));
                meta.record(&ctx.obs, Stage::BatchAssembly, ns);
            }
        }
        let profet = &snap.profet;
        let Some(model) = profet.cross.get(&(anchor, target)) else {
            for (_, mut reply) in group {
                reply
                    .meta_mut()
                    .record(&ctx.obs, Stage::Execute, ns_of(exec_start.elapsed()));
                reply.send(Response::Err(format!("no model for {anchor}->{target}")));
            }
            continue;
        };
        let mut results: Vec<Option<(f64, crate::predictor::Member)>> = vec![None; group.len()];
        // unique missing keys, in first-seen order; waiters per key
        let mut miss_keys: Vec<CacheKey> = Vec::new();
        let mut miss_rows: Vec<Vec<f64>> = Vec::new();
        let mut miss_lats: Vec<f64> = Vec::new();
        let mut waiters: BTreeMap<CacheKey, Vec<usize>> = BTreeMap::new();
        for (i, (req, _)) in group.iter().enumerate() {
            let key = CacheKey::of(epoch, anchor, target, req.anchor_latency_ms, &req.profile);
            if let Some(v) = cache.get(&key, &stats.cache) {
                results[i] = Some(v);
                continue;
            }
            if !waiters.contains_key(&key) {
                miss_keys.push(key.clone());
                miss_rows.push(profet.feature_space.vectorize(&req.profile));
                miss_lats.push(req.anchor_latency_ms);
            }
            waiters.entry(key).or_default().push(i);
        }
        if !miss_rows.is_empty() {
            let executed = crate::ml::FeatureMatrix::from_rows(&miss_rows)
                .and_then(|feats| model.predict_batch(rt, &feats, &miss_lats));
            match executed {
                Ok(preds) => {
                    // ordering: batch tallies are stats-only counters read
                    // by the metrics snapshot; they order nothing.
                    stats.batches.fetch_add(1, Ordering::Relaxed);
                    stats
                        .batched_requests
                        .fetch_add(miss_keys.len() as u64, Ordering::Relaxed);
                    for (key, pred) in miss_keys.into_iter().zip(preds) {
                        for &i in &waiters[&key] {
                            results[i] = Some(pred);
                        }
                        cache.insert(key, pred);
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    let exec_ns = ns_of(exec_start.elapsed());
                    for (i, (_, mut reply)) in group.into_iter().enumerate() {
                        let resp = match results[i] {
                            Some((v, member)) => ok_prediction(v, member),
                            None => Response::Err(msg.clone()),
                        };
                        reply.meta_mut().record(&ctx.obs, Stage::Execute, exec_ns);
                        reply.send(resp);
                    }
                    continue;
                }
            }
        }
        // the group's execution cost, attributed to every member (they
        // shared one coalesced artifact execution — see OBSERVABILITY.md)
        let exec_ns = ns_of(exec_start.elapsed());
        for (i, (_, mut reply)) in group.into_iter().enumerate() {
            let resp = match results[i] {
                Some((v, member)) => ok_prediction(v, member),
                None => Response::Err("prediction missing from batch".into()),
            };
            reply.meta_mut().record(&ctx.obs, Stage::Execute, exec_ns);
            reply.send(resp);
        }
    }
}

fn ok_prediction(latency_ms: f64, member: crate::predictor::Member) -> Response {
    Response::Prediction { latency_ms, member }
}

/// Rank candidates (cost-efficiency first, then speed, then a stable tie
/// key), tag Pareto-frontier membership — computed over the FULL candidate
/// set, before any `top_k` truncation — and build the typed reply (the
/// connection handler encodes it straight to its output buffer).
/// `top_k == 0` is the documented "return everything" sentinel (see the
/// protocol op table).
fn recommend_response(cands: &[Candidate], top_k: usize) -> Response {
    let points: Vec<(f64, f64)> = cands.iter().map(Candidate::objectives).collect();
    let frontier: std::collections::BTreeSet<usize> =
        advisor::pareto_frontier(&points).into_iter().collect();
    let order = advisor::rank_candidates(cands);
    let take = if top_k == 0 { order.len() } else { top_k.min(order.len()) };
    Response::Recommend {
        candidates: order[..take]
            .iter()
            .map(|&i| (cands[i], frontier.contains(&i)))
            .collect(),
        n_candidates: cands.len(),
        frontier_size: frontier.len(),
    }
}

fn plan_response(cands: &[Candidate], choice: &PlanChoice) -> Response {
    // one membership bit only — a direct dominance scan, not a full frontier
    let pt = cands[choice.index].objectives();
    let on_frontier = cands
        .iter()
        .all(|q| !advisor::dominates(q.objectives(), pt));
    Response::Plan {
        choice: (cands[choice.index], on_frontier),
        hours: choice.hours,
        cost_usd: choice.cost_usd,
        epochs: choice.epochs,
        n_considered: cands.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost_model::Pricing;

    fn cand(batch: usize, latency_ms: f64, price_hr: f64) -> Candidate {
        let imgs_per_s = batch as f64 * 1e3 / latency_ms;
        Candidate {
            target: Instance::P3,
            batch,
            pixels: 64,
            n_gpus: 1,
            pricing: Pricing::OnDemand,
            latency_ms,
            imgs_per_s,
            price_hr,
            cost_per_img_usd: price_hr / 3600.0 / imgs_per_s,
        }
    }

    /// `top_k == 0` means "return everything" (documented sentinel);
    /// nonzero truncates after ranking but frontier/count fields still
    /// describe the full candidate set.
    #[test]
    fn recommend_top_k_zero_returns_all_candidates() {
        let cands = vec![
            cand(16, 100.0, 3.0),
            cand(64, 250.0, 3.0),
            cand(256, 700.0, 3.0),
        ];
        let all = recommend_response(&cands, 0);
        let Response::Recommend { candidates, n_candidates, .. } = all else {
            panic!("err response")
        };
        assert_eq!(candidates.len(), 3);
        assert_eq!(n_candidates, 3);

        let top2 = recommend_response(&cands, 2);
        let Response::Recommend { candidates, n_candidates, .. } = top2 else {
            panic!("err response")
        };
        assert_eq!(candidates.len(), 2);
        // truncation must not shrink the full-set metadata
        assert_eq!(n_candidates, 3);

        // top_k beyond the candidate count clamps instead of panicking
        let top9 = recommend_response(&cands, 9);
        let Response::Recommend { candidates, .. } = top9 else {
            panic!("err response")
        };
        assert_eq!(candidates.len(), 3);
    }

    /// Predict jobs from different registry epochs never share a batch
    /// group — the group key carries the epoch.
    #[test]
    fn absorb_groups_by_epoch_and_pair() {
        use crate::coordinator::registry::empty_profet;
        use std::collections::BTreeMap as Map;
        use std::sync::mpsc::channel;
        let req = |lat: f64| PredictRequest {
            anchor: Instance::G4dn,
            target: Instance::P3,
            anchor_latency_ms: lat,
            profile: Map::from([("Conv2D".to_string(), 1.0)]),
        };
        let snap_at = |epoch| ModelSnapshot {
            epoch,
            profet: Arc::new(empty_profet()),
        };
        let mut groups: PredictGroups = BTreeMap::new();
        let mut immediate = Vec::new();
        let mut shutdown = false;
        for (epoch, lat) in [(1u64, 1.0), (1, 2.0), (2, 3.0), (1, 4.0)] {
            let (tx, _rx) = channel();
            absorb(
                Job::Predict(req(lat), snap_at(epoch), Reply::channel(tx)),
                &mut groups,
                &mut immediate,
                &mut shutdown,
            );
        }
        assert_eq!(groups.len(), 2, "one group per (epoch, pair)");
        assert_eq!(groups[&(1, Instance::G4dn, Instance::P3)].1.len(), 3);
        assert_eq!(groups[&(2, Instance::G4dn, Instance::P3)].1.len(), 1);
        assert!(immediate.is_empty());
        assert!(!shutdown);
    }

    /// A job whose deadline already passed is shed at dequeue with the
    /// structured `deadline_exceeded` error, never executed; one with
    /// headroom passes through untouched.
    #[test]
    fn admit_sheds_expired_jobs_with_deadline_exceeded() {
        use crate::coordinator::registry::test_registry;
        use std::sync::mpsc::channel;
        let ctx = LaneCtx {
            cache: Arc::new(PredictionCache::new(4, 64)),
            scaling: Arc::new(ScalingTable::new()),
            stats: Arc::new(EngineStats::default()),
            registry: Arc::new(test_registry("deadline")),
            onboard: OnboardOptions::default(),
            obs: Arc::new(Obs::new(250.0, 1)),
        };
        let (tx, rx) = channel();
        let mut reply = Reply::channel(tx);
        reply.meta_mut().deadline = Some(Instant::now() - Duration::from_millis(5));
        let job = Job::Reload { only_if_changed: false, dry_run: false, reply };
        assert!(admit(&ctx, job).is_none(), "expired job must be shed");
        match rx.try_recv().unwrap() {
            Response::ErrKind { kind, .. } => assert_eq!(kind, "deadline_exceeded"),
            other => panic!("expected deadline_exceeded, got {other:?}"),
        }
        // headroom: admitted, and no reply is sent at admission
        let (tx, rx) = channel();
        let mut reply = Reply::channel(tx);
        reply.meta_mut().deadline = Some(Instant::now() + Duration::from_secs(60));
        let job = Job::Reload { only_if_changed: false, dry_run: false, reply };
        assert!(admit(&ctx, job).is_some());
        assert!(rx.try_recv().is_err(), "no reply may be sent at admission");
    }
}
