//! Readiness-polled connection reactor: a few threads own *all* sockets.
//!
//! The previous tier spent one blocking thread per connection, so 10k
//! idle keep-alive clients cost 10k parked threads. Here each reactor
//! thread runs one [`Poller`] (epoll on Linux — see [`crate::util::poll`])
//! over its share of the accepted sockets and a self-pipe [`Waker`]; an
//! idle connection costs a file descriptor and a ~100-byte table entry,
//! nothing else.
//!
//! Per connection the reactor keeps the PR 4 wire buffers
//! ([`ConnScratch`]) plus an inbound byte buffer with the same
//! line-framing semantics the old bounded reader had: lines are
//! newline-delimited with `\r` stripped, a line past [`MAX_LINE_BYTES`]
//! is discarded as it streams in and answered with a structured
//! `line_too_long` error (the connection then keeps serving), and a
//! final unterminated line at EOF is served like any other.
//!
//! The request path is two-tier, exactly as before:
//!
//! * **warm/inline** — parse → cache-key → peek → encode happens right
//!   on the reactor thread through
//!   [`crate::coordinator::router::respond_or_submit`]; a steady-state
//!   cache-hit `predict` stays zero-allocation (`tests/wire_alloc.rs`).
//! * **cold** — the job goes to its [`EnginePool`] lane carrying a
//!   [`Reply`] that points back at this reactor's [`CompletionQueue`];
//!   the lane's `send` enqueues the response and wakes the reactor,
//!   which encodes and flushes it on writable readiness. While a job is
//!   in flight the connection's read interest is dropped (one in-flight
//!   request per connection), which preserves the protocol's "requests
//!   on one connection are answered in order" guarantee and turns TCP
//!   receive-buffer backpressure on pipelining clients.
//!
//! Misbehaving peers are bounded three ways: the line cap above, an
//! optional **idle timeout** (a slow-loris dribbling bytes never
//! completes a line, so it is evicted like any idle connection), and a
//! **write-stall timeout** (a peer that stops reading its replies is
//! closed once its backlog makes no progress for
//! [`crate::coordinator::ServeOptions::write_stall_timeout`]).
//!
//! **Graceful drain**: [`ReactorPool::drain`] half-closes every read
//! side, serves whatever complete lines were already buffered, waits for
//! every in-flight engine reply to flush, then closes. An accepted
//! request never loses its response; the only bound is the write-stall
//! timeout for peers that stopped reading.

use crate::coordinator::dispatch::{EnginePool, EngineStats, Reply, ReqMeta};
use crate::coordinator::protocol::Response;
use crate::coordinator::router::{self, ConnScratch, RouteOutcome};
use crate::coordinator::server::MAX_LINE_BYTES;
use crate::obs::{OpClass, Stage, Temp, TraceEntry};
use crate::util::failpoint::Hit;
use crate::util::poll::{Event, Interest, Poller, Waker};
use anyhow::Result;
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Poller token reserved for the reactor's own waker pipe.
const WAKE_TOKEN: u64 = u64::MAX;

/// One read syscall's worth of inbound bytes (reused per reactor).
const READ_CHUNK: usize = 16 * 1024;

/// Per-event read budget: how many chunks one socket may consume before
/// the reactor moves on (level-triggered readiness re-fires if more
/// bytes remain, so fairness costs nothing).
const READ_BUDGET: usize = 8;

/// How often the timer sweep (idle eviction, write-stall) runs at most.
const SWEEP_GRANULARITY: Duration = Duration::from_millis(100);

/// Completion hand-back: engine lanes push `(connection, response,
/// request metadata)` here and wake the owning reactor, which flushes
/// the response through the connection's writable-readiness path. One
/// queue per reactor thread. The [`ReqMeta`] rides along so delivery
/// can record the completion-queue wait and finalize the request's
/// trace.
pub struct CompletionQueue {
    items: Mutex<Vec<(u64, Response, ReqMeta)>>,
    waker: Arc<Waker>,
}

impl CompletionQueue {
    fn new(waker: Arc<Waker>) -> CompletionQueue {
        CompletionQueue { items: Mutex::new(Vec::new()), waker } // lint: allow(hot-path-alloc): empty-Vec construction at startup allocates nothing
    }

    /// Engine-lane side (via [`Reply::send`]): enqueue and wake.
    pub(crate) fn push(&self, conn: u64, resp: Response, meta: ReqMeta) {
        // lint: allow(reactor-blocking-call): runs on an engine lane, not the reactor; push-only critical section
        self.items.lock().unwrap().push((conn, resp, meta));
        self.waker.wake();
    }

    fn drain_into(&self, out: &mut Vec<(u64, Response, ReqMeta)>) {
        // lint: allow(reactor-blocking-call): bounded swap-drain — the only reactor-side lock, held for one append
        out.append(&mut self.items.lock().unwrap());
    }
}

/// Saturating `Duration` → nanoseconds for histogram recording.
#[inline]
fn ns_of(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

/// Reactor sizing/eviction knobs (resolved from
/// [`crate::coordinator::ServeOptions`]).
#[derive(Debug, Clone)]
pub(crate) struct ReactorConfig {
    pub threads: usize,
    /// Evict a connection with no complete request line for this long.
    /// `None` disables eviction (idle keep-alives live forever).
    pub idle_timeout: Option<Duration>,
    /// Close a connection whose reply backlog makes no write progress
    /// for this long (peer stopped reading).
    pub write_stall_timeout: Duration,
}

/// Handoff mailbox from the acceptor (and the drain signal).
#[derive(Default)]
struct Inbox {
    conns: Vec<TcpStream>,
    drain: bool,
}

struct Reactor {
    waker: Arc<Waker>,
    inbox: Arc<Mutex<Inbox>>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// The set of reactor threads behind one server.
pub(crate) struct ReactorPool {
    reactors: Vec<Reactor>,
    next: AtomicUsize,
}

impl ReactorPool {
    pub(crate) fn spawn(pool: Arc<EnginePool>, cfg: &ReactorConfig) -> Result<ReactorPool> {
        let threads = cfg.threads.max(1);
        // ordering: one-time gauge write at startup, read only by stats
        pool.stats
            .conns
            .reactor_threads
            .store(threads as u64, Ordering::Relaxed);
        // lint: allow(hot-path-alloc) begin: one-time pool construction at server startup
        let mut reactors = Vec::with_capacity(threads);
        for i in 0..threads {
            let waker = Arc::new(Waker::new()?);
            let inbox = Arc::new(Mutex::new(Inbox::default()));
            let ctx = ReactorCtx {
                pool: pool.clone(),
                stats: pool.stats.clone(),
                queue: Arc::new(CompletionQueue::new(waker.clone())),
                waker: waker.clone(),
                inbox: inbox.clone(),
                cfg: cfg.clone(),
            };
            let join = std::thread::Builder::new()
                .name(format!("profet-reactor-{i}"))
                .spawn(move || reactor_loop(ctx))?;
            reactors.push(Reactor {
                waker,
                inbox,
                join: Mutex::new(Some(join)),
            });
        }
        // lint: allow(hot-path-alloc) end
        Ok(ReactorPool { reactors, next: AtomicUsize::new(0) })
    }

    /// Hand an accepted connection to the next reactor (round-robin).
    /// The acceptor has already counted it against `stats.conns.open`.
    pub(crate) fn adopt(&self, stream: TcpStream) {
        // ordering: round-robin cursor — occasional duplicate indices
        // under contention only skew balance, never correctness
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.reactors.len();
        let r = &self.reactors[i];
        // lint: allow(reactor-blocking-call): runs on the acceptor thread; reactor holds this lock only for a bounded drain
        r.inbox.lock().unwrap().conns.push(stream);
        r.waker.wake();
    }

    /// Graceful drain: signal every reactor and join it. Returns once
    /// every in-flight response has been flushed (or its peer stalled
    /// out) and every connection is closed. Idempotent — a second call
    /// finds the joins already taken.
    pub(crate) fn drain(&self) {
        // lint: allow(reactor-blocking-call) begin: shutdown path runs on the caller's thread, not a reactor
        for r in &self.reactors {
            r.inbox.lock().unwrap().drain = true;
            r.waker.wake();
        }
        for r in &self.reactors {
            let handle = r.join.lock().unwrap().take();
            if let Some(j) = handle {
                let _ = j.join();
            }
        }
        // lint: allow(reactor-blocking-call) end
    }
}

impl Drop for ReactorPool {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Everything one reactor thread shares with the outside.
struct ReactorCtx {
    pool: Arc<EnginePool>,
    stats: Arc<EngineStats>,
    queue: Arc<CompletionQueue>,
    waker: Arc<Waker>,
    inbox: Arc<Mutex<Inbox>>,
    cfg: ReactorConfig,
}

/// Per-connection reactor state. Steady-state warm traffic touches only
/// `stream`, `inbuf`, and `scratch` — all reused, zero allocations.
struct Conn {
    stream: TcpStream,
    scratch: ConnScratch,
    /// Unparsed inbound bytes (complete and partial lines).
    inbuf: Vec<u8>,
    /// Prefix of `inbuf` already scanned without finding a newline, so a
    /// slowly growing partial line is never rescanned from the start.
    scanned: usize,
    /// An oversized line is being discarded up to its newline.
    discarding: bool,
    /// Reply bytes the socket wouldn't take yet (backpressure spill).
    outbuf: Vec<u8>,
    outpos: usize,
    /// An engine job is in flight — reads pause until its reply lands.
    awaiting: bool,
    /// Peer finished sending (EOF read, hangup, or drain half-close).
    eof: bool,
    /// Fd was deregistered after a hangup while awaiting an engine
    /// reply (a level-triggered HUP would otherwise spin the poller).
    detached: bool,
    interest: Interest,
    /// Last complete request line / delivered reply (idle eviction).
    last_activity: Instant,
    /// Last write progress while a backlog exists (stall eviction).
    last_write: Instant,
}

impl Conn {
    fn has_backlog(&self) -> bool {
        self.outpos < self.outbuf.len()
    }

    /// Nothing left to read, work on, or flush — close cleanly.
    fn done(&self) -> bool {
        self.eof && !self.awaiting && !self.has_backlog() && self.inbuf.is_empty()
    }
}

fn reactor_loop(ctx: ReactorCtx) {
    let poller = match Poller::new() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("reactor: poller init failed: {e}");
            return;
        }
    };
    if let Err(e) = poller.add(ctx.waker.fd(), WAKE_TOKEN, Interest::READ) {
        eprintln!("reactor: waker registration failed: {e}");
        return;
    }
    // lint: allow(hot-path-alloc) begin: loop-lifetime buffers allocated once per reactor and reused every iteration
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut events: Vec<Event> = Vec::new();
    let mut completions: Vec<(u64, Response, ReqMeta)> = Vec::new();
    let mut dead: Vec<u64> = Vec::new();
    let mut rdbuf = vec![0u8; READ_CHUNK];
    // lint: allow(hot-path-alloc) end
    let mut draining = false;
    let mut drain_deadline: Option<Instant> = None;
    let mut last_sweep = Instant::now();

    loop {
        // 1) adopt handed-over connections / notice the drain signal
        {
            // lint: allow(reactor-blocking-call): adoption mailbox — acceptor holds it only to push one stream
            let mut inbox = ctx.inbox.lock().unwrap();
            if inbox.drain {
                draining = true;
            }
            for stream in inbox.conns.drain(..) {
                if draining {
                    // raced the drain: never served, close unannounced
                    // ordering: gauge decrement; monotonic counter, no ordering dependency
                    ctx.stats.conns.open.fetch_sub(1, Ordering::Relaxed);
                    continue;
                }
                register(&poller, &mut conns, &mut next_id, stream, &ctx);
            }
        }

        // 2) drain transition: half-close every read side; buffered
        //    complete lines (and the final partial one) still get served,
        //    mirroring what the old per-connection reader saw at EOF
        if draining && drain_deadline.is_none() {
            drain_deadline =
                Some(Instant::now() + ctx.cfg.write_stall_timeout + Duration::from_secs(60));
            for (&id, conn) in conns.iter_mut() {
                let _ = conn.stream.shutdown(Shutdown::Read);
                conn.eof = true;
                if !conn.awaiting && !(process(&ctx, id, conn) && sync_interest(&poller, id, conn))
                {
                    dead.push(id);
                }
            }
            close_dead(&poller, &mut conns, &mut dead, &ctx);
        }

        // 3) engine completions → encode, flush, resume buffered lines
        ctx.queue.drain_into(&mut completions);
        for (id, resp, meta) in completions.drain(..) {
            let Some(conn) = conns.get_mut(&id) else {
                continue; // connection died while its job was in flight
            };
            if !(deliver(&ctx, id, conn, resp, meta) && sync_interest(&poller, id, conn)) {
                dead.push(id);
            }
        }
        close_dead(&poller, &mut conns, &mut dead, &ctx);

        // 4) drain exit: everything flushed (or the hard deadline hit)
        if draining {
            for (&id, conn) in conns.iter() {
                if conn.done() || (!conn.awaiting && !conn.has_backlog()) {
                    dead.push(id);
                }
            }
            close_dead(&poller, &mut conns, &mut dead, &ctx);
            let expired = drain_deadline.is_some_and(|d| Instant::now() >= d);
            if conns.is_empty() || expired {
                for (_, conn) in conns.drain() {
                    if conn.awaiting {
                        // ordering: gauge decrements at shutdown; stats-only
                        ctx.stats.conns.active.fetch_sub(1, Ordering::Relaxed);
                    }
                    // ordering: gauge decrement at shutdown; stats-only
                    ctx.stats.conns.open.fetch_sub(1, Ordering::Relaxed);
                }
                return;
            }
        }

        // 5) wait: block forever when nothing is timed, otherwise tick
        //    often enough for eviction/stall sweeps (and drain progress)
        let any_backlog = conns.values().any(Conn::has_backlog);
        let timeout = if draining {
            Some(Duration::from_millis(100))
        } else {
            match (ctx.cfg.idle_timeout, any_backlog) {
                (Some(idle), _) => Some(
                    (idle / 2).clamp(Duration::from_millis(10), Duration::from_millis(250)),
                ),
                (None, true) => Some(Duration::from_millis(500)),
                (None, false) => None,
            }
        };
        // lint: allow(reactor-blocking-call): the event loop's designed wait — epoll/poll readiness, not a stall
        if let Err(e) = poller.wait(&mut events, timeout) {
            eprintln!("reactor: poll failed: {e}");
            for (_, conn) in conns.drain() {
                if conn.awaiting {
                    // ordering: gauge decrements on teardown; stats-only
                    ctx.stats.conns.active.fetch_sub(1, Ordering::Relaxed);
                }
                // ordering: gauge decrement on teardown; stats-only
                ctx.stats.conns.open.fetch_sub(1, Ordering::Relaxed);
            }
            return;
        }

        // 6) readiness events
        for ev in &events {
            if ev.token == WAKE_TOKEN {
                ctx.waker.drain();
                continue;
            }
            let Some(conn) = conns.get_mut(&ev.token) else {
                continue; // closed earlier in this batch
            };
            if ev.hangup && conn.awaiting {
                // peer is fully gone but an engine reply is pending:
                // deregister (a level-triggered HUP with no interest
                // bits would spin the loop) and let the completion
                // attempt its write and close
                if !conn.detached {
                    let _ = poller.del(conn.stream.as_raw_fd());
                    conn.detached = true;
                    conn.eof = true;
                }
                continue;
            }
            let mut alive = true;
            if ev.writable && conn.has_backlog() {
                let t0 = Instant::now();
                alive = flush_backlog(conn);
                ctx.pool.obs().record_ns(
                    Stage::WriteFlush,
                    OpClass::Other,
                    Temp::Cold,
                    ns_of(t0.elapsed()),
                );
            }
            if alive && (ev.readable || ev.hangup) && !conn.eof && !conn.awaiting {
                alive = fill(conn, &mut rdbuf) && process(&ctx, ev.token, conn);
            }
            if !(alive && sync_interest(&poller, ev.token, conn)) || conn.done() {
                dead.push(ev.token);
            }
        }
        close_dead(&poller, &mut conns, &mut dead, &ctx);

        // 7) timer sweep: write-stall and idle eviction
        let now = Instant::now();
        if now.duration_since(last_sweep) >= SWEEP_GRANULARITY {
            last_sweep = now;
            for (&id, conn) in conns.iter() {
                if conn.has_backlog()
                    && now.duration_since(conn.last_write) > ctx.cfg.write_stall_timeout
                {
                    dead.push(id); // peer stopped reading its replies
                } else if let Some(idle) = ctx.cfg.idle_timeout {
                    if !draining
                        && !conn.awaiting
                        && !conn.has_backlog()
                        && now.duration_since(conn.last_activity) > idle
                    {
                        // ordering: eviction counter; stats-only
                        ctx.stats.conns.evicted.fetch_add(1, Ordering::Relaxed);
                        dead.push(id);
                    }
                }
            }
            close_dead(&poller, &mut conns, &mut dead, &ctx);
        }
    }
}

fn register(
    poller: &Poller,
    conns: &mut HashMap<u64, Conn>,
    next_id: &mut u64,
    stream: TcpStream,
    ctx: &ReactorCtx,
) {
    if stream.set_nonblocking(true).is_err() {
        // ordering: gauge decrement on a failed adopt; stats-only
        ctx.stats.conns.open.fetch_sub(1, Ordering::Relaxed);
        return;
    }
    stream.set_nodelay(true).ok();
    let id = *next_id;
    *next_id += 1;
    if poller.add(stream.as_raw_fd(), id, Interest::READ).is_err() {
        // ordering: gauge decrement on a failed adopt; stats-only
        ctx.stats.conns.open.fetch_sub(1, Ordering::Relaxed);
        return;
    }
    let now = Instant::now();
    conns.insert(
        id,
        Conn {
            stream,
            scratch: ConnScratch::default(),
            inbuf: Vec::new(), // lint: allow(hot-path-alloc): empty-Vec construction allocates nothing; grows lazily per connection
            scanned: 0,
            discarding: false,
            outbuf: Vec::new(), // lint: allow(hot-path-alloc): empty-Vec construction allocates nothing; grows lazily per connection
            outpos: 0,
            awaiting: false,
            eof: false,
            detached: false,
            interest: Interest::READ,
            last_activity: now,
            last_write: now,
        },
    );
}

fn close_dead(
    poller: &Poller,
    conns: &mut HashMap<u64, Conn>,
    dead: &mut Vec<u64>,
    ctx: &ReactorCtx,
) {
    for id in dead.drain(..) {
        if let Some(conn) = conns.remove(&id) {
            if !conn.detached {
                let _ = poller.del(conn.stream.as_raw_fd());
            }
            if conn.awaiting {
                // ordering: gauge decrement on close; stats-only
                ctx.stats.conns.active.fetch_sub(1, Ordering::Relaxed);
            }
            // ordering: gauge decrement on close; stats-only
            ctx.stats.conns.open.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Keep the kernel's interest mask in sync with the connection state:
/// read while a request may arrive, write while a backlog exists.
fn sync_interest(poller: &Poller, id: u64, conn: &mut Conn) -> bool {
    if conn.detached {
        return true;
    }
    let want = Interest {
        readable: !conn.eof && !conn.awaiting,
        writable: conn.has_backlog(),
    };
    if want != conn.interest {
        if poller.modify(conn.stream.as_raw_fd(), id, want).is_err() {
            return false;
        }
        conn.interest = want;
    }
    true
}

/// Read until `WouldBlock`, EOF, or the fairness budget. Returns `false`
/// on a hard read error (connection is dropped).
fn fill(conn: &mut Conn, rdbuf: &mut [u8]) -> bool {
    for _ in 0..READ_BUDGET {
        match conn.stream.read(rdbuf) {
            Ok(0) => {
                conn.eof = true;
                return true;
            }
            Ok(n) => {
                conn.inbuf.extend_from_slice(&rdbuf[..n]);
                if n < rdbuf.len() {
                    return true;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}

fn find_newline(buf: &[u8], from: usize) -> Option<usize> {
    buf[from..].iter().position(|&b| b == b'\n').map(|p| from + p)
}

/// Parse and serve every actionable buffered line. Stops at a partial
/// line, or as soon as a request is handed to an engine lane (in-order
/// replies: one in-flight job per connection). Returns `false` if the
/// connection died on a write error.
fn process(ctx: &ReactorCtx, id: u64, conn: &mut Conn) -> bool {
    loop {
        if conn.discarding {
            match find_newline(&conn.inbuf, 0) {
                Some(nl) => {
                    conn.inbuf.drain(..=nl);
                    conn.scanned = 0;
                    conn.discarding = false;
                    conn.last_activity = Instant::now();
                    if !respond_too_long(conn) {
                        return false;
                    }
                }
                None => {
                    conn.inbuf.clear();
                    conn.scanned = 0;
                    if conn.eof {
                        // unterminated oversized line at EOF still gets
                        // its structured error (old reader semantics)
                        conn.discarding = false;
                        if !respond_too_long(conn) {
                            return false;
                        }
                    }
                    return true;
                }
            }
            continue;
        }
        if conn.awaiting {
            return true;
        }
        match find_newline(&conn.inbuf, conn.scanned) {
            Some(nl) => {
                if nl > MAX_LINE_BYTES {
                    // a complete-but-oversized line delivered in one gulp
                    conn.inbuf.drain(..=nl);
                    conn.scanned = 0;
                    conn.last_activity = Instant::now();
                    if !respond_too_long(conn) {
                        return false;
                    }
                    continue;
                }
                if !serve_line(ctx, id, conn, Some(nl)) {
                    return false;
                }
            }
            None => {
                conn.scanned = conn.inbuf.len();
                if conn.inbuf.len() > MAX_LINE_BYTES {
                    // partial line already past the cap: drop what we
                    // hold and discard the rest as it streams in
                    conn.inbuf.clear();
                    conn.scanned = 0;
                    conn.discarding = true;
                    continue;
                }
                if conn.eof && !conn.inbuf.is_empty() {
                    // final unterminated line is served like any other
                    if !serve_line(ctx, id, conn, None) {
                        return false;
                    }
                    continue;
                }
                return true;
            }
        }
    }
}

/// Serve the line ending at `nl` (`None` = the final unterminated line,
/// which consumes the whole buffer). Consumes the line's bytes and
/// queues/flushes its response, or submits its engine job.
fn serve_line(ctx: &ReactorCtx, id: u64, conn: &mut Conn, nl: Option<usize>) -> bool {
    let Conn { inbuf, scratch, .. } = conn;
    let end = match nl {
        // \r is stripped on terminated lines only (old reader parity)
        Some(p) if p > 0 && inbuf[p - 1] == b'\r' => p - 1,
        Some(p) => p,
        None => inbuf.len(),
    };
    let mut wrote = true;
    let mut submitted = false;
    match std::str::from_utf8(&inbuf[..end]) {
        Ok(line) if line.trim().is_empty() => wrote = false,
        Ok(line) => {
            match router::respond_or_submit(&ctx.pool, line, scratch, || {
                // lint: allow(hot-path-alloc): Arc refcount bump, not a heap allocation; built only when a job is actually submitted
                Reply::completion(ctx.queue.clone(), id)
            }) {
                RouteOutcome::Done => {}
                RouteOutcome::Pending => {
                    submitted = true;
                    wrote = false;
                }
            }
        }
        // lossy replacement would silently mangle profile keys; reject
        // like any other malformed payload
        Err(_) => Response::err_kind("bad_request", "request line is not valid UTF-8")
            .encode_line(&mut scratch.out),
    }
    match nl {
        Some(p) => {
            conn.inbuf.drain(..=p);
        }
        None => conn.inbuf.clear(),
    }
    conn.scanned = 0;
    conn.last_activity = Instant::now();
    if submitted {
        conn.awaiting = true;
        // ordering: gauge increment; stats-only
        ctx.stats.conns.active.fetch_add(1, Ordering::Relaxed);
    }
    if wrote {
        // inline replies (health/stats/warm predicts/errors) aggregate
        // their flush under `other:warm` — the op is gone by this point
        // and the warm path must not re-derive it
        let t0 = Instant::now();
        let ok = queue_write(conn);
        ctx.pool
            .obs()
            .record_ns(Stage::WriteFlush, OpClass::Other, Temp::Warm, ns_of(t0.elapsed()));
        return ok;
    }
    true
}

fn respond_too_long(conn: &mut Conn) -> bool {
    Response::err_kind(
        "line_too_long",
        format!("request line exceeds {MAX_LINE_BYTES} bytes"), // lint: allow(hot-path-alloc): abuse-rejection error path, not the serving path
    )
    .encode_line(&mut conn.scratch.out);
    queue_write(conn)
}

/// An engine reply arrived for `conn`: encode, flush, resume parsing
/// whatever lines are already buffered. Records the completion-queue
/// wait and the write flush, and finalizes the request's trace (the
/// admission→delivery total, checked against the slow threshold).
fn deliver(ctx: &ReactorCtx, id: u64, conn: &mut Conn, resp: Response, mut meta: ReqMeta) -> bool {
    conn.awaiting = false;
    // ordering: gauge decrement; stats-only
    ctx.stats.conns.active.fetch_sub(1, Ordering::Relaxed);
    conn.last_activity = Instant::now();
    let obs = ctx.pool.obs();
    if let Some(pushed) = meta.pushed {
        meta.record(obs, Stage::CompletionWait, ns_of(pushed.elapsed()));
    }
    // the trace closes here: write flush happens after delivery and is
    // histogram-only (see docs/OBSERVABILITY.md)
    if let Some(trace) = meta.trace.take() {
        let total_ms = meta.submitted.elapsed().as_secs_f64() * 1e3;
        obs.complete_trace(TraceEntry::from_state(
            meta.op.name(),
            meta.temp.name(),
            total_ms,
            &trace,
        ));
    }
    resp.encode_line(&mut conn.scratch.out);
    let t0 = Instant::now();
    let wrote = queue_write(conn);
    obs.record_ns(Stage::WriteFlush, meta.op, meta.temp, ns_of(t0.elapsed()));
    if !wrote {
        return false;
    }
    if conn.detached {
        // peer hung up while the job ran; the reply got its best-effort
        // write above, nothing more to serve
        return false;
    }
    process(ctx, id, conn)
}

/// Write `conn.scratch.out` (one encoded response line) straight to the
/// socket; whatever the socket won't take spills into the backlog
/// buffer, to be flushed on writable readiness. The warm path writes
/// directly from the reused scratch buffer — no copies, no allocations.
fn queue_write(conn: &mut Conn) -> bool {
    // failpoint `reactor.write`: partial-write(n) caps this call's socket
    // write at n bytes (the rest spills to the backlog, exercising the
    // writable-readiness flush path without a slow peer); return-err
    // closes the connection as a hard write error; delay stalls inline.
    // Unarmed, this is a single relaxed atomic load — the warm path
    // stays allocation-free.
    let cap = match crate::fp!("reactor.write") {
        None => usize::MAX,
        Some(Hit::PartialWrite(n)) => n,
        Some(Hit::ReturnErr) => return false,
    };
    if conn.has_backlog() {
        // keep strict response order: never bypass queued bytes
        let out = &conn.scratch.out;
        conn.outbuf.extend_from_slice(out);
        return true;
    }
    let mut off = 0;
    let end = conn.scratch.out.len().min(cap);
    while off < end {
        match conn.stream.write(&conn.scratch.out[off..end]) {
            Ok(0) => return false,
            Ok(n) => {
                off += n;
                conn.last_write = Instant::now();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if off < conn.scratch.out.len() {
        conn.outbuf.extend_from_slice(&conn.scratch.out[off..]);
        conn.last_write = Instant::now();
    }
    true
}

/// Writable readiness: push the spilled backlog out.
fn flush_backlog(conn: &mut Conn) -> bool {
    // failpoint `reactor.flush`: partial-write(n) caps the flush at n
    // bytes per readiness event (keeps a backlog alive so write-stall
    // sweeps see it); return-err drops the connection; delay stalls the
    // flush inline. A single relaxed atomic load when unarmed.
    let cap = match crate::fp!("reactor.flush") {
        None => usize::MAX,
        Some(Hit::PartialWrite(n)) => n,
        Some(Hit::ReturnErr) => return false,
    };
    let end = conn.outbuf.len().min(conn.outpos.saturating_add(cap));
    while conn.outpos < end {
        match conn.stream.write(&conn.outbuf[conn.outpos..end]) {
            Ok(0) => return false,
            Ok(n) => {
                conn.outpos += n;
                conn.last_write = Instant::now();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if conn.outpos >= conn.outbuf.len() {
        conn.outbuf.clear();
        conn.outpos = 0;
    }
    true
}
