//! Wire protocol: newline-delimited JSON requests/responses.
//!
//! The complete op-by-op reference with request/response examples and the
//! full error-kind table lives in `docs/PROTOCOL.md`; this table is the
//! in-tree summary:
//!
//! | op | request fields | reply fields |
//! |----|----------------|--------------|
//! | `health` | — | `status` |
//! | `stats` | — | `requests`, `artifact_batches`, `avg_batch_fill`, `overloaded`, `predict_lanes`, `cache_hits`, `cache_misses`, `registry_epoch`, `last_reload`, `open_conns`, `active_conns`, `idle_conns`, `evictions`, `hints_applied`, `reactor_threads`, `uptime_s`, `version` |
//! | `metrics` | — | `uptime_s`, `version`, `gauges{}`, `stages[]` (per-stage × op × warm/cold latency histograms with `p50_ms`/`p90_ms`/`p99_ms`/`max_ms` and raw `buckets`), `slow_traces[]` (see `docs/OBSERVABILITY.md`) |
//! | `instances` | — | `instances[]` (key, gpu, price_hr) |
//! | `predict` | `anchor`, `target`, `anchor_latency_ms`, `profile` | `latency_ms`, `member` |
//! | `predict_batch_size` | `instance`, `batch`, `t_min`, `t_max` | `latency_ms` |
//! | `predict_pixel_size` | `instance`, `pixels`, `t_min`, `t_max` | `latency_ms` |
//! | `recommend` | `anchor`, `pixels`, `profile_bmin`/`anchor_lat_bmin`, `profile_bmax`/`anchor_lat_bmax`, optional `profile_pmin`/`anchor_lat_pmin`/`profile_pmax`/`anchor_lat_pmax`, optional `targets[]`, `batches[]`, `pixel_sizes[]`, `gpu_counts[]`, `include_spot`, `top_k` | `candidates[]` (each with `on_frontier`), `n_candidates`, `frontier_size` |
//! | `plan` | `recommend` fields + `objective` (`cheapest`\|`fastest`\|`max_epochs`), `dataset_images`, `epochs`, `deadline_hours`\|`budget_usd` | `choice`, `hours`, `cost_usd`, `epochs`, `n_considered` |
//! | `ingest` | `anchor`, `target`, `model`, `batch`, `pixels`, `profile`, `anchor_latency_ms`, `target_latency_ms` | `anchor`, `target`, `staged` |
//! | `onboard` | optional `anchor` + `target` (both or neither; absent = every staged pair), optional `dry_run` | `epoch`, `pairs`, `staged` (`dry_run`: validation verdict only, nothing published) |
//! | `reload` | optional `dry_run` | `epoch` (`dry_run`: validation verdict only, nothing published) |
//! | `hint` | `epoch`, `anchor`, `target`, `member`, `anchor_latency_ms`, `latency_ms`, `profile` | `applied` (peer cache-warmth transfer; see `docs/PROTOCOL.md` §hint) |
//! | `cluster_stats` | — | route-tier membership/forwarding counters (backends answer `bad_request`) |
//!
//! Example request lines:
//! ```json
//! {"op":"predict","anchor":"g4dn","target":"p3",
//!  "anchor_latency_ms":123.4,"profile":{"Conv2D":286.0,"Relu":26.0}}
//! {"op":"recommend","anchor":"g4dn","pixels":64,
//!  "profile_bmin":{"Conv2D":80.0},"anchor_lat_bmin":95.0,
//!  "profile_bmax":{"Conv2D":900.0},"anchor_lat_bmax":1020.0,
//!  "gpu_counts":[1,2],"include_spot":true,"top_k":8}
//! {"op":"plan","anchor":"g4dn","pixels":64,
//!  "profile_bmin":{"Conv2D":80.0},"anchor_lat_bmin":95.0,
//!  "profile_bmax":{"Conv2D":900.0},"anchor_lat_bmax":1020.0,
//!  "objective":"cheapest","deadline_hours":4.0,
//!  "dataset_images":50000,"epochs":10}
//! ```
//!
//! `recommend.top_k` is optional; `0` (also the default when the field is
//! absent) is the documented "return every ranked candidate" sentinel —
//! nonzero values truncate after ranking, while `n_candidates` /
//! `frontier_size` / `on_frontier` always describe the full candidate set.
//!
//! Errors are structured, never silent: every rejected line gets
//! `{"ok":false,"kind":...,"error":...}` — `kind` is `unknown_op` for an
//! unrecognized `op` value and `bad_request` for malformed payloads.
//! Under load shedding the service answers `kind:"overloaded"` (full
//! engine-lane queue, or a connection past the server's budget) — the
//! request was NOT executed and should be retried with backoff. The
//! registry ops add `no_staged_data` (`onboard` with nothing ingested)
//! and `validation_failed` (`onboard`/`reload` candidate rejected by the
//! registry's probe gate — the previous epoch is still serving). The
//! route tier (`repro route`) adds `no_backend` (no healthy backend owns
//! the shard) and `epoch_divergence` (a fleet-wide publish left nodes on
//! different epochs; the reply carries a per-node report). The full
//! kind table is in `docs/PROTOCOL.md`.
//!
//! # Wire path (DOM-free hot loop)
//!
//! Serving traffic never touches the DOM [`Json`] tree. Requests are
//! decoded straight off the line by [`parse_line`] over the streaming
//! scanner in [`crate::util::json_stream`]: field names and profile keys
//! are borrowed `&str` slices of the line (escaped ones cow'd into a
//! reusable per-connection scratch), so a warm parse allocates nothing.
//! `predict` additionally stays *borrowed* ([`PredictView`]) so the
//! router can answer cache hits without materializing the profile map at
//! all. Responses are typed [`Response`] variants encoded directly into
//! a reusable output buffer by [`Response::encode_line`] — no
//! intermediate `Json` values or `String`s, floats rendered by the
//! shared shortest-round-trip formatter.
//!
//! The DOM `Json` remains authoritative on cold paths only: model
//! persistence, `artifacts/meta.json`, client-side helpers
//! ([`Request::to_json`]), and as the reference decoder
//! ([`Request::parse_dom`]) that the differential fuzz tests lock the
//! streaming decoder against — both accept the same grammar and produce
//! the same errors, byte offsets included.

use crate::advisor::{Candidate, EndpointProfiles, Objective, SweepRequest, TrainingJob};
use crate::coordinator::registry::IngestRequest;
use crate::obs::MetricsSnapshot;
use crate::gpu::Instance;
use crate::models::ModelId;
use crate::predictor::Member;
use crate::sim::workload::{BATCHES, PIXELS};
use crate::util::json_stream::{JsonWriter, LineScratch, RawElem, RawVal};
use crate::util::Json;
use anyhow::anyhow;
use std::collections::BTreeMap;
use std::fmt;

/// A phase-1 (cross-instance) prediction request.
#[derive(Debug, Clone, PartialEq)]
pub struct PredictRequest {
    pub anchor: Instance,
    pub target: Instance,
    pub anchor_latency_ms: f64,
    /// Aggregated (op name → ms) profile — the black-box feature payload.
    pub profile: BTreeMap<String, f64>,
}

/// A peer cache-warmth hint (the `hint` op): one answered prediction,
/// replayed into another backend's cache so a warm `(anchor, target)`
/// on one node is answered warm from any entry point. Carries the
/// registry epoch the prediction was computed under — a hint from a
/// different epoch is acknowledged but not applied.
#[derive(Debug, Clone, PartialEq)]
pub struct HintRequest {
    pub epoch: u64,
    pub anchor: Instance,
    pub target: Instance,
    pub anchor_latency_ms: f64,
    /// The predicted latency being transplanted.
    pub latency_ms: f64,
    /// Ensemble member that produced the prediction.
    pub member: Member,
    pub profile: BTreeMap<String, f64>,
}

/// Parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Health,
    /// Serving counters (requests, artifact batches, cache hits/misses).
    Stats,
    /// Latency observatory snapshot: per-stage histograms, gauges, and
    /// the sampled slow-request ring (see [`crate::obs`]).
    Metrics,
    Instances,
    Predict(PredictRequest),
    PredictBatchSize {
        instance: Instance,
        batch: usize,
        t_min: f64,
        t_max: f64,
    },
    PredictPixelSize {
        instance: Instance,
        pixels: usize,
        t_min: f64,
        t_max: f64,
    },
    /// Advisor sweep + Pareto ranking. `top_k == 0` (the default) is the
    /// documented "return everything" sentinel; nonzero truncates the
    /// ranked list (full-set metadata fields are unaffected).
    Recommend { query: SweepRequest, top_k: usize },
    /// Advisor sweep + constrained planning.
    Plan {
        query: SweepRequest,
        job: TrainingJob,
        objective: Objective,
    },
    /// Stage one profiled measurement for a device pair (the online
    /// onboarding input path; see `coordinator::registry`).
    Ingest(IngestRequest),
    /// Train the staged pair(s) and publish a new registry epoch.
    /// `pair == None` onboards every staged pair. `dry_run` runs the
    /// full train-and-validate pipeline but publishes nothing — the
    /// route tier's phase-1 vote before a fleet-wide publish.
    Onboard {
        pair: Option<(Instance, Instance)>,
        dry_run: bool,
    },
    /// Re-load the model directory and publish it as a new epoch.
    /// `dry_run` validates the on-disk candidate without swapping it in.
    Reload { dry_run: bool },
    /// Peer cache-warmth transfer (route tier fan-out).
    Hint(HintRequest),
    /// Route-tier membership/forwarding counters. A plain backend does
    /// not own this data and answers `bad_request`.
    ClusterStats,
}

/// Why a request line was rejected. `UnknownOp` is split out so the
/// service can answer with a distinct structured error instead of a
/// generic parse failure (or worse, a silent drop).
#[derive(Debug)]
pub enum ParseError {
    UnknownOp(String),
    Malformed(anyhow::Error),
}

impl ParseError {
    /// Stable error-kind tag for the wire (`{"ok":false,"kind":...}`).
    pub fn kind(&self) -> &'static str {
        match self {
            ParseError::UnknownOp(_) => "unknown_op",
            ParseError::Malformed(_) => "bad_request",
        }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParseError::UnknownOp(op) => write!(f, "unknown op `{op}`"),
            ParseError::Malformed(e) => write!(f, "{e:#}"),
        }
    }
}

impl std::error::Error for ParseError {}

impl Request {
    /// Parse one request line via the streaming (DOM-free) decoder. A
    /// fresh scratch per call — servers hold a per-connection
    /// [`WireScratch`] and use [`parse_line`] directly instead.
    pub fn parse(line: &str) -> Result<Request, ParseError> {
        let mut scratch = WireScratch::default();
        match parse_line(line, &mut scratch)? {
            ParsedLine::Req(req) => Ok(req),
            ParsedLine::Predict(view) => Ok(Request::Predict(view.materialize())),
        }
    }

    /// Reference decoder over the DOM [`Json`] tree. Kept for the
    /// differential wire tests (`tests/wire_differential.rs`), which
    /// assert `parse` and `parse_dom` agree — same requests, same error
    /// kinds and messages — on every example line and mutations thereof.
    pub fn parse_dom(line: &str) -> Result<Request, ParseError> {
        let j = Json::parse(line).map_err(ParseError::Malformed)?;
        let op = j.req_str("op").map_err(ParseError::Malformed)?;
        match parse_fields(op, &j) {
            Ok(Some(req)) => Ok(req),
            Ok(None) => Err(ParseError::UnknownOp(op.to_string())), // lint: allow(hot-path-alloc): unknown-op error path, not reached by valid traffic
            Err(e) => Err(ParseError::Malformed(e)),
        }
    }

    /// Serialize back to the wire object (`parse` ∘ `to_json` is identity —
    /// covered by the round-trip tests).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            Request::Health => {
                o.set("op", Json::Str("health".into()));
            }
            Request::Stats => {
                o.set("op", Json::Str("stats".into()));
            }
            Request::Metrics => {
                o.set("op", Json::Str("metrics".into()));
            }
            Request::Instances => {
                o.set("op", Json::Str("instances".into()));
            }
            Request::Predict(p) => {
                o.set("op", Json::Str("predict".into()));
                o.set("anchor", Json::Str(p.anchor.key().into()));
                o.set("target", Json::Str(p.target.key().into()));
                o.set("anchor_latency_ms", Json::Num(p.anchor_latency_ms));
                o.set("profile", profile_json(&p.profile));
            }
            Request::PredictBatchSize {
                instance,
                batch,
                t_min,
                t_max,
            } => {
                o.set("op", Json::Str("predict_batch_size".into()));
                o.set("instance", Json::Str(instance.key().into()));
                o.set("batch", Json::Num(*batch as f64));
                o.set("t_min", Json::Num(*t_min));
                o.set("t_max", Json::Num(*t_max));
            }
            Request::PredictPixelSize {
                instance,
                pixels,
                t_min,
                t_max,
            } => {
                o.set("op", Json::Str("predict_pixel_size".into()));
                o.set("instance", Json::Str(instance.key().into()));
                o.set("pixels", Json::Num(*pixels as f64));
                o.set("t_min", Json::Num(*t_min));
                o.set("t_max", Json::Num(*t_max));
            }
            Request::Recommend { query, top_k } => {
                o.set("op", Json::Str("recommend".into()));
                query_json(query, &mut o);
                o.set("top_k", Json::Num(*top_k as f64));
            }
            Request::Plan {
                query,
                job,
                objective,
            } => {
                o.set("op", Json::Str("plan".into()));
                query_json(query, &mut o);
                o.set("dataset_images", Json::Num(job.dataset_images));
                o.set("epochs", Json::Num(job.epochs));
                match *objective {
                    Objective::CheapestUnderDeadline { deadline_hours } => {
                        o.set("objective", Json::Str("cheapest".into()));
                        o.set("deadline_hours", Json::Num(deadline_hours));
                    }
                    Objective::FastestUnderBudget { budget_usd } => {
                        o.set("objective", Json::Str("fastest".into()));
                        o.set("budget_usd", Json::Num(budget_usd));
                    }
                    Objective::MaxEpochsUnderDeadline { deadline_hours } => {
                        o.set("objective", Json::Str("max_epochs".into()));
                        o.set("deadline_hours", Json::Num(deadline_hours));
                    }
                }
            }
            Request::Ingest(r) => {
                o.set("op", Json::Str("ingest".into()));
                o.set("anchor", Json::Str(r.anchor.key().into()));
                o.set("target", Json::Str(r.target.key().into()));
                o.set("model", Json::Str(r.model.name().into()));
                o.set("batch", Json::Num(r.batch as f64));
                o.set("pixels", Json::Num(r.pixels as f64));
                o.set("profile", profile_json(&r.profile));
                o.set("anchor_latency_ms", Json::Num(r.anchor_latency_ms));
                o.set("target_latency_ms", Json::Num(r.target_latency_ms));
            }
            Request::Onboard { pair, dry_run } => {
                o.set("op", Json::Str("onboard".into()));
                if let Some((a, t)) = pair {
                    o.set("anchor", Json::Str(a.key().into()));
                    o.set("target", Json::Str(t.key().into()));
                }
                if *dry_run {
                    o.set("dry_run", Json::Bool(true));
                }
            }
            Request::Reload { dry_run } => {
                o.set("op", Json::Str("reload".into()));
                if *dry_run {
                    o.set("dry_run", Json::Bool(true));
                }
            }
            Request::Hint(h) => {
                o.set("op", Json::Str("hint".into()));
                o.set("anchor", Json::Str(h.anchor.key().into()));
                o.set("target", Json::Str(h.target.key().into()));
                o.set("member", Json::Str(h.member.name().into()));
                o.set("epoch", Json::Num(h.epoch as f64));
                o.set("anchor_latency_ms", Json::Num(h.anchor_latency_ms));
                o.set("latency_ms", Json::Num(h.latency_ms));
                o.set("profile", profile_json(&h.profile));
            }
            Request::ClusterStats => {
                o.set("op", Json::Str("cluster_stats".into()));
            }
        }
        o
    }
}

// ---------------------------------------------------------------------------
// Streaming (DOM-free) request decoding — the wire hot path
// ---------------------------------------------------------------------------

/// Reusable per-connection decode state (index vectors + unescape
/// buffer). Warm parses allocate nothing.
#[derive(Default)]
pub struct WireScratch {
    line: LineScratch,
}

/// Result of [`parse_line`]: every op except phase-1 `predict` is
/// materialized into an owned [`Request`]; `predict` stays borrowed so
/// the cache fast path can skip materialization entirely.
pub enum ParsedLine<'s> {
    Req(Request),
    Predict(PredictView<'s>),
}

/// A fully validated `predict` request borrowing the scanned line: the
/// profile is a sorted, deduplicated span list over the scratch — no
/// `BTreeMap`, no key `String`s. [`Self::materialize`] builds the owned
/// [`PredictRequest`] for the engine handoff (cache misses only).
pub struct PredictView<'s> {
    pub anchor: Instance,
    pub target: Instance,
    pub anchor_latency_ms: f64,
    scratch: &'s LineScratch,
    line: &'s str,
    start: u32,
    len: u32,
}

impl<'s> PredictView<'s> {
    /// Sorted, deduplicated `(op, ms)` pairs — the exact order a
    /// `BTreeMap<String, f64>` iterates, so cache keys built from this
    /// iterator equal keys built from the materialized profile.
    pub fn pairs(&self) -> impl Iterator<Item = (&'s str, f64)> + '_ {
        self.scratch
            .pairs(self.start, self.len)
            .iter()
            .map(move |p| (self.scratch.str_of(self.line, p.key), p.val))
    }

    pub fn materialize(&self) -> PredictRequest {
        PredictRequest {
            anchor: self.anchor,
            target: self.target,
            anchor_latency_ms: self.anchor_latency_ms,
            profile: self.pairs().map(|(k, v)| (k.to_string(), v)).collect(), // lint: allow(hot-path-alloc): materialize() runs once per cache miss to build the engine-lane request
        }
    }
}

/// Decode one request line with the streaming scanner. Grammar, field
/// validation order, and error text all mirror [`Request::parse_dom`]
/// (the differential fuzz test enforces it).
pub fn parse_line<'s>(
    line: &'s str,
    scratch: &'s mut WireScratch,
) -> Result<ParsedLine<'s>, ParseError> {
    let ls = &mut scratch.line;
    ls.scan(line).map_err(ParseError::Malformed)?;
    let op = match ls.field(line, "op") {
        Some(RawVal::Str(sp)) => ls.str_of(line, sp),
        _ => {
            return Err(ParseError::Malformed(anyhow!(
                "missing/invalid string field `op`"
            )))
        }
    };
    let op = match op {
        "health" => Op::Health,
        "stats" => Op::Stats,
        "metrics" => Op::Metrics,
        "instances" => Op::Instances,
        "predict" => Op::Predict,
        "predict_batch_size" => Op::BatchSize,
        "predict_pixel_size" => Op::PixelSize,
        "recommend" => Op::Recommend,
        "plan" => Op::Plan,
        "ingest" => Op::Ingest,
        "onboard" => Op::Onboard,
        "reload" => Op::Reload,
        "hint" => Op::Hint,
        "cluster_stats" => Op::ClusterStats,
        other => return Err(ParseError::UnknownOp(other.to_string())), // lint: allow(hot-path-alloc): unknown-op error path, not reached by valid traffic
    };
    wire_request(op, line, ls).map_err(ParseError::Malformed)
}

#[derive(Clone, Copy)]
enum Op {
    Health,
    Stats,
    Metrics,
    Instances,
    Predict,
    BatchSize,
    PixelSize,
    Recommend,
    Plan,
    Ingest,
    Onboard,
    Reload,
    Hint,
    ClusterStats,
}

fn wire_request<'s>(
    op: Op,
    line: &'s str,
    ls: &'s mut LineScratch,
) -> anyhow::Result<ParsedLine<'s>> {
    Ok(ParsedLine::Req(match op {
        Op::Health => Request::Health,
        Op::Stats => Request::Stats,
        Op::Metrics => Request::Metrics,
        Op::Instances => Request::Instances,
        Op::Predict => {
            let anchor = sraw_req_instance(ls, line, "anchor")?;
            let target = sraw_req_instance(ls, line, "target")?;
            let anchor_latency_ms = sraw_req_positive(ls, line, "anchor_latency_ms")?;
            let (start, len) = sraw_profile_range(ls, line, "profile")?;
            let ls: &'s LineScratch = ls;
            return Ok(ParsedLine::Predict(PredictView {
                anchor,
                target,
                anchor_latency_ms,
                scratch: ls,
                line,
                start,
                len,
            }));
        }
        Op::BatchSize => Request::PredictBatchSize {
            instance: sraw_req_instance(ls, line, "instance")?,
            batch: match ls.field(line, "batch") {
                None => anyhow::bail!("missing `batch`"),
                Some(v) => sraw_as_usize_strict(&v, "`batch`")?,
            },
            t_min: sraw_req_positive(ls, line, "t_min")?,
            t_max: sraw_req_positive(ls, line, "t_max")?,
        },
        Op::PixelSize => Request::PredictPixelSize {
            instance: sraw_req_instance(ls, line, "instance")?,
            pixels: match ls.field(line, "pixels") {
                None => anyhow::bail!("missing `pixels`"),
                Some(v) => sraw_as_usize_strict(&v, "`pixels`")?,
            },
            t_min: sraw_req_positive(ls, line, "t_min")?,
            t_max: sraw_req_positive(ls, line, "t_max")?,
        },
        Op::Recommend => Request::Recommend {
            query: sraw_query(ls, line)?,
            top_k: match ls.field(line, "top_k") {
                None => 0,
                Some(v) => sraw_as_usize_strict(&v, "`top_k`")?,
            },
        },
        Op::Plan => sraw_plan(ls, line)?,
        Op::Ingest => sraw_ingest(ls, line)?,
        Op::Onboard => Request::Onboard {
            pair: sraw_onboard_pair(ls, line)?,
            dry_run: sraw_dry_run(ls, line)?,
        },
        Op::Reload => Request::Reload {
            dry_run: sraw_dry_run(ls, line)?,
        },
        Op::Hint => sraw_hint(ls, line)?,
        Op::ClusterStats => Request::ClusterStats,
    }))
}

/// Streaming mirror of [`parse_dry_run`]: optional boolean, default
/// `false`.
fn sraw_dry_run(ls: &LineScratch, line: &str) -> anyhow::Result<bool> {
    match ls.field(line, "dry_run") {
        None => Ok(false),
        Some(RawVal::Bool(b)) => Ok(b),
        Some(_) => Err(anyhow!("`dry_run` must be a boolean")),
    }
}

/// Streaming mirror of [`parse_hint`] — same field order, same checks,
/// same messages.
fn sraw_hint(ls: &mut LineScratch, line: &str) -> anyhow::Result<Request> {
    let anchor = sraw_req_instance(ls, line, "anchor")?;
    let target = sraw_req_instance(ls, line, "target")?;
    anyhow::ensure!(anchor != target, "`anchor` and `target` must differ");
    let member = Member::from_name(sraw_req_str(ls, line, "member")?)
        .ok_or_else(|| anyhow!("unknown member in `member`"))?;
    let epoch = match ls.field(line, "epoch") {
        None => anyhow::bail!("missing `epoch`"),
        Some(v) => sraw_as_usize_strict(&v, "`epoch`")? as u64,
    };
    let anchor_latency_ms = sraw_req_positive(ls, line, "anchor_latency_ms")?;
    let latency_ms = sraw_req_positive(ls, line, "latency_ms")?;
    let profile = sraw_profile_map(ls, line, "profile")?;
    Ok(Request::Hint(HintRequest {
        epoch,
        anchor,
        target,
        anchor_latency_ms,
        latency_ms,
        member,
        profile,
    }))
}

/// Streaming mirror of [`parse_ingest`] — same field order, same checks,
/// same messages.
fn sraw_ingest(ls: &mut LineScratch, line: &str) -> anyhow::Result<Request> {
    let anchor = sraw_req_instance(ls, line, "anchor")?;
    let target = sraw_req_instance(ls, line, "target")?;
    anyhow::ensure!(anchor != target, "`anchor` and `target` must differ");
    let model = ModelId::from_name(sraw_req_str(ls, line, "model")?)
        .ok_or_else(|| anyhow!("unknown model in `model`"))?;
    let batch = match ls.field(line, "batch") {
        None => anyhow::bail!("missing `batch`"),
        Some(v) => sraw_as_usize_strict(&v, "`batch`")?,
    };
    anyhow::ensure!(batch >= 1, "`batch` must be at least 1");
    let pixels = match ls.field(line, "pixels") {
        None => anyhow::bail!("missing `pixels`"),
        Some(v) => sraw_as_usize_strict(&v, "`pixels`")?,
    };
    anyhow::ensure!(pixels >= 1, "`pixels` must be at least 1");
    let profile = sraw_profile_map(ls, line, "profile")?;
    Ok(Request::Ingest(IngestRequest {
        anchor,
        target,
        model,
        batch,
        pixels,
        profile,
        anchor_latency_ms: sraw_req_positive(ls, line, "anchor_latency_ms")?,
        target_latency_ms: sraw_req_positive(ls, line, "target_latency_ms")?,
    }))
}

/// Streaming mirror of the `onboard` pair rule: both fields, or neither.
fn sraw_onboard_pair(
    ls: &LineScratch,
    line: &str,
) -> anyhow::Result<Option<(Instance, Instance)>> {
    let anchor = match ls.field(line, "anchor") {
        None => None,
        Some(_) => Some(sraw_req_instance(ls, line, "anchor")?),
    };
    let target = match ls.field(line, "target") {
        None => None,
        Some(_) => Some(sraw_req_instance(ls, line, "target")?),
    };
    match (anchor, target) {
        (Some(a), Some(t)) => {
            anyhow::ensure!(a != t, "`anchor` and `target` must differ");
            Ok(Some((a, t)))
        }
        (None, None) => Ok(None),
        _ => anyhow::bail!("`anchor` and `target` must be given together"),
    }
}

fn sraw_req_str<'a>(ls: &'a LineScratch, line: &'a str, key: &str) -> anyhow::Result<&'a str> {
    match ls.field(line, key) {
        Some(RawVal::Str(sp)) => Ok(ls.str_of(line, sp)),
        _ => Err(anyhow!("missing/invalid string field `{key}`")),
    }
}

fn sraw_req_f64(ls: &LineScratch, line: &str, key: &str) -> anyhow::Result<f64> {
    match ls.field(line, key) {
        Some(RawVal::Num(n)) => Ok(n),
        _ => Err(anyhow!("missing/invalid number field `{key}`")),
    }
}

/// Mirror of [`req_positive`] for the streaming decoder.
fn sraw_req_positive(ls: &LineScratch, line: &str, key: &str) -> anyhow::Result<f64> {
    let v = sraw_req_f64(ls, line, key)?;
    anyhow::ensure!(v.is_finite() && v > 0.0, "`{key}` must be positive and finite");
    Ok(v)
}

fn sraw_req_instance(ls: &LineScratch, line: &str, key: &str) -> anyhow::Result<Instance> {
    Instance::from_key(sraw_req_str(ls, line, key)?)
        .ok_or_else(|| anyhow!("unknown instance in `{key}`"))
}

/// Mirror of [`as_usize_strict`] over a scanned value.
fn sraw_usize_strict(n: Option<f64>, what: &str) -> anyhow::Result<usize> {
    let n = n.ok_or_else(|| anyhow!("non-number {what}"))?;
    anyhow::ensure!(
        n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64,
        "{what} must be a non-negative integer"
    );
    Ok(n as usize)
}

fn sraw_as_usize_strict(v: &RawVal, what: &str) -> anyhow::Result<usize> {
    sraw_usize_strict(
        match v {
            RawVal::Num(n) => Some(*n),
            _ => None,
        },
        what,
    )
}

/// Sort + dedupe + validate a profile object field in place; returns the
/// compacted `(start, len)` pair range. Validation iterates in sorted
/// order — the same order the DOM's `BTreeMap` walk reports errors in.
fn sraw_profile_range(
    ls: &mut LineScratch,
    line: &str,
    key: &str,
) -> anyhow::Result<(u32, u32)> {
    let (start, len) = match ls.field(line, key) {
        Some(RawVal::Obj { start, len }) => (start, len),
        _ => return Err(anyhow!("missing profile object `{key}`")),
    };
    let len = ls.sort_dedup_pairs(line, start, len);
    for p in ls.pairs(start, len) {
        anyhow::ensure!(!p.bad, "non-number profile value in `{key}`");
        anyhow::ensure!(p.val.is_finite(), "non-finite profile value in `{key}`");
    }
    Ok((start, len))
}

fn sraw_profile_map(
    ls: &mut LineScratch,
    line: &str,
    key: &str,
) -> anyhow::Result<BTreeMap<String, f64>> {
    let (start, len) = sraw_profile_range(ls, line, key)?;
    Ok(ls
        .pairs(start, len)
        .iter()
        .map(|p| (ls.str_of(line, p.key).to_string(), p.val)) // lint: allow(hot-path-alloc): cache-miss submission — builds the owned request handed to an engine lane
        .collect()) // lint: allow(hot-path-alloc): cache-miss submission — builds the owned request handed to an engine lane
}

fn sraw_usize_list(
    ls: &LineScratch,
    line: &str,
    key: &str,
    max_entries: usize,
    min_value: usize,
    max_value: usize,
) -> anyhow::Result<Vec<usize>> {
    match ls.field(line, key) {
        None => Ok(Vec::new()), // lint: allow(hot-path-alloc): empty-Vec construction allocates nothing
        Some(RawVal::Arr { start, len }) => {
            anyhow::ensure!(
                len as usize <= max_entries,
                "`{key}` has {len} entries (max {max_entries})"
            );
            ls.elems(start, len)
                .iter()
                .map(|e| {
                    let n = sraw_usize_strict(
                        match e {
                            RawElem::Num(n) => Some(*n),
                            _ => None,
                        },
                        &format!("entry in `{key}`"), // lint: allow(hot-path-alloc): cold-op parse path (plan/recommend batch lists)
                    )?;
                    anyhow::ensure!(
                        (min_value..=max_value).contains(&n),
                        "entry {n} in `{key}` outside [{min_value}, {max_value}]"
                    );
                    Ok(n)
                })
                .collect() // lint: allow(hot-path-alloc): cold-op numeric list, bounded by max_entries
        }
        Some(_) => Err(anyhow!("`{key}` must be an array of numbers")),
    }
}

fn sraw_targets(ls: &LineScratch, line: &str) -> anyhow::Result<Vec<Instance>> {
    match ls.field(line, "targets") {
        None => Ok(Vec::new()), // lint: allow(hot-path-alloc): empty-Vec construction allocates nothing
        Some(RawVal::Arr { start, len }) => {
            anyhow::ensure!(
                len as usize <= MAX_TARGET_ENTRIES,
                "`targets` has {len} entries (max {MAX_TARGET_ENTRIES})"
            );
            ls.elems(start, len)
                .iter()
                .map(|e| {
                    match e {
                        RawElem::Str(sp) => Instance::from_key(ls.str_of(line, *sp)),
                        _ => None,
                    }
                    .ok_or_else(|| anyhow!("unknown instance in `targets`"))
                })
                .collect() // lint: allow(hot-path-alloc): cold-op (recommend/plan) target list, bounded by MAX_TARGET_ENTRIES
        }
        Some(_) => anyhow::bail!("`targets` must be an array of instance keys"),
    }
}

fn sraw_endpoints(
    ls: &mut LineScratch,
    line: &str,
    profile_min_key: &str,
    lat_min_key: &str,
    profile_max_key: &str,
    lat_max_key: &str,
) -> anyhow::Result<EndpointProfiles> {
    Ok(EndpointProfiles {
        profile_min: sraw_profile_map(ls, line, profile_min_key)?,
        lat_min: sraw_req_positive(ls, line, lat_min_key)?,
        profile_max: sraw_profile_map(ls, line, profile_max_key)?,
        lat_max: sraw_req_positive(ls, line, lat_max_key)?,
    })
}

/// Streaming mirror of [`parse_query`] — same field order, same checks,
/// same messages.
fn sraw_query(ls: &mut LineScratch, line: &str) -> anyhow::Result<SweepRequest> {
    let targets = sraw_targets(ls, line)?;
    let pixel_keys = [
        "profile_pmin",
        "anchor_lat_pmin",
        "profile_pmax",
        "anchor_lat_pmax",
    ];
    let pixel = if pixel_keys.iter().any(|k| ls.field(line, k).is_some()) {
        Some(sraw_endpoints(
            ls,
            line,
            "profile_pmin",
            "anchor_lat_pmin",
            "profile_pmax",
            "anchor_lat_pmax",
        )?)
    } else {
        None
    };
    let (bmin, bmax) = (BATCHES[0], BATCHES[4]);
    let (pmin, pmax) = (PIXELS[0], PIXELS[4]);
    let pixels = match ls.field(line, "pixels") {
        None => anyhow::bail!("missing `pixels`"),
        Some(v) => sraw_as_usize_strict(&v, "`pixels`")?,
    };
    anyhow::ensure!(
        (pmin..=pmax).contains(&pixels),
        "`pixels` outside the modeled range [{pmin}, {pmax}]"
    );
    let pixel_sizes = sraw_usize_list(ls, line, "pixel_sizes", MAX_AXIS_ENTRIES, pmin, pmax)?;
    if pixel.is_none() {
        anyhow::ensure!(
            pixel_sizes.iter().all(|&p| p == pixels),
            "`pixel_sizes` beyond the profiled `pixels` require the pixel-endpoint \
             fields (profile_pmin/anchor_lat_pmin/profile_pmax/anchor_lat_pmax)"
        );
    }
    let batches = sraw_usize_list(ls, line, "batches", MAX_AXIS_ENTRIES, bmin, bmax)?;
    let gpu_counts = sraw_usize_list(ls, line, "gpu_counts", MAX_GPU_ENTRIES, 1, MAX_GPUS)?;
    let eff = |n: usize, default: usize| if n == 0 { default } else { n };
    let grid = eff(targets.len(), Instance::ALL.len())
        * eff(batches.len(), 5)
        * eff(pixel_sizes.len(), 1)
        * eff(gpu_counts.len(), 1)
        * 2;
    anyhow::ensure!(
        grid <= MAX_GRID_CANDIDATES,
        "candidate grid of {grid} exceeds {MAX_GRID_CANDIDATES} — shrink an axis"
    );
    Ok(SweepRequest {
        anchor: sraw_req_instance(ls, line, "anchor")?,
        pixels,
        batch: sraw_endpoints(
            ls,
            line,
            "profile_bmin",
            "anchor_lat_bmin",
            "profile_bmax",
            "anchor_lat_bmax",
        )?,
        pixel,
        targets,
        batches,
        pixel_sizes,
        gpu_counts,
        include_spot: match ls.field(line, "include_spot") {
            None => false,
            Some(RawVal::Bool(b)) => b,
            Some(_) => anyhow::bail!("`include_spot` must be a boolean"),
        },
    })
}

fn sraw_plan(ls: &mut LineScratch, line: &str) -> anyhow::Result<Request> {
    let query = sraw_query(ls, line)?;
    let job = TrainingJob {
        dataset_images: sraw_req_positive(ls, line, "dataset_images")?,
        epochs: match ls.field(line, "epochs") {
            None => 1.0,
            Some(_) => sraw_req_positive(ls, line, "epochs")?,
        },
    };
    let objective = match sraw_req_str(ls, line, "objective")? {
        "cheapest" => Objective::CheapestUnderDeadline {
            deadline_hours: sraw_req_positive(ls, line, "deadline_hours")?,
        },
        "fastest" => Objective::FastestUnderBudget {
            budget_usd: sraw_req_positive(ls, line, "budget_usd")?,
        },
        "max_epochs" => Objective::MaxEpochsUnderDeadline {
            deadline_hours: sraw_req_positive(ls, line, "deadline_hours")?,
        },
        other => anyhow::bail!("unknown objective `{other}` (expected cheapest|fastest|max_epochs)"),
    };
    Ok(Request::Plan {
        query,
        job,
        objective,
    })
}

// ---------------------------------------------------------------------------
// DOM reference decoding (cold paths + differential tests)
// ---------------------------------------------------------------------------

/// Field parsing: the single known-op list. `Ok(None)` means the op is
/// not recognized (surfaced as `unknown_op`); field errors are plain
/// `bad_request` errors.
fn parse_fields(op: &str, j: &Json) -> anyhow::Result<Option<Request>> {
    Ok(Some(match op {
        "health" => Request::Health,
        "stats" => Request::Stats,
        "metrics" => Request::Metrics,
        "instances" => Request::Instances,
        "predict" => parse_predict(j)?,
        "predict_batch_size" => Request::PredictBatchSize {
            instance: req_instance(j, "instance")?,
            batch: as_usize_strict(req_field(j, "batch")?, "`batch`")?,
            t_min: req_positive(j, "t_min")?,
            t_max: req_positive(j, "t_max")?,
        },
        "predict_pixel_size" => Request::PredictPixelSize {
            instance: req_instance(j, "instance")?,
            pixels: as_usize_strict(req_field(j, "pixels")?, "`pixels`")?,
            t_min: req_positive(j, "t_min")?,
            t_max: req_positive(j, "t_max")?,
        },
        "recommend" => Request::Recommend {
            query: parse_query(j)?,
            top_k: match j.get("top_k") {
                None => 0,
                Some(v) => as_usize_strict(v, "`top_k`")?,
            },
        },
        "plan" => parse_plan(j)?,
        "ingest" => parse_ingest(j)?,
        "onboard" => parse_onboard(j)?,
        "reload" => Request::Reload {
            dry_run: parse_dry_run(j)?,
        },
        "hint" => parse_hint(j)?,
        "cluster_stats" => Request::ClusterStats,
        _ => return Ok(None),
    }))
}

/// Optional `dry_run` boolean, default `false` (rule mirrored by
/// [`sraw_dry_run`]).
fn parse_dry_run(j: &Json) -> anyhow::Result<bool> {
    match j.get("dry_run") {
        None => Ok(false),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| anyhow!("`dry_run` must be a boolean")),
    }
}

/// DOM reference decoder for `ingest` (field order mirrored by
/// [`sraw_ingest`]).
fn parse_ingest(j: &Json) -> anyhow::Result<Request> {
    let anchor = req_instance(j, "anchor")?;
    let target = req_instance(j, "target")?;
    anyhow::ensure!(anchor != target, "`anchor` and `target` must differ");
    let model = ModelId::from_name(j.req_str("model")?)
        .ok_or_else(|| anyhow!("unknown model in `model`"))?;
    let batch = as_usize_strict(req_field(j, "batch")?, "`batch`")?;
    anyhow::ensure!(batch >= 1, "`batch` must be at least 1");
    let pixels = as_usize_strict(req_field(j, "pixels")?, "`pixels`")?;
    anyhow::ensure!(pixels >= 1, "`pixels` must be at least 1");
    let profile = parse_profile(j, "profile")?;
    Ok(Request::Ingest(IngestRequest {
        anchor,
        target,
        model,
        batch,
        pixels,
        profile,
        anchor_latency_ms: req_positive(j, "anchor_latency_ms")?,
        target_latency_ms: req_positive(j, "target_latency_ms")?,
    }))
}

/// DOM reference decoder for `onboard` (rule mirrored by
/// [`sraw_onboard_pair`]): a pair restricts the onboard to one staged
/// `(anchor, target)`; both fields must come together.
fn parse_onboard(j: &Json) -> anyhow::Result<Request> {
    let anchor = match j.get("anchor") {
        None => None,
        Some(_) => Some(req_instance(j, "anchor")?),
    };
    let target = match j.get("target") {
        None => None,
        Some(_) => Some(req_instance(j, "target")?),
    };
    let pair = match (anchor, target) {
        (Some(a), Some(t)) => {
            anyhow::ensure!(a != t, "`anchor` and `target` must differ");
            Some((a, t))
        }
        (None, None) => None,
        _ => anyhow::bail!("`anchor` and `target` must be given together"),
    };
    Ok(Request::Onboard {
        pair,
        dry_run: parse_dry_run(j)?,
    })
}

fn req_field<'a>(j: &'a Json, key: &str) -> anyhow::Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("missing `{key}`"))
}

fn req_instance(j: &Json, key: &str) -> anyhow::Result<Instance> {
    Instance::from_key(j.req_str(key)?).ok_or_else(|| anyhow!("unknown instance in `{key}`"))
}

// lint: allow(hot-path-alloc) begin: DOM reference parser — differential-testing twin of the scratch parser; requests it builds go to engine lanes, not the reactor
fn parse_profile(j: &Json, key: &str) -> anyhow::Result<BTreeMap<String, f64>> {
    match j.get(key) {
        Some(Json::Obj(m)) => {
            let mut profile = BTreeMap::new();
            for (k, v) in m {
                let ms = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("non-number profile value in `{key}`"))?;
                // non-finite values would alias in the prediction-cache
                // key quantization (and are meaningless as op times)
                anyhow::ensure!(ms.is_finite(), "non-finite profile value in `{key}`");
                profile.insert(k.clone(), ms);
            }
            Ok(profile)
        }
        _ => Err(anyhow!("missing profile object `{key}`")),
    }
}

fn profile_json(profile: &BTreeMap<String, f64>) -> Json {
    let mut o = Json::obj();
    for (k, v) in profile {
        o.set(k, Json::Num(*v));
    }
    o
}

fn parse_predict(j: &Json) -> anyhow::Result<Request> {
    Ok(Request::Predict(PredictRequest {
        anchor: req_instance(j, "anchor")?,
        target: req_instance(j, "target")?,
        anchor_latency_ms: req_positive(j, "anchor_latency_ms")?,
        profile: parse_profile(j, "profile")?,
    }))
}

/// DOM reference decoder for `hint` (field order mirrored by
/// [`sraw_hint`]).
fn parse_hint(j: &Json) -> anyhow::Result<Request> {
    let anchor = req_instance(j, "anchor")?;
    let target = req_instance(j, "target")?;
    anyhow::ensure!(anchor != target, "`anchor` and `target` must differ");
    let member = Member::from_name(j.req_str("member")?)
        .ok_or_else(|| anyhow!("unknown member in `member`"))?;
    let epoch = as_usize_strict(req_field(j, "epoch")?, "`epoch`")? as u64;
    let anchor_latency_ms = req_positive(j, "anchor_latency_ms")?;
    let latency_ms = req_positive(j, "latency_ms")?;
    let profile = parse_profile(j, "profile")?;
    Ok(Request::Hint(HintRequest {
        epoch,
        anchor,
        target,
        anchor_latency_ms,
        latency_ms,
        member,
        profile,
    }))
}

/// Grid-axis sanity caps: the sweep expands `batches × pixel_sizes ×
/// gpu_counts × pricing` candidates per target, so one request must not
/// be able to ask for an astronomically large grid (the line-length cap
/// in `server.rs` bounds bytes; these bound the *amplification*).
const MAX_AXIS_ENTRIES: usize = 64;
const MAX_GPU_ENTRIES: usize = 16;
const MAX_GPUS: usize = 64;
const MAX_TARGET_ENTRIES: usize = 32;
/// Per-axis caps bound entries, not their cross product — this bounds the
/// number of candidates one sweep may expand to (the paper-grid default is
/// 6 targets × 5 batches × 1 pixel × 1 gpu × 2 pricing = 60).
const MAX_GRID_CANDIDATES: usize = 4096;

/// Strict non-negative-integer read: rejects fractional and negative
/// values instead of silently truncating/saturating them.
fn as_usize_strict(v: &Json, what: &str) -> anyhow::Result<usize> {
    let n = v
        .as_f64()
        .ok_or_else(|| anyhow!("non-number {what}"))?;
    anyhow::ensure!(
        n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64,
        "{what} must be a non-negative integer"
    );
    Ok(n as usize)
}

fn parse_usize_list(
    j: &Json,
    key: &str,
    max_entries: usize,
    min_value: usize,
    max_value: usize,
) -> anyhow::Result<Vec<usize>> {
    match j.get(key) {
        None => Ok(Vec::new()),
        Some(Json::Arr(a)) => {
            anyhow::ensure!(
                a.len() <= max_entries,
                "`{key}` has {} entries (max {max_entries})",
                a.len()
            );
            a.iter()
                .map(|v| {
                    let n = as_usize_strict(v, &format!("entry in `{key}`"))?;
                    anyhow::ensure!(
                        (min_value..=max_value).contains(&n),
                        "entry {n} in `{key}` outside [{min_value}, {max_value}]"
                    );
                    Ok(n)
                })
                .collect()
        }
        Some(_) => Err(anyhow!("`{key}` must be an array of numbers")),
    }
}

fn parse_endpoints(
    j: &Json,
    profile_min_key: &str,
    lat_min_key: &str,
    profile_max_key: &str,
    lat_max_key: &str,
) -> anyhow::Result<EndpointProfiles> {
    Ok(EndpointProfiles {
        profile_min: parse_profile(j, profile_min_key)?,
        lat_min: req_positive(j, lat_min_key)?,
        profile_max: parse_profile(j, profile_max_key)?,
        lat_max: req_positive(j, lat_max_key)?,
    })
}

fn parse_query(j: &Json) -> anyhow::Result<SweepRequest> {
    let targets = match j.get("targets") {
        None => Vec::new(),
        Some(Json::Arr(a)) => {
            anyhow::ensure!(
                a.len() <= MAX_TARGET_ENTRIES,
                "`targets` has {} entries (max {MAX_TARGET_ENTRIES})",
                a.len()
            );
            a.iter()
                .map(|v| {
                    v.as_str()
                        .and_then(Instance::from_key)
                        .ok_or_else(|| anyhow!("unknown instance in `targets`"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?
        }
        Some(_) => anyhow::bail!("`targets` must be an array of instance keys"),
    };
    // any one pixel-endpoint field present requires the full quartet —
    // a partial set is a bad request, not a silently dropped axis
    let pixel_keys = [
        "profile_pmin",
        "anchor_lat_pmin",
        "profile_pmax",
        "anchor_lat_pmax",
    ];
    let pixel = if pixel_keys.iter().any(|k| j.get(k).is_some()) {
        Some(parse_endpoints(
            j,
            "profile_pmin",
            "anchor_lat_pmin",
            "profile_pmax",
            "anchor_lat_pmax",
        )?)
    } else {
        None
    };
    // batch/pixel values must stay inside the interpolation models'
    // fitted range (the paper grid) — anything outside would be served
    // as confident polynomial extrapolation
    let (bmin, bmax) = (BATCHES[0], BATCHES[4]);
    let (pmin, pmax) = (PIXELS[0], PIXELS[4]);
    let pixels = as_usize_strict(req_field(j, "pixels")?, "`pixels`")?;
    anyhow::ensure!(
        (pmin..=pmax).contains(&pixels),
        "`pixels` outside the modeled range [{pmin}, {pmax}]"
    );
    let pixel_sizes = parse_usize_list(j, "pixel_sizes", MAX_AXIS_ENTRIES, pmin, pmax)?;
    // a pixel size beyond the profiled one is only answerable with the
    // pixel-endpoint quartet — reject up front rather than silently
    // dropping the axis during the sweep
    if pixel.is_none() {
        anyhow::ensure!(
            pixel_sizes.iter().all(|&p| p == pixels),
            "`pixel_sizes` beyond the profiled `pixels` require the pixel-endpoint \
             fields (profile_pmin/anchor_lat_pmin/profile_pmax/anchor_lat_pmax)"
        );
    }
    let batches = parse_usize_list(j, "batches", MAX_AXIS_ENTRIES, bmin, bmax)?;
    let gpu_counts = parse_usize_list(j, "gpu_counts", MAX_GPU_ENTRIES, 1, MAX_GPUS)?;
    // bound the cross product (empty axes take their sweep defaults)
    let eff = |n: usize, default: usize| if n == 0 { default } else { n };
    let grid = eff(targets.len(), Instance::ALL.len())
        * eff(batches.len(), 5)
        * eff(pixel_sizes.len(), 1)
        * eff(gpu_counts.len(), 1)
        * 2;
    anyhow::ensure!(
        grid <= MAX_GRID_CANDIDATES,
        "candidate grid of {grid} exceeds {MAX_GRID_CANDIDATES} — shrink an axis"
    );
    Ok(SweepRequest {
        anchor: req_instance(j, "anchor")?,
        pixels,
        batch: parse_endpoints(
            j,
            "profile_bmin",
            "anchor_lat_bmin",
            "profile_bmax",
            "anchor_lat_bmax",
        )?,
        pixel,
        targets,
        batches,
        pixel_sizes,
        gpu_counts,
        include_spot: match j.get("include_spot") {
            None => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| anyhow!("`include_spot` must be a boolean"))?,
        },
    })
}
// lint: allow(hot-path-alloc) end

/// Required positive finite number (infinities from overflowing JSON
/// literals like `1e400` would otherwise flow into the planner and come
/// back out as unparseable `inf` tokens on the wire).
fn req_positive(j: &Json, key: &str) -> anyhow::Result<f64> {
    let v = j.req_f64(key)?;
    anyhow::ensure!(v.is_finite() && v > 0.0, "`{key}` must be positive and finite");
    Ok(v)
}

fn parse_plan(j: &Json) -> anyhow::Result<Request> {
    let query = parse_query(j)?;
    let job = TrainingJob {
        dataset_images: req_positive(j, "dataset_images")?,
        epochs: match j.get("epochs") {
            None => 1.0,
            Some(_) => req_positive(j, "epochs")?,
        },
    };
    let objective = match j.req_str("objective")? {
        "cheapest" => Objective::CheapestUnderDeadline {
            deadline_hours: req_positive(j, "deadline_hours")?,
        },
        "fastest" => Objective::FastestUnderBudget {
            budget_usd: req_positive(j, "budget_usd")?,
        },
        "max_epochs" => Objective::MaxEpochsUnderDeadline {
            deadline_hours: req_positive(j, "deadline_hours")?,
        },
        other => anyhow::bail!("unknown objective `{other}` (expected cheapest|fastest|max_epochs)"),
    };
    Ok(Request::Plan {
        query,
        job,
        objective,
    })
}

fn query_json(q: &SweepRequest, o: &mut Json) {
    o.set("anchor", Json::Str(q.anchor.key().into()));
    o.set("pixels", Json::Num(q.pixels as f64));
    o.set("profile_bmin", profile_json(&q.batch.profile_min));
    o.set("anchor_lat_bmin", Json::Num(q.batch.lat_min));
    o.set("profile_bmax", profile_json(&q.batch.profile_max));
    o.set("anchor_lat_bmax", Json::Num(q.batch.lat_max));
    if let Some(px) = &q.pixel {
        o.set("profile_pmin", profile_json(&px.profile_min));
        o.set("anchor_lat_pmin", Json::Num(px.lat_min));
        o.set("profile_pmax", profile_json(&px.profile_max));
        o.set("anchor_lat_pmax", Json::Num(px.lat_max));
    }
    if !q.targets.is_empty() {
        o.set(
            "targets",
            Json::Arr(q.targets.iter().map(|t| Json::Str(t.key().into())).collect()), // lint: allow(hot-path-alloc): DOM round-trip encoder for tests/clients, never on the serving path
        );
    }
    let usize_arr = |xs: &[usize]| Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect()); // lint: allow(hot-path-alloc): DOM round-trip encoder for tests/clients, never on the serving path
    if !q.batches.is_empty() {
        o.set("batches", usize_arr(&q.batches));
    }
    if !q.pixel_sizes.is_empty() {
        o.set("pixel_sizes", usize_arr(&q.pixel_sizes));
    }
    if !q.gpu_counts.is_empty() {
        o.set("gpu_counts", usize_arr(&q.gpu_counts));
    }
    o.set("include_spot", Json::Bool(q.include_spot));
}

/// One backend row in the route tier's `cluster_stats` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterBackend {
    pub addr: String,
    pub healthy: bool,
    /// Requests the router forwarded to (and got answered by) this
    /// backend.
    pub requests: u64,
}

/// One node's verdict in a route-tier fleet operation (`onboard`/
/// `reload` fan-out) failure report.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeReport {
    pub addr: String,
    /// The node's registry epoch after the operation; `None` when the
    /// node could not be reached (the key is omitted on the wire).
    pub epoch: Option<u64>,
    pub ok: bool,
    /// Empty when the node succeeded.
    pub error: String,
}

/// Service response — typed variants, encoded straight to the output
/// buffer (no DOM). Keys are emitted in sorted order, matching what the
/// old `BTreeMap`-backed serializer produced byte for byte.
#[derive(Debug, Clone)]
pub enum Response {
    /// `health` reply.
    Health,
    /// `stats` counters snapshot.
    Stats {
        requests: u64,
        artifact_batches: u64,
        avg_batch_fill: f64,
        overloaded: u64,
        predict_lanes: usize,
        cache_hits: u64,
        cache_misses: u64,
        /// Current model-registry epoch (starts at 1; bumps on every
        /// successful `onboard`/`reload`).
        registry_epoch: u64,
        /// Unix ms of the last successful post-boot publish; 0 = never.
        last_reload: u64,
        /// Connections currently owned by the reactor (gauge).
        open_conns: u64,
        /// Connections with an engine job in flight (gauge).
        active_conns: u64,
        /// `open_conns - active_conns` (gauge).
        idle_conns: u64,
        /// Lane replicas respawned by the supervisor after a panic
        /// (counter; 0 on a healthy process).
        lane_restarts: u64,
        /// Connections closed by the idle-timeout sweep (counter).
        evictions: u64,
        /// Peer cache hints accepted and inserted into the prediction
        /// cache (counter; stays 0 outside a routed cluster).
        hints_applied: u64,
        /// Reactor threads serving this listener.
        reactor_threads: u64,
        /// Seconds since the engine pool spawned.
        uptime_s: f64,
        /// Crate version serving this reply.
        version: &'static str,
    },
    /// `metrics` reply: full latency-observatory snapshot (boxed — this
    /// is a cold, allocating op by design and the variant would otherwise
    /// dominate the enum's size).
    Metrics(Box<MetricsSnapshot>),
    /// `instances` catalogue (payload derived from [`Instance::ALL`] at
    /// encode time — nothing to allocate or carry).
    Instances,
    /// Phase-1 `predict` reply.
    Prediction { latency_ms: f64, member: Member },
    /// Interpolation (`predict_batch_size`/`predict_pixel_size`) reply.
    Latency { latency_ms: f64 },
    /// `recommend` reply: ranked (candidate, on_frontier) rows plus
    /// full-set metadata.
    Recommend {
        candidates: Vec<(Candidate, bool)>,
        n_candidates: usize,
        frontier_size: usize,
    },
    /// `plan` reply.
    Plan {
        choice: (Candidate, bool),
        hours: f64,
        cost_usd: f64,
        epochs: f64,
        n_considered: usize,
    },
    /// `ingest` acknowledgement: the pair and its staged count so far.
    Ingested {
        anchor: Instance,
        target: Instance,
        staged: usize,
    },
    /// `onboard` success: the published epoch, pairs trained, and staged
    /// measurements consumed.
    Onboarded {
        epoch: u64,
        pairs: usize,
        staged: usize,
    },
    /// `reload` success (also the watcher's no-op answer): the current
    /// epoch after the call.
    Reloaded { epoch: u64 },
    /// `onboard` with `dry_run`: the candidate trained and passed the
    /// validation gate — nothing was published.
    OnboardCheck { pairs: usize, staged: usize },
    /// `reload` with `dry_run`: the on-disk candidate validated;
    /// `epoch` is the (unchanged) serving epoch.
    ReloadCheck { epoch: u64 },
    /// `hint` acknowledgement: whether the prediction entered this
    /// backend's cache (`false` = registry-epoch mismatch, dropped).
    HintApplied { applied: bool },
    /// Route-tier `cluster_stats` reply (encoded only by `repro route`;
    /// a plain backend answers `bad_request` instead).
    ClusterStats {
        /// Lines the router accepted from clients.
        requests: u64,
        /// Lines forwarded to (and answered by) a backend.
        forwarded: u64,
        /// Forwards that failed over to a lower-ranked ring owner.
        retries: u64,
        /// Health transitions healthy → ejected.
        ejections: u64,
        /// Health transitions ejected → healthy.
        rejoins: u64,
        /// Requests dropped because no healthy backend remained.
        no_backend: u64,
        /// Cache hints buffered for currently-ejected shard owners.
        hints_pending: u64,
        /// Cache hints replayed into rejoining shard owners.
        hints_replayed: u64,
        healthy_backends: usize,
        backends: Vec<ClusterBackend>,
    },
    /// Structured route-tier failure with a per-node report (a fleet
    /// publish where a node's validation gate rejected the candidate, or
    /// where the published epochs diverged).
    ClusterErr {
        kind: &'static str,
        msg: String,
        nodes: Vec<NodeReport>,
    },
    /// Generic error (engine/model failures).
    Err(String),
    /// Structured error with a stable machine-readable kind tag.
    ErrKind { kind: &'static str, msg: String },
}

impl Response {
    pub fn err_kind(kind: &'static str, msg: impl Into<String>) -> Response {
        Response::ErrKind {
            kind,
            msg: msg.into(),
        }
    }

    /// Route-tier error with a per-node report (see [`NodeReport`]).
    pub fn cluster_err(
        kind: &'static str,
        msg: impl Into<String>,
        nodes: Vec<NodeReport>,
    ) -> Response {
        Response::ClusterErr {
            kind,
            msg: msg.into(),
            nodes,
        }
    }

    /// Encode as one newline-terminated wire line into a reusable buffer
    /// (cleared first; capacity persists — a warm encode performs zero
    /// heap allocations). The buffer is handed straight to the socket
    /// write.
    pub fn encode_line(&self, out: &mut Vec<u8>) {
        out.clear();
        self.encode(out);
        out.push(b'\n');
    }

    /// Append the JSON body (no newline).
    pub fn encode(&self, out: &mut Vec<u8>) {
        let mut w = JsonWriter::new(out);
        match self {
            Response::Health => {
                w.begin_obj();
                w.key("ok").bool_(true);
                w.key("status").str_("healthy");
                w.end_obj();
            }
            Response::Stats {
                requests,
                artifact_batches,
                avg_batch_fill,
                overloaded,
                predict_lanes,
                cache_hits,
                cache_misses,
                registry_epoch,
                last_reload,
                open_conns,
                active_conns,
                idle_conns,
                lane_restarts,
                evictions,
                hints_applied,
                reactor_threads,
                uptime_s,
                version,
            } => {
                w.begin_obj();
                w.key("active_conns").num(*active_conns as f64);
                w.key("artifact_batches").num(*artifact_batches as f64);
                w.key("avg_batch_fill").num(*avg_batch_fill);
                w.key("cache_hits").num(*cache_hits as f64);
                w.key("cache_misses").num(*cache_misses as f64);
                w.key("evictions").num(*evictions as f64);
                w.key("hints_applied").num(*hints_applied as f64);
                w.key("idle_conns").num(*idle_conns as f64);
                w.key("lane_restarts").num(*lane_restarts as f64);
                w.key("last_reload").num(*last_reload as f64);
                w.key("ok").bool_(true);
                w.key("open_conns").num(*open_conns as f64);
                w.key("overloaded").num(*overloaded as f64);
                w.key("predict_lanes").num(*predict_lanes as f64);
                w.key("reactor_threads").num(*reactor_threads as f64);
                w.key("registry_epoch").num(*registry_epoch as f64);
                w.key("requests").num(*requests as f64);
                w.key("uptime_s").num(*uptime_s);
                w.key("version").str_(version);
                w.end_obj();
            }
            Response::Metrics(m) => {
                w.begin_obj();
                w.key("gauges").begin_obj();
                for (name, val) in &m.gauges {
                    w.key(name).num(*val);
                }
                w.end_obj();
                w.key("ok").bool_(true);
                w.key("slow_traces").begin_arr();
                for t in &m.slow {
                    w.begin_obj();
                    w.key("batch_assembly_ms").num(t.batch_assembly_ms);
                    w.key("completion_wait_ms").num(t.completion_wait_ms);
                    w.key("execute_ms").num(t.execute_ms);
                    w.key("op").str_(t.op);
                    w.key("parse_ms").num(t.parse_ms);
                    w.key("queue_wait_ms").num(t.queue_wait_ms);
                    w.key("seq").num(t.seq as f64);
                    w.key("temp").str_(t.temp);
                    w.key("total_ms").num(t.total_ms);
                    w.key("unattributed_ms").num(t.unattributed_ms);
                    w.end_obj();
                }
                w.end_arr();
                w.key("stages").begin_arr();
                for s in &m.stages {
                    w.begin_obj();
                    w.key("cells").begin_arr();
                    for c in &s.cells {
                        w.begin_obj();
                        w.key("buckets").begin_arr();
                        for (idx, n) in &c.buckets {
                            w.begin_arr();
                            w.num(*idx as f64);
                            w.num(*n as f64);
                            w.end_arr();
                        }
                        w.end_arr();
                        w.key("count").num(c.count as f64);
                        w.key("max_ms").num(c.max_ms);
                        w.key("op").str_(c.op);
                        w.key("p50_ms").num(c.p50_ms);
                        w.key("p90_ms").num(c.p90_ms);
                        w.key("p99_ms").num(c.p99_ms);
                        w.key("sum_ms").num(c.sum_ms);
                        w.key("temp").str_(c.temp);
                        w.end_obj();
                    }
                    w.end_arr();
                    w.key("stage").str_(s.stage);
                    w.end_obj();
                }
                w.end_arr();
                w.key("uptime_s").num(m.uptime_s);
                w.key("version").str_(env!("CARGO_PKG_VERSION"));
                w.end_obj();
            }
            Response::Instances => {
                w.begin_obj();
                w.key("instances").begin_arr();
                for i in Instance::ALL.iter().copied() {
                    w.begin_obj();
                    w.key("gpu").str_(i.spec().gpu_model);
                    w.key("key").str_(i.key());
                    w.key("price_hr").num(i.spec().price_hr);
                    w.end_obj();
                }
                w.end_arr();
                w.key("ok").bool_(true);
                w.end_obj();
            }
            Response::Prediction { latency_ms, member } => {
                w.begin_obj();
                w.key("latency_ms").num(*latency_ms);
                w.key("member").str_(member.name());
                w.key("ok").bool_(true);
                w.end_obj();
            }
            Response::Latency { latency_ms } => {
                w.begin_obj();
                w.key("latency_ms").num(*latency_ms);
                w.key("ok").bool_(true);
                w.end_obj();
            }
            Response::Recommend {
                candidates,
                n_candidates,
                frontier_size,
            } => {
                w.begin_obj();
                w.key("candidates").begin_arr();
                for (c, on_frontier) in candidates {
                    encode_candidate(&mut w, c, *on_frontier);
                }
                w.end_arr();
                w.key("frontier_size").num(*frontier_size as f64);
                w.key("n_candidates").num(*n_candidates as f64);
                w.key("ok").bool_(true);
                w.end_obj();
            }
            Response::Plan {
                choice,
                hours,
                cost_usd,
                epochs,
                n_considered,
            } => {
                w.begin_obj();
                w.key("choice");
                encode_candidate(&mut w, &choice.0, choice.1);
                w.key("cost_usd").num(*cost_usd);
                w.key("epochs").num(*epochs);
                w.key("hours").num(*hours);
                w.key("n_considered").num(*n_considered as f64);
                w.key("ok").bool_(true);
                w.end_obj();
            }
            Response::Ingested {
                anchor,
                target,
                staged,
            } => {
                w.begin_obj();
                w.key("anchor").str_(anchor.key());
                w.key("ok").bool_(true);
                w.key("staged").num(*staged as f64);
                w.key("target").str_(target.key());
                w.end_obj();
            }
            Response::Onboarded {
                epoch,
                pairs,
                staged,
            } => {
                w.begin_obj();
                w.key("epoch").num(*epoch as f64);
                w.key("ok").bool_(true);
                w.key("pairs").num(*pairs as f64);
                w.key("staged").num(*staged as f64);
                w.end_obj();
            }
            Response::Reloaded { epoch } => {
                w.begin_obj();
                w.key("epoch").num(*epoch as f64);
                w.key("ok").bool_(true);
                w.end_obj();
            }
            Response::OnboardCheck { pairs, staged } => {
                w.begin_obj();
                w.key("dry_run").bool_(true);
                w.key("ok").bool_(true);
                w.key("pairs").num(*pairs as f64);
                w.key("staged").num(*staged as f64);
                w.end_obj();
            }
            Response::ReloadCheck { epoch } => {
                w.begin_obj();
                w.key("dry_run").bool_(true);
                w.key("epoch").num(*epoch as f64);
                w.key("ok").bool_(true);
                w.end_obj();
            }
            Response::HintApplied { applied } => {
                w.begin_obj();
                w.key("applied").bool_(*applied);
                w.key("ok").bool_(true);
                w.end_obj();
            }
            Response::ClusterStats {
                requests,
                forwarded,
                retries,
                ejections,
                rejoins,
                no_backend,
                hints_pending,
                hints_replayed,
                healthy_backends,
                backends,
            } => {
                w.begin_obj();
                w.key("backends").begin_arr();
                for b in backends {
                    w.begin_obj();
                    w.key("addr").str_(&b.addr);
                    w.key("healthy").bool_(b.healthy);
                    w.key("requests").num(b.requests as f64);
                    w.end_obj();
                }
                w.end_arr();
                w.key("ejections").num(*ejections as f64);
                w.key("forwarded").num(*forwarded as f64);
                w.key("healthy_backends").num(*healthy_backends as f64);
                w.key("hints_pending").num(*hints_pending as f64);
                w.key("hints_replayed").num(*hints_replayed as f64);
                w.key("no_backend").num(*no_backend as f64);
                w.key("ok").bool_(true);
                w.key("rejoins").num(*rejoins as f64);
                w.key("requests").num(*requests as f64);
                w.key("retries").num(*retries as f64);
                w.end_obj();
            }
            Response::ClusterErr { kind, msg, nodes } => {
                w.begin_obj();
                w.key("error").str_(msg);
                w.key("kind").str_(kind);
                w.key("nodes").begin_arr();
                for n in nodes {
                    w.begin_obj();
                    w.key("addr").str_(&n.addr);
                    if let Some(e) = n.epoch {
                        w.key("epoch").num(e as f64);
                    }
                    w.key("error").str_(&n.error);
                    w.key("ok").bool_(n.ok);
                    w.end_obj();
                }
                w.end_arr();
                w.key("ok").bool_(false);
                w.end_obj();
            }
            Response::Err(msg) => {
                w.begin_obj();
                w.key("error").str_(msg);
                w.key("ok").bool_(false);
                w.end_obj();
            }
            Response::ErrKind { kind, msg } => {
                w.begin_obj();
                w.key("error").str_(msg);
                w.key("kind").str_(kind);
                w.key("ok").bool_(false);
                w.end_obj();
            }
        }
    }

    /// One line as an owned `String` (cold paths/tests; the serving loop
    /// uses [`Self::encode_line`] into a reused buffer instead).
    pub fn to_line(&self) -> String {
        let mut out = Vec::new(); // lint: allow(hot-path-alloc): cold convenience wrapper; the serving loop uses encode_line
        self.encode(&mut out);
        // lint: allow(unwrap-in-server): JsonWriter only ever emits ASCII/escaped UTF-8, so this is unreachable
        String::from_utf8(out).expect("encoder emits UTF-8")
    }
}

fn encode_candidate(w: &mut JsonWriter, c: &Candidate, on_frontier: bool) {
    w.begin_obj();
    w.key("batch").num(c.batch as f64);
    w.key("cost_per_img_usd").num(c.cost_per_img_usd);
    w.key("imgs_per_s").num(c.imgs_per_s);
    w.key("latency_ms").num(c.latency_ms);
    w.key("n_gpus").num(c.n_gpus as f64);
    w.key("on_frontier").bool_(on_frontier);
    w.key("pixels").num(c.pixels as f64);
    w.key("price_hr").num(c.price_hr);
    w.key("pricing").str_(c.pricing.key());
    w.key("target").str_(c.target.key());
    w.end_obj();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn sample_query(pixel: bool) -> SweepRequest {
        SweepRequest {
            anchor: Instance::G4dn,
            pixels: 64,
            batch: EndpointProfiles {
                profile_min: profile(&[("Conv2D", 80.5), ("Relu", 7.25)]),
                lat_min: 95.125,
                profile_max: profile(&[("Conv2D", 900.0), ("Relu", 80.0)]),
                lat_max: 1020.75,
            },
            pixel: pixel.then(|| EndpointProfiles {
                profile_min: profile(&[("Conv2D", 40.0)]),
                lat_min: 50.0,
                profile_max: profile(&[("Conv2D", 1200.0)]),
                lat_max: 1500.0,
            }),
            targets: vec![Instance::P3, Instance::G4dn],
            batches: vec![16, 64, 256],
            // non-profiled pixel sizes are only valid with pixel endpoints
            pixel_sizes: if pixel { vec![64, 128] } else { vec![64] },
            gpu_counts: vec![1, 2, 4],
            include_spot: true,
        }
    }

    fn roundtrip(req: &Request) {
        let line = req.to_json().to_string();
        let back = Request::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(&back, req, "{line}");
        // the DOM reference decoder agrees
        let dom = Request::parse_dom(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(&dom, req, "{line}");
    }

    #[test]
    fn parse_predict() {
        let line = r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":42.5,"profile":{"Conv2D":286,"Relu":26}}"#;
        let Request::Predict(p) = Request::parse(line).unwrap() else {
            panic!("wrong variant");
        };
        assert_eq!(p.anchor, Instance::G4dn);
        assert_eq!(p.target, Instance::P3);
        assert_eq!(p.profile["Conv2D"], 286.0);
    }

    #[test]
    fn roundtrip_every_variant() {
        roundtrip(&Request::Health);
        roundtrip(&Request::Stats);
        roundtrip(&Request::Metrics);
        roundtrip(&Request::Instances);
        roundtrip(&Request::Predict(PredictRequest {
            anchor: Instance::G4dn,
            target: Instance::P3,
            anchor_latency_ms: 42.625,
            profile: profile(&[("Conv2D", 286.0), ("Relu", 26.5)]),
        }));
        roundtrip(&Request::PredictBatchSize {
            instance: Instance::P3,
            batch: 64,
            t_min: 100.0,
            t_max: 900.5,
        });
        roundtrip(&Request::PredictPixelSize {
            instance: Instance::Ac1,
            pixels: 128,
            t_min: 10.25,
            t_max: 90.75,
        });
        // recommend: minimal (no optional axes) and maximal
        roundtrip(&Request::Recommend {
            query: SweepRequest {
                pixel: None,
                targets: vec![],
                batches: vec![],
                pixel_sizes: vec![],
                gpu_counts: vec![],
                include_spot: false,
                ..sample_query(false)
            },
            top_k: 0,
        });
        roundtrip(&Request::Recommend {
            query: sample_query(true),
            top_k: 8,
        });
        // plan: one per objective
        for objective in [
            Objective::CheapestUnderDeadline { deadline_hours: 4.5 },
            Objective::FastestUnderBudget { budget_usd: 12.25 },
            Objective::MaxEpochsUnderDeadline { deadline_hours: 2.0 },
        ] {
            roundtrip(&Request::Plan {
                query: sample_query(false),
                job: TrainingJob {
                    dataset_images: 50_000.0,
                    epochs: 10.0,
                },
                objective,
            });
        }
        // registry ops: ingest, onboard (targeted, catch-all, dry-run),
        // reload (live and dry-run)
        roundtrip(&Request::Ingest(sample_ingest()));
        roundtrip(&Request::Onboard {
            pair: Some((Instance::G4dn, Instance::G5)),
            dry_run: false,
        });
        roundtrip(&Request::Onboard {
            pair: None,
            dry_run: false,
        });
        roundtrip(&Request::Onboard {
            pair: Some((Instance::G4dn, Instance::G5)),
            dry_run: true,
        });
        roundtrip(&Request::Reload { dry_run: false });
        roundtrip(&Request::Reload { dry_run: true });
        // cluster ops: peer cache hint, route-tier stats
        roundtrip(&Request::Hint(sample_hint()));
        roundtrip(&Request::ClusterStats);
    }

    fn sample_hint() -> HintRequest {
        HintRequest {
            epoch: 3,
            anchor: Instance::G4dn,
            target: Instance::P3,
            anchor_latency_ms: 42.625,
            latency_ms: 87.5,
            member: Member::Forest,
            profile: profile(&[("Conv2D", 286.0), ("Relu", 26.5)]),
        }
    }

    fn sample_ingest() -> IngestRequest {
        IngestRequest {
            anchor: Instance::G4dn,
            target: Instance::G5,
            model: ModelId::from_name("VGG16").unwrap(),
            batch: 32,
            pixels: 64,
            profile: profile(&[("Conv2D", 80.5), ("Relu", 8.25)]),
            anchor_latency_ms: 120.5,
            target_latency_ms: 60.25,
        }
    }

    #[test]
    fn unknown_op_is_a_distinct_structured_error() {
        let err = Request::parse(r#"{"op":"nope"}"#).unwrap_err();
        assert!(matches!(&err, ParseError::UnknownOp(op) if op == "nope"));
        assert_eq!(err.kind(), "unknown_op");
        // malformed inputs report the other kind
        let err = Request::parse("not json").unwrap_err();
        assert!(matches!(err, ParseError::Malformed(_)));
        assert_eq!(err.kind(), "bad_request");
    }

    #[test]
    fn malformed_inputs_per_op() {
        for line in [
            // structural
            "not json",
            "{}",
            r#"{"op":42}"#,
            // predict
            r#"{"op":"predict","anchor":"zzz","target":"p3","anchor_latency_ms":1,"profile":{}}"#,
            r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":1}"#,
            r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":1,"profile":{"Conv2D":"x"}}"#,
            r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":-1,"profile":{"Conv2D":1}}"#,
            r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":1,"profile":{"Conv2D":1e400}}"#,
            // batch/pixel interpolation
            r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":100.0}"#,
            r#"{"op":"predict_batch_size","instance":"p3","batch":-1,"t_min":100.0,"t_max":900.0}"#,
            r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":1e400,"t_max":900.0}"#,
            r#"{"op":"predict_pixel_size","instance":"p9","pixels":64,"t_min":1,"t_max":2}"#,
            r#"{"op":"predict_pixel_size","instance":"p3","pixels":64.5,"t_min":1,"t_max":2}"#,
            // recommend: missing endpoints, bad endpoint sign, bad lists
            r#"{"op":"recommend","anchor":"g4dn","pixels":64}"#,
            // partial pixel-endpoint quartet is rejected, not dropped
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"anchor_lat_pmax":7}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":-5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"targets":["warp9"]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"batches":"all"}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"gpu_counts":[1,"two"]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"batches":[16.9]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"gpu_counts":[-2]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"top_k":-1}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"gpu_counts":[0]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"gpu_counts":[65]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"include_spot":"true"}"#,
            // values outside the interpolation models' fitted range
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"batches":[4096]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":16,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10}"#,
            // pixel sizes beyond the profiled size need the pixel quartet
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"pixel_sizes":[64,128]}"#,
            // plan: missing job, unknown objective, missing constraint,
            // non-finite constraint
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"objective":"cheapest","deadline_hours":1}"#,
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"dataset_images":1000,"objective":"cheapest","deadline_hours":1e400}"#,
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"dataset_images":1000,"epochs":1e400,"objective":"fastest","budget_usd":5}"#,
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"dataset_images":1000,"objective":"soonest","deadline_hours":1}"#,
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"dataset_images":1000,"objective":"fastest"}"#,
            // ingest: identity pair, unknown model, zero batch, missing
            // target latency, non-finite profile value
            r#"{"op":"ingest","anchor":"g4dn","target":"g4dn","model":"VGG16","batch":32,"pixels":64,"profile":{"Conv2D":1},"anchor_latency_ms":10,"target_latency_ms":5}"#,
            r#"{"op":"ingest","anchor":"g4dn","target":"g5","model":"NotANet","batch":32,"pixels":64,"profile":{"Conv2D":1},"anchor_latency_ms":10,"target_latency_ms":5}"#,
            r#"{"op":"ingest","anchor":"g4dn","target":"g5","model":"VGG16","batch":0,"pixels":64,"profile":{"Conv2D":1},"anchor_latency_ms":10,"target_latency_ms":5}"#,
            r#"{"op":"ingest","anchor":"g4dn","target":"g5","model":"VGG16","batch":32,"pixels":64,"profile":{"Conv2D":1},"anchor_latency_ms":10}"#,
            r#"{"op":"ingest","anchor":"g4dn","target":"g5","model":"VGG16","batch":32,"pixels":64,"profile":{"Conv2D":1e400},"anchor_latency_ms":10,"target_latency_ms":5}"#,
            // onboard: lone anchor, identity pair, unknown instance,
            // non-boolean dry_run (reload too)
            r#"{"op":"onboard","anchor":"g4dn"}"#,
            r#"{"op":"onboard","anchor":"g4dn","target":"g4dn"}"#,
            r#"{"op":"onboard","anchor":"g4dn","target":"warp9"}"#,
            r#"{"op":"onboard","dry_run":"yes"}"#,
            r#"{"op":"reload","dry_run":1}"#,
            // hint: identity pair, unknown member, missing epoch,
            // fractional epoch, non-positive latency, missing profile
            r#"{"op":"hint","anchor":"g4dn","target":"g4dn","member":"Linear","epoch":1,"anchor_latency_ms":10,"latency_ms":5,"profile":{"Conv2D":1}}"#,
            r#"{"op":"hint","anchor":"g4dn","target":"p3","member":"Oracle","epoch":1,"anchor_latency_ms":10,"latency_ms":5,"profile":{"Conv2D":1}}"#,
            r#"{"op":"hint","anchor":"g4dn","target":"p3","member":"Linear","anchor_latency_ms":10,"latency_ms":5,"profile":{"Conv2D":1}}"#,
            r#"{"op":"hint","anchor":"g4dn","target":"p3","member":"Linear","epoch":1.5,"anchor_latency_ms":10,"latency_ms":5,"profile":{"Conv2D":1}}"#,
            r#"{"op":"hint","anchor":"g4dn","target":"p3","member":"Linear","epoch":1,"anchor_latency_ms":10,"latency_ms":-5,"profile":{"Conv2D":1}}"#,
            r#"{"op":"hint","anchor":"g4dn","target":"p3","member":"Linear","epoch":1,"anchor_latency_ms":10,"latency_ms":5}"#,
        ] {
            let err = Request::parse(line).unwrap_err();
            assert!(
                matches!(err, ParseError::Malformed(_)),
                "expected Malformed for {line}, got {err:?}"
            );
            // the streaming decoder reports the DOM decoder's exact error
            let dom = Request::parse_dom(line).unwrap_err();
            assert_eq!(err.to_string(), dom.to_string(), "{line}");
        }
        // grid axes are length-capped (sweep-amplification guard)
        let big = vec!["16"; MAX_AXIS_ENTRIES + 1].join(",");
        let line = format!(
            r#"{{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{{"Conv2D":1}},"anchor_lat_bmin":5,"profile_bmax":{{"Conv2D":2}},"anchor_lat_bmax":10,"batches":[{big}]}}"#
        );
        assert!(matches!(
            Request::parse(&line).unwrap_err(),
            ParseError::Malformed(_)
        ));
        // ... and so is the cross product of individually-legal axes
        // (64 in-range batches x 16 gpu counts x default 6 targets x 2)
        let batches = (16..16 + MAX_AXIS_ENTRIES).map(|b| b.to_string()).collect::<Vec<_>>().join(",");
        let gpus = (1..=MAX_GPU_ENTRIES).map(|g| g.to_string()).collect::<Vec<_>>().join(",");
        let line = format!(
            r#"{{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{{"Conv2D":1}},"anchor_lat_bmin":5,"profile_bmax":{{"Conv2D":2}},"anchor_lat_bmax":10,"batches":[{batches}],"gpu_counts":[{gpus}]}}"#
        );
        assert!(matches!(
            Request::parse(&line).unwrap_err(),
            ParseError::Malformed(_)
        ));
    }

    #[test]
    fn response_lines() {
        let r = Response::Latency { latency_ms: 12.5 };
        assert!(r.to_line().contains("\"ok\":true"));
        let e = Response::Err("boom".into());
        assert!(e.to_line().contains("\"ok\":false"));
        let k = Response::err_kind("unknown_op", "unknown op `nope`");
        let line = k.to_line();
        assert!(line.contains("\"ok\":false"));
        assert!(line.contains("\"kind\":\"unknown_op\""));
        // encode_line clears, appends a newline, and matches to_line
        let mut buf = vec![1, 2, 3];
        k.encode_line(&mut buf);
        assert_eq!(buf, format!("{}\n", k.to_line()).into_bytes());
    }

    fn sample_candidate(i: usize) -> Candidate {
        Candidate {
            target: if i % 2 == 0 { Instance::P3 } else { Instance::G4dn },
            batch: 16 << (i % 3),
            pixels: 64,
            n_gpus: 1 + i % 4,
            pricing: if i % 2 == 0 {
                crate::sim::cost_model::Pricing::OnDemand
            } else {
                crate::sim::cost_model::Pricing::Spot
            },
            latency_ms: 100.5 + i as f64 * 3.25,
            imgs_per_s: 160.0 / (1.0 + i as f64),
            price_hr: 3.06 + i as f64 * 0.125,
            cost_per_img_usd: 5.3e-6 * (1.0 + i as f64),
        }
    }

    fn dom_candidate(c: &Candidate, on_frontier: bool) -> Json {
        let mut o = Json::obj();
        o.set("target", Json::Str(c.target.key().into()));
        o.set("batch", Json::Num(c.batch as f64));
        o.set("pixels", Json::Num(c.pixels as f64));
        o.set("n_gpus", Json::Num(c.n_gpus as f64));
        o.set("pricing", Json::Str(c.pricing.key().into()));
        o.set("latency_ms", Json::Num(c.latency_ms));
        o.set("imgs_per_s", Json::Num(c.imgs_per_s));
        o.set("price_hr", Json::Num(c.price_hr));
        o.set("cost_per_img_usd", Json::Num(c.cost_per_img_usd));
        o.set("on_frontier", Json::Bool(on_frontier));
        o
    }

    /// The acceptance bar for the encoder swap: for every protocol
    /// variant, the streaming encoder's bytes parse (via the DOM parser)
    /// to exactly the `Json` value the old DOM-built path produced — and
    /// since both sides share one float formatter and sorted key order,
    /// the bytes themselves match too.
    #[test]
    fn streaming_responses_equal_the_old_dom_built_values() {
        use crate::predictor::Member;
        let cands = vec![(sample_candidate(0), true), (sample_candidate(1), false)];
        let cases: Vec<(Response, Json)> = vec![
            (Response::Health, {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(true));
                o.set("status", Json::Str("healthy".into()));
                o
            }),
            (
                Response::Stats {
                    requests: 17,
                    artifact_batches: 3,
                    avg_batch_fill: 2.5,
                    overloaded: 1,
                    predict_lanes: 4,
                    cache_hits: 9,
                    cache_misses: 8,
                    registry_epoch: 2,
                    last_reload: 1_753_600_000_123,
                    open_conns: 21,
                    active_conns: 5,
                    idle_conns: 16,
                    lane_restarts: 1,
                    evictions: 7,
                    hints_applied: 6,
                    reactor_threads: 2,
                    uptime_s: 12.5,
                    version: env!("CARGO_PKG_VERSION"),
                },
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(true));
                    o.set("requests", Json::Num(17.0));
                    o.set("artifact_batches", Json::Num(3.0));
                    o.set("avg_batch_fill", Json::Num(2.5));
                    o.set("overloaded", Json::Num(1.0));
                    o.set("predict_lanes", Json::Num(4.0));
                    o.set("cache_hits", Json::Num(9.0));
                    o.set("cache_misses", Json::Num(8.0));
                    o.set("registry_epoch", Json::Num(2.0));
                    o.set("last_reload", Json::Num(1_753_600_000_123.0));
                    o.set("open_conns", Json::Num(21.0));
                    o.set("active_conns", Json::Num(5.0));
                    o.set("idle_conns", Json::Num(16.0));
                    o.set("lane_restarts", Json::Num(1.0));
                    o.set("evictions", Json::Num(7.0));
                    o.set("hints_applied", Json::Num(6.0));
                    o.set("reactor_threads", Json::Num(2.0));
                    o.set("uptime_s", Json::Num(12.5));
                    o.set("version", Json::Str(env!("CARGO_PKG_VERSION").into()));
                    o
                },
            ),
            (
                Response::Metrics(Box::new(MetricsSnapshot {
                    uptime_s: 3.25,
                    gauges: vec![("open_conns", 2.0), ("requests", 5.0)],
                    stages: vec![crate::obs::StageSummary {
                        stage: "execute",
                        cells: vec![crate::obs::CellSummary {
                            op: "predict",
                            temp: "cold",
                            count: 2,
                            sum_ms: 3.5,
                            p50_ms: 1.5,
                            p90_ms: 2.0,
                            p99_ms: 2.0,
                            max_ms: 2.0,
                            buckets: vec![(40, 1), (41, 1)],
                        }],
                    }],
                    slow: vec![crate::obs::TraceEntry {
                        seq: 9,
                        op: "recommend",
                        temp: "cold",
                        total_ms: 300.5,
                        parse_ms: 0.25,
                        queue_wait_ms: 10.0,
                        batch_assembly_ms: 0.0,
                        execute_ms: 289.0,
                        completion_wait_ms: 1.0,
                        unattributed_ms: 0.25,
                    }],
                })),
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(true));
                    o.set("uptime_s", Json::Num(3.25));
                    o.set("version", Json::Str(env!("CARGO_PKG_VERSION").into()));
                    o.set("gauges", {
                        let mut g = Json::obj();
                        g.set("open_conns", Json::Num(2.0));
                        g.set("requests", Json::Num(5.0));
                        g
                    });
                    o.set(
                        "stages",
                        Json::Arr(vec![{
                            let mut s = Json::obj();
                            s.set("stage", Json::Str("execute".into()));
                            s.set(
                                "cells",
                                Json::Arr(vec![{
                                    let mut c = Json::obj();
                                    c.set("op", Json::Str("predict".into()));
                                    c.set("temp", Json::Str("cold".into()));
                                    c.set("count", Json::Num(2.0));
                                    c.set("sum_ms", Json::Num(3.5));
                                    c.set("p50_ms", Json::Num(1.5));
                                    c.set("p90_ms", Json::Num(2.0));
                                    c.set("p99_ms", Json::Num(2.0));
                                    c.set("max_ms", Json::Num(2.0));
                                    c.set(
                                        "buckets",
                                        Json::Arr(vec![
                                            Json::Arr(vec![Json::Num(40.0), Json::Num(1.0)]),
                                            Json::Arr(vec![Json::Num(41.0), Json::Num(1.0)]),
                                        ]),
                                    );
                                    c
                                }]),
                            );
                            s
                        }]),
                    );
                    o.set(
                        "slow_traces",
                        Json::Arr(vec![{
                            let mut t = Json::obj();
                            t.set("seq", Json::Num(9.0));
                            t.set("op", Json::Str("recommend".into()));
                            t.set("temp", Json::Str("cold".into()));
                            t.set("total_ms", Json::Num(300.5));
                            t.set("parse_ms", Json::Num(0.25));
                            t.set("queue_wait_ms", Json::Num(10.0));
                            t.set("batch_assembly_ms", Json::Num(0.0));
                            t.set("execute_ms", Json::Num(289.0));
                            t.set("completion_wait_ms", Json::Num(1.0));
                            t.set("unattributed_ms", Json::Num(0.25));
                            t
                        }]),
                    );
                    o
                },
            ),
            (
                Response::Ingested {
                    anchor: Instance::G4dn,
                    target: Instance::G5,
                    staged: 12,
                },
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(true));
                    o.set("anchor", Json::Str("g4dn".into()));
                    o.set("target", Json::Str("g5".into()));
                    o.set("staged", Json::Num(12.0));
                    o
                },
            ),
            (
                Response::Onboarded {
                    epoch: 3,
                    pairs: 2,
                    staged: 48,
                },
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(true));
                    o.set("epoch", Json::Num(3.0));
                    o.set("pairs", Json::Num(2.0));
                    o.set("staged", Json::Num(48.0));
                    o
                },
            ),
            (Response::Reloaded { epoch: 4 }, {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(true));
                o.set("epoch", Json::Num(4.0));
                o
            }),
            (
                Response::OnboardCheck {
                    pairs: 2,
                    staged: 48,
                },
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(true));
                    o.set("dry_run", Json::Bool(true));
                    o.set("pairs", Json::Num(2.0));
                    o.set("staged", Json::Num(48.0));
                    o
                },
            ),
            (Response::ReloadCheck { epoch: 4 }, {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(true));
                o.set("dry_run", Json::Bool(true));
                o.set("epoch", Json::Num(4.0));
                o
            }),
            (Response::HintApplied { applied: false }, {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(true));
                o.set("applied", Json::Bool(false));
                o
            }),
            (
                Response::ClusterStats {
                    requests: 100,
                    forwarded: 97,
                    retries: 3,
                    ejections: 1,
                    rejoins: 1,
                    no_backend: 2,
                    hints_pending: 4,
                    hints_replayed: 9,
                    healthy_backends: 2,
                    backends: vec![
                        ClusterBackend {
                            addr: "127.0.0.1:7070".into(),
                            healthy: true,
                            requests: 60,
                        },
                        ClusterBackend {
                            addr: "127.0.0.1:7071".into(),
                            healthy: false,
                            requests: 37,
                        },
                    ],
                },
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(true));
                    o.set("requests", Json::Num(100.0));
                    o.set("forwarded", Json::Num(97.0));
                    o.set("retries", Json::Num(3.0));
                    o.set("ejections", Json::Num(1.0));
                    o.set("rejoins", Json::Num(1.0));
                    o.set("no_backend", Json::Num(2.0));
                    o.set("hints_pending", Json::Num(4.0));
                    o.set("hints_replayed", Json::Num(9.0));
                    o.set("healthy_backends", Json::Num(2.0));
                    o.set(
                        "backends",
                        Json::Arr(vec![
                            {
                                let mut b = Json::obj();
                                b.set("addr", Json::Str("127.0.0.1:7070".into()));
                                b.set("healthy", Json::Bool(true));
                                b.set("requests", Json::Num(60.0));
                                b
                            },
                            {
                                let mut b = Json::obj();
                                b.set("addr", Json::Str("127.0.0.1:7071".into()));
                                b.set("healthy", Json::Bool(false));
                                b.set("requests", Json::Num(37.0));
                                b
                            },
                        ]),
                    );
                    o
                },
            ),
            (
                Response::cluster_err(
                    "epoch_divergence",
                    "fleet publish diverged",
                    vec![
                        NodeReport {
                            addr: "127.0.0.1:7070".into(),
                            epoch: Some(3),
                            ok: true,
                            error: String::new(),
                        },
                        NodeReport {
                            addr: "127.0.0.1:7071".into(),
                            epoch: None,
                            ok: false,
                            error: "connection refused".into(),
                        },
                    ],
                ),
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(false));
                    o.set("kind", Json::Str("epoch_divergence".into()));
                    o.set("error", Json::Str("fleet publish diverged".into()));
                    o.set(
                        "nodes",
                        Json::Arr(vec![
                            {
                                let mut n = Json::obj();
                                n.set("addr", Json::Str("127.0.0.1:7070".into()));
                                n.set("epoch", Json::Num(3.0));
                                n.set("error", Json::Str(String::new()));
                                n.set("ok", Json::Bool(true));
                                n
                            },
                            {
                                let mut n = Json::obj();
                                n.set("addr", Json::Str("127.0.0.1:7071".into()));
                                n.set("error", Json::Str("connection refused".into()));
                                n.set("ok", Json::Bool(false));
                                n
                            },
                        ]),
                    );
                    o
                },
            ),
            (Response::Instances, {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(true));
                o.set(
                    "instances",
                    Json::Arr(
                        Instance::ALL
                            .iter()
                            .map(|i| {
                                let mut e = Json::obj();
                                e.set("key", Json::Str(i.key().into()));
                                e.set("gpu", Json::Str(i.spec().gpu_model.into()));
                                e.set("price_hr", Json::Num(i.spec().price_hr));
                                e
                            })
                            .collect(),
                    ),
                );
                o
            }),
            (
                Response::Prediction {
                    latency_ms: 123.456,
                    member: Member::Forest,
                },
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(true));
                    o.set("latency_ms", Json::Num(123.456));
                    o.set("member", Json::Str("RandomForest".into()));
                    o
                },
            ),
            (Response::Latency { latency_ms: 42.125 }, {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(true));
                o.set("latency_ms", Json::Num(42.125));
                o
            }),
            (
                Response::Recommend {
                    candidates: cands.clone(),
                    n_candidates: 60,
                    frontier_size: 7,
                },
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(true));
                    o.set(
                        "candidates",
                        Json::Arr(cands.iter().map(|(c, f)| dom_candidate(c, *f)).collect()),
                    );
                    o.set("n_candidates", Json::Num(60.0));
                    o.set("frontier_size", Json::Num(7.0));
                    o
                },
            ),
            (
                Response::Plan {
                    choice: (sample_candidate(2), true),
                    hours: 3.75,
                    cost_usd: 11.5,
                    epochs: 10.0,
                    n_considered: 60,
                },
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(true));
                    o.set("choice", dom_candidate(&sample_candidate(2), true));
                    o.set("hours", Json::Num(3.75));
                    o.set("cost_usd", Json::Num(11.5));
                    o.set("epochs", Json::Num(10.0));
                    o.set("n_considered", Json::Num(60.0));
                    o
                },
            ),
            (Response::Err("boom \"quoted\"\n".into()), {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(false));
                o.set("error", Json::Str("boom \"quoted\"\n".into()));
                o
            }),
            (
                Response::err_kind("overloaded", "engine queue is full — shed load and retry"),
                {
                    let mut o = Json::obj();
                    o.set("ok", Json::Bool(false));
                    o.set("kind", Json::Str("overloaded".into()));
                    o.set(
                        "error",
                        Json::Str("engine queue is full — shed load and retry".into()),
                    );
                    o
                },
            ),
        ];
        for (resp, expected) in cases {
            let line = resp.to_line();
            let parsed = Json::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(parsed, expected, "{line}");
            assert_eq!(line, expected.to_string(), "byte-level divergence");
        }
    }

    /// Every wire example in this module decodes identically through the
    /// streaming and DOM parsers (the heavy mutation fuzz lives in
    /// `tests/wire_differential.rs`).
    #[test]
    fn streaming_and_dom_decoders_agree_on_examples() {
        let mut lines: Vec<String> = vec![
            r#"{"op":"health"}"#.into(),
            r#"{"op":"stats"}"#.into(),
            r#"{"op":"metrics"}"#.into(),
            r#"{"op":"instances"}"#.into(),
            r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":42.5,"profile":{"Conv2D":286,"Relu":26}}"#.into(),
            // escaped field + profile keys, duplicate keys, odd spacing
            "{\"\\u006fp\":\"predict\",\"anchor\":\"g4dn\",\"target\":\"p3\",\"anchor_latency_ms\":1.5,\"profile\":{\"a\\tb\":1,\"a\\tb\":2,\"B\":3}}".into(),
            " { \"op\" : \"health\" } ".into(),
            r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":100.0,"t_max":900.5}"#.into(),
            r#"{"op":"predict_pixel_size","instance":"ac1","pixels":128,"t_min":10.25,"t_max":90.75}"#.into(),
            r#"{"op":"reload"}"#.into(),
            r#"{"op":"reload","dry_run":true}"#.into(),
            r#"{"op":"onboard"}"#.into(),
            r#"{"op":"onboard","anchor":"g4dn","target":"g5"}"#.into(),
            r#"{"op":"onboard","anchor":"g4dn","target":"g5","dry_run":true}"#.into(),
            r#"{"op":"cluster_stats"}"#.into(),
            r#"{"op":"hint","anchor":"g4dn","target":"p3","member":"RandomForest","epoch":2,"anchor_latency_ms":42.5,"latency_ms":87.25,"profile":{"Conv2D":286,"Relu":26}}"#.into(),
            r#"{"op":"ingest","anchor":"g4dn","target":"g5","model":"VGG16","batch":32,"pixels":64,"profile":{"Conv2D":80.5,"Relu":8.25},"anchor_latency_ms":120.5,"target_latency_ms":60.25}"#.into(),
        ];
        // roundtrip corpus: every variant's canonical serialization
        lines.push(
            Request::Recommend {
                query: sample_query(true),
                top_k: 8,
            }
            .to_json()
            .to_string(),
        );
        lines.push(
            Request::Plan {
                query: sample_query(false),
                job: TrainingJob {
                    dataset_images: 50_000.0,
                    epochs: 10.0,
                },
                objective: Objective::CheapestUnderDeadline { deadline_hours: 4.5 },
            }
            .to_json()
            .to_string(),
        );
        for line in &lines {
            match (Request::parse(line), Request::parse_dom(line)) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "{line}"),
                (a, b) => panic!("decoder divergence on {line}: {a:?} vs {b:?}"),
            }
        }
    }
}
