//! Wire protocol: newline-delimited JSON requests/responses.
//!
//! Request shapes (the `op` field dispatches):
//! ```json
//! {"op":"health"}
//! {"op":"stats"}
//! {"op":"instances"}
//! {"op":"predict","anchor":"g4dn","target":"p3",
//!  "anchor_latency_ms":123.4,"profile":{"Conv2D":286.0,"Relu":26.0}}
//! {"op":"predict_batch_size","instance":"p3","batch":64,
//!  "t_min":100.0,"t_max":900.0}
//! {"op":"predict_pixel_size","instance":"p3","pixels":128,
//!  "t_min":100.0,"t_max":900.0}
//! ```

use crate::gpu::Instance;
use crate::util::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// A phase-1 (cross-instance) prediction request.
#[derive(Debug, Clone)]
pub struct PredictRequest {
    pub anchor: Instance,
    pub target: Instance,
    pub anchor_latency_ms: f64,
    /// Aggregated (op name → ms) profile — the black-box feature payload.
    pub profile: BTreeMap<String, f64>,
}

/// Parsed request.
#[derive(Debug, Clone)]
pub enum Request {
    Health,
    /// Serving counters (requests, artifact batches).
    Stats,
    Instances,
    Predict(PredictRequest),
    PredictBatchSize {
        instance: Instance,
        batch: usize,
        t_min: f64,
        t_max: f64,
    },
    PredictPixelSize {
        instance: Instance,
        pixels: usize,
        t_min: f64,
        t_max: f64,
    },
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line)?;
        let op = j.req_str("op")?;
        let inst = |key: &str| -> Result<Instance> {
            Instance::from_key(j.req_str(key)?)
                .ok_or_else(|| anyhow!("unknown instance in `{key}`"))
        };
        Ok(match op {
            "health" => Request::Health,
            "stats" => Request::Stats,
            "instances" => Request::Instances,
            "predict" => {
                let mut profile = BTreeMap::new();
                match j.get("profile") {
                    Some(Json::Obj(m)) => {
                        for (k, v) in m {
                            profile.insert(
                                k.clone(),
                                v.as_f64().ok_or_else(|| anyhow!("profile value"))?,
                            );
                        }
                    }
                    _ => anyhow::bail!("missing profile object"),
                }
                Request::Predict(PredictRequest {
                    anchor: inst("anchor")?,
                    target: inst("target")?,
                    anchor_latency_ms: j.req_f64("anchor_latency_ms")?,
                    profile,
                })
            }
            "predict_batch_size" => Request::PredictBatchSize {
                instance: inst("instance")?,
                batch: j.req_usize("batch")?,
                t_min: j.req_f64("t_min")?,
                t_max: j.req_f64("t_max")?,
            },
            "predict_pixel_size" => Request::PredictPixelSize {
                instance: inst("instance")?,
                pixels: j.req_usize("pixels")?,
                t_min: j.req_f64("t_min")?,
                t_max: j.req_f64("t_max")?,
            },
            other => anyhow::bail!("unknown op `{other}`"),
        })
    }
}

/// Service response.
#[derive(Debug, Clone)]
pub enum Response {
    Ok(Json),
    Err(String),
}

impl Response {
    pub fn ok_obj(f: impl FnOnce(&mut Json)) -> Response {
        let mut o = Json::obj();
        o.set("ok", Json::Bool(true));
        f(&mut o);
        Response::Ok(o)
    }

    pub fn to_line(&self) -> String {
        match self {
            Response::Ok(j) => j.to_string(),
            Response::Err(msg) => {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(false));
                o.set("error", Json::Str(msg.clone()));
                o.to_string()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_predict() {
        let line = r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":42.5,"profile":{"Conv2D":286,"Relu":26}}"#;
        let Request::Predict(p) = Request::parse(line).unwrap() else {
            panic!("wrong variant");
        };
        assert_eq!(p.anchor, Instance::G4dn);
        assert_eq!(p.target, Instance::P3);
        assert_eq!(p.profile["Conv2D"], 286.0);
    }

    #[test]
    fn parse_rejects_bad_ops() {
        assert!(Request::parse(r#"{"op":"nope"}"#).is_err());
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"op":"predict","anchor":"zzz","target":"p3","anchor_latency_ms":1,"profile":{}}"#).is_err());
    }

    #[test]
    fn response_lines() {
        let r = Response::ok_obj(|o| {
            o.set("latency_ms", crate::util::Json::Num(12.5));
        });
        assert!(r.to_line().contains("\"ok\":true"));
        let e = Response::Err("boom".into());
        assert!(e.to_line().contains("\"ok\":false"));
    }
}
