//! Wire protocol: newline-delimited JSON requests/responses.
//!
//! | op | request fields | reply fields |
//! |----|----------------|--------------|
//! | `health` | — | `status` |
//! | `stats` | — | `requests`, `artifact_batches`, `avg_batch_fill`, `overloaded`, `predict_lanes`, `cache_hits`, `cache_misses` |
//! | `instances` | — | `instances[]` (key, gpu, price_hr) |
//! | `predict` | `anchor`, `target`, `anchor_latency_ms`, `profile` | `latency_ms`, `member` |
//! | `predict_batch_size` | `instance`, `batch`, `t_min`, `t_max` | `latency_ms` |
//! | `predict_pixel_size` | `instance`, `pixels`, `t_min`, `t_max` | `latency_ms` |
//! | `recommend` | `anchor`, `pixels`, `profile_bmin`/`anchor_lat_bmin`, `profile_bmax`/`anchor_lat_bmax`, optional `profile_pmin`/`anchor_lat_pmin`/`profile_pmax`/`anchor_lat_pmax`, optional `targets[]`, `batches[]`, `pixel_sizes[]`, `gpu_counts[]`, `include_spot`, `top_k` | `candidates[]` (each with `on_frontier`), `n_candidates`, `frontier_size` |
//! | `plan` | `recommend` fields + `objective` (`cheapest`\|`fastest`\|`max_epochs`), `dataset_images`, `epochs`, `deadline_hours`\|`budget_usd` | `choice`, `hours`, `cost_usd`, `epochs`, `n_considered` |
//!
//! Example request lines:
//! ```json
//! {"op":"predict","anchor":"g4dn","target":"p3",
//!  "anchor_latency_ms":123.4,"profile":{"Conv2D":286.0,"Relu":26.0}}
//! {"op":"recommend","anchor":"g4dn","pixels":64,
//!  "profile_bmin":{"Conv2D":80.0},"anchor_lat_bmin":95.0,
//!  "profile_bmax":{"Conv2D":900.0},"anchor_lat_bmax":1020.0,
//!  "gpu_counts":[1,2],"include_spot":true,"top_k":8}
//! {"op":"plan","anchor":"g4dn","pixels":64,
//!  "profile_bmin":{"Conv2D":80.0},"anchor_lat_bmin":95.0,
//!  "profile_bmax":{"Conv2D":900.0},"anchor_lat_bmax":1020.0,
//!  "objective":"cheapest","deadline_hours":4.0,
//!  "dataset_images":50000,"epochs":10}
//! ```
//!
//! `recommend.top_k` is optional; `0` (also the default when the field is
//! absent) is the documented "return every ranked candidate" sentinel —
//! nonzero values truncate after ranking, while `n_candidates` /
//! `frontier_size` / `on_frontier` always describe the full candidate set.
//!
//! Errors are structured, never silent: every rejected line gets
//! `{"ok":false,"kind":...,"error":...}` — `kind` is `unknown_op` for an
//! unrecognized `op` value and `bad_request` for malformed payloads.
//! Under load shedding the service answers `kind:"overloaded"` (full
//! engine-lane queue, or a connection past the server's budget) — the
//! request was NOT executed and should be retried with backoff.

use crate::advisor::{EndpointProfiles, Objective, SweepRequest, TrainingJob};
use crate::gpu::Instance;
use crate::sim::workload::{BATCHES, PIXELS};
use crate::util::Json;
use anyhow::anyhow;
use std::collections::BTreeMap;
use std::fmt;

/// A phase-1 (cross-instance) prediction request.
#[derive(Debug, Clone, PartialEq)]
pub struct PredictRequest {
    pub anchor: Instance,
    pub target: Instance,
    pub anchor_latency_ms: f64,
    /// Aggregated (op name → ms) profile — the black-box feature payload.
    pub profile: BTreeMap<String, f64>,
}

/// Parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Health,
    /// Serving counters (requests, artifact batches, cache hits/misses).
    Stats,
    Instances,
    Predict(PredictRequest),
    PredictBatchSize {
        instance: Instance,
        batch: usize,
        t_min: f64,
        t_max: f64,
    },
    PredictPixelSize {
        instance: Instance,
        pixels: usize,
        t_min: f64,
        t_max: f64,
    },
    /// Advisor sweep + Pareto ranking. `top_k == 0` (the default) is the
    /// documented "return everything" sentinel; nonzero truncates the
    /// ranked list (full-set metadata fields are unaffected).
    Recommend { query: SweepRequest, top_k: usize },
    /// Advisor sweep + constrained planning.
    Plan {
        query: SweepRequest,
        job: TrainingJob,
        objective: Objective,
    },
}

/// Why a request line was rejected. `UnknownOp` is split out so the
/// service can answer with a distinct structured error instead of a
/// generic parse failure (or worse, a silent drop).
#[derive(Debug)]
pub enum ParseError {
    UnknownOp(String),
    Malformed(anyhow::Error),
}

impl ParseError {
    /// Stable error-kind tag for the wire (`{"ok":false,"kind":...}`).
    pub fn kind(&self) -> &'static str {
        match self {
            ParseError::UnknownOp(_) => "unknown_op",
            ParseError::Malformed(_) => "bad_request",
        }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParseError::UnknownOp(op) => write!(f, "unknown op `{op}`"),
            ParseError::Malformed(e) => write!(f, "{e:#}"),
        }
    }
}

impl std::error::Error for ParseError {}

impl Request {
    pub fn parse(line: &str) -> Result<Request, ParseError> {
        let j = Json::parse(line).map_err(ParseError::Malformed)?;
        let op = j.req_str("op").map_err(ParseError::Malformed)?;
        match parse_fields(op, &j) {
            Ok(Some(req)) => Ok(req),
            Ok(None) => Err(ParseError::UnknownOp(op.to_string())),
            Err(e) => Err(ParseError::Malformed(e)),
        }
    }

    /// Serialize back to the wire object (`parse` ∘ `to_json` is identity —
    /// covered by the round-trip tests).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            Request::Health => {
                o.set("op", Json::Str("health".into()));
            }
            Request::Stats => {
                o.set("op", Json::Str("stats".into()));
            }
            Request::Instances => {
                o.set("op", Json::Str("instances".into()));
            }
            Request::Predict(p) => {
                o.set("op", Json::Str("predict".into()));
                o.set("anchor", Json::Str(p.anchor.key().into()));
                o.set("target", Json::Str(p.target.key().into()));
                o.set("anchor_latency_ms", Json::Num(p.anchor_latency_ms));
                o.set("profile", profile_json(&p.profile));
            }
            Request::PredictBatchSize {
                instance,
                batch,
                t_min,
                t_max,
            } => {
                o.set("op", Json::Str("predict_batch_size".into()));
                o.set("instance", Json::Str(instance.key().into()));
                o.set("batch", Json::Num(*batch as f64));
                o.set("t_min", Json::Num(*t_min));
                o.set("t_max", Json::Num(*t_max));
            }
            Request::PredictPixelSize {
                instance,
                pixels,
                t_min,
                t_max,
            } => {
                o.set("op", Json::Str("predict_pixel_size".into()));
                o.set("instance", Json::Str(instance.key().into()));
                o.set("pixels", Json::Num(*pixels as f64));
                o.set("t_min", Json::Num(*t_min));
                o.set("t_max", Json::Num(*t_max));
            }
            Request::Recommend { query, top_k } => {
                o.set("op", Json::Str("recommend".into()));
                query_json(query, &mut o);
                o.set("top_k", Json::Num(*top_k as f64));
            }
            Request::Plan {
                query,
                job,
                objective,
            } => {
                o.set("op", Json::Str("plan".into()));
                query_json(query, &mut o);
                o.set("dataset_images", Json::Num(job.dataset_images));
                o.set("epochs", Json::Num(job.epochs));
                match *objective {
                    Objective::CheapestUnderDeadline { deadline_hours } => {
                        o.set("objective", Json::Str("cheapest".into()));
                        o.set("deadline_hours", Json::Num(deadline_hours));
                    }
                    Objective::FastestUnderBudget { budget_usd } => {
                        o.set("objective", Json::Str("fastest".into()));
                        o.set("budget_usd", Json::Num(budget_usd));
                    }
                    Objective::MaxEpochsUnderDeadline { deadline_hours } => {
                        o.set("objective", Json::Str("max_epochs".into()));
                        o.set("deadline_hours", Json::Num(deadline_hours));
                    }
                }
            }
        }
        o
    }
}

/// Field parsing: the single known-op list. `Ok(None)` means the op is
/// not recognized (surfaced as `unknown_op`); field errors are plain
/// `bad_request` errors.
fn parse_fields(op: &str, j: &Json) -> anyhow::Result<Option<Request>> {
    Ok(Some(match op {
        "health" => Request::Health,
        "stats" => Request::Stats,
        "instances" => Request::Instances,
        "predict" => parse_predict(j)?,
        "predict_batch_size" => Request::PredictBatchSize {
            instance: req_instance(j, "instance")?,
            batch: as_usize_strict(req_field(j, "batch")?, "`batch`")?,
            t_min: req_positive(j, "t_min")?,
            t_max: req_positive(j, "t_max")?,
        },
        "predict_pixel_size" => Request::PredictPixelSize {
            instance: req_instance(j, "instance")?,
            pixels: as_usize_strict(req_field(j, "pixels")?, "`pixels`")?,
            t_min: req_positive(j, "t_min")?,
            t_max: req_positive(j, "t_max")?,
        },
        "recommend" => Request::Recommend {
            query: parse_query(j)?,
            top_k: match j.get("top_k") {
                None => 0,
                Some(v) => as_usize_strict(v, "`top_k`")?,
            },
        },
        "plan" => parse_plan(j)?,
        _ => return Ok(None),
    }))
}

fn req_field<'a>(j: &'a Json, key: &str) -> anyhow::Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("missing `{key}`"))
}

fn req_instance(j: &Json, key: &str) -> anyhow::Result<Instance> {
    Instance::from_key(j.req_str(key)?).ok_or_else(|| anyhow!("unknown instance in `{key}`"))
}

fn parse_profile(j: &Json, key: &str) -> anyhow::Result<BTreeMap<String, f64>> {
    match j.get(key) {
        Some(Json::Obj(m)) => {
            let mut profile = BTreeMap::new();
            for (k, v) in m {
                let ms = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("non-number profile value in `{key}`"))?;
                // non-finite values would alias in the prediction-cache
                // key quantization (and are meaningless as op times)
                anyhow::ensure!(ms.is_finite(), "non-finite profile value in `{key}`");
                profile.insert(k.clone(), ms);
            }
            Ok(profile)
        }
        _ => Err(anyhow!("missing profile object `{key}`")),
    }
}

fn profile_json(profile: &BTreeMap<String, f64>) -> Json {
    let mut o = Json::obj();
    for (k, v) in profile {
        o.set(k, Json::Num(*v));
    }
    o
}

fn parse_predict(j: &Json) -> anyhow::Result<Request> {
    Ok(Request::Predict(PredictRequest {
        anchor: req_instance(j, "anchor")?,
        target: req_instance(j, "target")?,
        anchor_latency_ms: req_positive(j, "anchor_latency_ms")?,
        profile: parse_profile(j, "profile")?,
    }))
}

/// Grid-axis sanity caps: the sweep expands `batches × pixel_sizes ×
/// gpu_counts × pricing` candidates per target, so one request must not
/// be able to ask for an astronomically large grid (the line-length cap
/// in `server.rs` bounds bytes; these bound the *amplification*).
const MAX_AXIS_ENTRIES: usize = 64;
const MAX_GPU_ENTRIES: usize = 16;
const MAX_GPUS: usize = 64;
const MAX_TARGET_ENTRIES: usize = 32;
/// Per-axis caps bound entries, not their cross product — this bounds the
/// number of candidates one sweep may expand to (the paper-grid default is
/// 6 targets × 5 batches × 1 pixel × 1 gpu × 2 pricing = 60).
const MAX_GRID_CANDIDATES: usize = 4096;

/// Strict non-negative-integer read: rejects fractional and negative
/// values instead of silently truncating/saturating them.
fn as_usize_strict(v: &Json, what: &str) -> anyhow::Result<usize> {
    let n = v
        .as_f64()
        .ok_or_else(|| anyhow!("non-number {what}"))?;
    anyhow::ensure!(
        n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64,
        "{what} must be a non-negative integer"
    );
    Ok(n as usize)
}

fn parse_usize_list(
    j: &Json,
    key: &str,
    max_entries: usize,
    min_value: usize,
    max_value: usize,
) -> anyhow::Result<Vec<usize>> {
    match j.get(key) {
        None => Ok(Vec::new()),
        Some(Json::Arr(a)) => {
            anyhow::ensure!(
                a.len() <= max_entries,
                "`{key}` has {} entries (max {max_entries})",
                a.len()
            );
            a.iter()
                .map(|v| {
                    let n = as_usize_strict(v, &format!("entry in `{key}`"))?;
                    anyhow::ensure!(
                        (min_value..=max_value).contains(&n),
                        "entry {n} in `{key}` outside [{min_value}, {max_value}]"
                    );
                    Ok(n)
                })
                .collect()
        }
        Some(_) => Err(anyhow!("`{key}` must be an array of numbers")),
    }
}

fn parse_endpoints(
    j: &Json,
    profile_min_key: &str,
    lat_min_key: &str,
    profile_max_key: &str,
    lat_max_key: &str,
) -> anyhow::Result<EndpointProfiles> {
    Ok(EndpointProfiles {
        profile_min: parse_profile(j, profile_min_key)?,
        lat_min: req_positive(j, lat_min_key)?,
        profile_max: parse_profile(j, profile_max_key)?,
        lat_max: req_positive(j, lat_max_key)?,
    })
}

fn parse_query(j: &Json) -> anyhow::Result<SweepRequest> {
    let targets = match j.get("targets") {
        None => Vec::new(),
        Some(Json::Arr(a)) => {
            anyhow::ensure!(
                a.len() <= MAX_TARGET_ENTRIES,
                "`targets` has {} entries (max {MAX_TARGET_ENTRIES})",
                a.len()
            );
            a.iter()
                .map(|v| {
                    v.as_str()
                        .and_then(Instance::from_key)
                        .ok_or_else(|| anyhow!("unknown instance in `targets`"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?
        }
        Some(_) => anyhow::bail!("`targets` must be an array of instance keys"),
    };
    // any one pixel-endpoint field present requires the full quartet —
    // a partial set is a bad request, not a silently dropped axis
    let pixel_keys = [
        "profile_pmin",
        "anchor_lat_pmin",
        "profile_pmax",
        "anchor_lat_pmax",
    ];
    let pixel = if pixel_keys.iter().any(|k| j.get(k).is_some()) {
        Some(parse_endpoints(
            j,
            "profile_pmin",
            "anchor_lat_pmin",
            "profile_pmax",
            "anchor_lat_pmax",
        )?)
    } else {
        None
    };
    // batch/pixel values must stay inside the interpolation models'
    // fitted range (the paper grid) — anything outside would be served
    // as confident polynomial extrapolation
    let (bmin, bmax) = (BATCHES[0], BATCHES[4]);
    let (pmin, pmax) = (PIXELS[0], PIXELS[4]);
    let pixels = as_usize_strict(req_field(j, "pixels")?, "`pixels`")?;
    anyhow::ensure!(
        (pmin..=pmax).contains(&pixels),
        "`pixels` outside the modeled range [{pmin}, {pmax}]"
    );
    let pixel_sizes = parse_usize_list(j, "pixel_sizes", MAX_AXIS_ENTRIES, pmin, pmax)?;
    // a pixel size beyond the profiled one is only answerable with the
    // pixel-endpoint quartet — reject up front rather than silently
    // dropping the axis during the sweep
    if pixel.is_none() {
        anyhow::ensure!(
            pixel_sizes.iter().all(|&p| p == pixels),
            "`pixel_sizes` beyond the profiled `pixels` require the pixel-endpoint \
             fields (profile_pmin/anchor_lat_pmin/profile_pmax/anchor_lat_pmax)"
        );
    }
    let batches = parse_usize_list(j, "batches", MAX_AXIS_ENTRIES, bmin, bmax)?;
    let gpu_counts = parse_usize_list(j, "gpu_counts", MAX_GPU_ENTRIES, 1, MAX_GPUS)?;
    // bound the cross product (empty axes take their sweep defaults)
    let eff = |n: usize, default: usize| if n == 0 { default } else { n };
    let grid = eff(targets.len(), Instance::ALL.len())
        * eff(batches.len(), 5)
        * eff(pixel_sizes.len(), 1)
        * eff(gpu_counts.len(), 1)
        * 2;
    anyhow::ensure!(
        grid <= MAX_GRID_CANDIDATES,
        "candidate grid of {grid} exceeds {MAX_GRID_CANDIDATES} — shrink an axis"
    );
    Ok(SweepRequest {
        anchor: req_instance(j, "anchor")?,
        pixels,
        batch: parse_endpoints(
            j,
            "profile_bmin",
            "anchor_lat_bmin",
            "profile_bmax",
            "anchor_lat_bmax",
        )?,
        pixel,
        targets,
        batches,
        pixel_sizes,
        gpu_counts,
        include_spot: match j.get("include_spot") {
            None => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| anyhow!("`include_spot` must be a boolean"))?,
        },
    })
}

/// Required positive finite number (infinities from overflowing JSON
/// literals like `1e400` would otherwise flow into the planner and come
/// back out as unparseable `inf` tokens on the wire).
fn req_positive(j: &Json, key: &str) -> anyhow::Result<f64> {
    let v = j.req_f64(key)?;
    anyhow::ensure!(v.is_finite() && v > 0.0, "`{key}` must be positive and finite");
    Ok(v)
}

fn parse_plan(j: &Json) -> anyhow::Result<Request> {
    let query = parse_query(j)?;
    let job = TrainingJob {
        dataset_images: req_positive(j, "dataset_images")?,
        epochs: match j.get("epochs") {
            None => 1.0,
            Some(_) => req_positive(j, "epochs")?,
        },
    };
    let objective = match j.req_str("objective")? {
        "cheapest" => Objective::CheapestUnderDeadline {
            deadline_hours: req_positive(j, "deadline_hours")?,
        },
        "fastest" => Objective::FastestUnderBudget {
            budget_usd: req_positive(j, "budget_usd")?,
        },
        "max_epochs" => Objective::MaxEpochsUnderDeadline {
            deadline_hours: req_positive(j, "deadline_hours")?,
        },
        other => anyhow::bail!("unknown objective `{other}` (expected cheapest|fastest|max_epochs)"),
    };
    Ok(Request::Plan {
        query,
        job,
        objective,
    })
}

fn query_json(q: &SweepRequest, o: &mut Json) {
    o.set("anchor", Json::Str(q.anchor.key().into()));
    o.set("pixels", Json::Num(q.pixels as f64));
    o.set("profile_bmin", profile_json(&q.batch.profile_min));
    o.set("anchor_lat_bmin", Json::Num(q.batch.lat_min));
    o.set("profile_bmax", profile_json(&q.batch.profile_max));
    o.set("anchor_lat_bmax", Json::Num(q.batch.lat_max));
    if let Some(px) = &q.pixel {
        o.set("profile_pmin", profile_json(&px.profile_min));
        o.set("anchor_lat_pmin", Json::Num(px.lat_min));
        o.set("profile_pmax", profile_json(&px.profile_max));
        o.set("anchor_lat_pmax", Json::Num(px.lat_max));
    }
    if !q.targets.is_empty() {
        o.set(
            "targets",
            Json::Arr(q.targets.iter().map(|t| Json::Str(t.key().into())).collect()),
        );
    }
    let usize_arr = |xs: &[usize]| Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect());
    if !q.batches.is_empty() {
        o.set("batches", usize_arr(&q.batches));
    }
    if !q.pixel_sizes.is_empty() {
        o.set("pixel_sizes", usize_arr(&q.pixel_sizes));
    }
    if !q.gpu_counts.is_empty() {
        o.set("gpu_counts", usize_arr(&q.gpu_counts));
    }
    o.set("include_spot", Json::Bool(q.include_spot));
}

/// Service response.
#[derive(Debug, Clone)]
pub enum Response {
    Ok(Json),
    /// Generic error (engine/model failures).
    Err(String),
    /// Structured error with a stable machine-readable kind tag.
    ErrKind { kind: &'static str, msg: String },
}

impl Response {
    pub fn ok_obj(f: impl FnOnce(&mut Json)) -> Response {
        let mut o = Json::obj();
        o.set("ok", Json::Bool(true));
        f(&mut o);
        Response::Ok(o)
    }

    pub fn err_kind(kind: &'static str, msg: impl Into<String>) -> Response {
        Response::ErrKind {
            kind,
            msg: msg.into(),
        }
    }

    pub fn to_line(&self) -> String {
        match self {
            Response::Ok(j) => j.to_string(),
            Response::Err(msg) => {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(false));
                o.set("error", Json::Str(msg.clone()));
                o.to_string()
            }
            Response::ErrKind { kind, msg } => {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(false));
                o.set("kind", Json::Str((*kind).into()));
                o.set("error", Json::Str(msg.clone()));
                o.to_string()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn sample_query(pixel: bool) -> SweepRequest {
        SweepRequest {
            anchor: Instance::G4dn,
            pixels: 64,
            batch: EndpointProfiles {
                profile_min: profile(&[("Conv2D", 80.5), ("Relu", 7.25)]),
                lat_min: 95.125,
                profile_max: profile(&[("Conv2D", 900.0), ("Relu", 80.0)]),
                lat_max: 1020.75,
            },
            pixel: pixel.then(|| EndpointProfiles {
                profile_min: profile(&[("Conv2D", 40.0)]),
                lat_min: 50.0,
                profile_max: profile(&[("Conv2D", 1200.0)]),
                lat_max: 1500.0,
            }),
            targets: vec![Instance::P3, Instance::G4dn],
            batches: vec![16, 64, 256],
            // non-profiled pixel sizes are only valid with pixel endpoints
            pixel_sizes: if pixel { vec![64, 128] } else { vec![64] },
            gpu_counts: vec![1, 2, 4],
            include_spot: true,
        }
    }

    fn roundtrip(req: &Request) {
        let line = req.to_json().to_string();
        let back = Request::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(&back, req, "{line}");
    }

    #[test]
    fn parse_predict() {
        let line = r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":42.5,"profile":{"Conv2D":286,"Relu":26}}"#;
        let Request::Predict(p) = Request::parse(line).unwrap() else {
            panic!("wrong variant");
        };
        assert_eq!(p.anchor, Instance::G4dn);
        assert_eq!(p.target, Instance::P3);
        assert_eq!(p.profile["Conv2D"], 286.0);
    }

    #[test]
    fn roundtrip_every_variant() {
        roundtrip(&Request::Health);
        roundtrip(&Request::Stats);
        roundtrip(&Request::Instances);
        roundtrip(&Request::Predict(PredictRequest {
            anchor: Instance::G4dn,
            target: Instance::P3,
            anchor_latency_ms: 42.625,
            profile: profile(&[("Conv2D", 286.0), ("Relu", 26.5)]),
        }));
        roundtrip(&Request::PredictBatchSize {
            instance: Instance::P3,
            batch: 64,
            t_min: 100.0,
            t_max: 900.5,
        });
        roundtrip(&Request::PredictPixelSize {
            instance: Instance::Ac1,
            pixels: 128,
            t_min: 10.25,
            t_max: 90.75,
        });
        // recommend: minimal (no optional axes) and maximal
        roundtrip(&Request::Recommend {
            query: SweepRequest {
                pixel: None,
                targets: vec![],
                batches: vec![],
                pixel_sizes: vec![],
                gpu_counts: vec![],
                include_spot: false,
                ..sample_query(false)
            },
            top_k: 0,
        });
        roundtrip(&Request::Recommend {
            query: sample_query(true),
            top_k: 8,
        });
        // plan: one per objective
        for objective in [
            Objective::CheapestUnderDeadline { deadline_hours: 4.5 },
            Objective::FastestUnderBudget { budget_usd: 12.25 },
            Objective::MaxEpochsUnderDeadline { deadline_hours: 2.0 },
        ] {
            roundtrip(&Request::Plan {
                query: sample_query(false),
                job: TrainingJob {
                    dataset_images: 50_000.0,
                    epochs: 10.0,
                },
                objective,
            });
        }
    }

    #[test]
    fn unknown_op_is_a_distinct_structured_error() {
        let err = Request::parse(r#"{"op":"nope"}"#).unwrap_err();
        assert!(matches!(&err, ParseError::UnknownOp(op) if op == "nope"));
        assert_eq!(err.kind(), "unknown_op");
        // malformed inputs report the other kind
        let err = Request::parse("not json").unwrap_err();
        assert!(matches!(err, ParseError::Malformed(_)));
        assert_eq!(err.kind(), "bad_request");
    }

    #[test]
    fn malformed_inputs_per_op() {
        for line in [
            // structural
            "not json",
            "{}",
            r#"{"op":42}"#,
            // predict
            r#"{"op":"predict","anchor":"zzz","target":"p3","anchor_latency_ms":1,"profile":{}}"#,
            r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":1}"#,
            r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":1,"profile":{"Conv2D":"x"}}"#,
            r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":-1,"profile":{"Conv2D":1}}"#,
            r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":1,"profile":{"Conv2D":1e400}}"#,
            // batch/pixel interpolation
            r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":100.0}"#,
            r#"{"op":"predict_batch_size","instance":"p3","batch":-1,"t_min":100.0,"t_max":900.0}"#,
            r#"{"op":"predict_batch_size","instance":"p3","batch":64,"t_min":1e400,"t_max":900.0}"#,
            r#"{"op":"predict_pixel_size","instance":"p9","pixels":64,"t_min":1,"t_max":2}"#,
            r#"{"op":"predict_pixel_size","instance":"p3","pixels":64.5,"t_min":1,"t_max":2}"#,
            // recommend: missing endpoints, bad endpoint sign, bad lists
            r#"{"op":"recommend","anchor":"g4dn","pixels":64}"#,
            // partial pixel-endpoint quartet is rejected, not dropped
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"anchor_lat_pmax":7}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":-5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"targets":["warp9"]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"batches":"all"}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"gpu_counts":[1,"two"]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"batches":[16.9]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"gpu_counts":[-2]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"top_k":-1}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"gpu_counts":[0]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"gpu_counts":[65]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"include_spot":"true"}"#,
            // values outside the interpolation models' fitted range
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"batches":[4096]}"#,
            r#"{"op":"recommend","anchor":"g4dn","pixels":16,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10}"#,
            // pixel sizes beyond the profiled size need the pixel quartet
            r#"{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"pixel_sizes":[64,128]}"#,
            // plan: missing job, unknown objective, missing constraint,
            // non-finite constraint
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"objective":"cheapest","deadline_hours":1}"#,
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"dataset_images":1000,"objective":"cheapest","deadline_hours":1e400}"#,
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"dataset_images":1000,"epochs":1e400,"objective":"fastest","budget_usd":5}"#,
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"dataset_images":1000,"objective":"soonest","deadline_hours":1}"#,
            r#"{"op":"plan","anchor":"g4dn","pixels":64,"profile_bmin":{"Conv2D":1},"anchor_lat_bmin":5,"profile_bmax":{"Conv2D":2},"anchor_lat_bmax":10,"dataset_images":1000,"objective":"fastest"}"#,
        ] {
            let err = Request::parse(line).unwrap_err();
            assert!(
                matches!(err, ParseError::Malformed(_)),
                "expected Malformed for {line}, got {err:?}"
            );
        }
        // grid axes are length-capped (sweep-amplification guard)
        let big = vec!["16"; MAX_AXIS_ENTRIES + 1].join(",");
        let line = format!(
            r#"{{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{{"Conv2D":1}},"anchor_lat_bmin":5,"profile_bmax":{{"Conv2D":2}},"anchor_lat_bmax":10,"batches":[{big}]}}"#
        );
        assert!(matches!(
            Request::parse(&line).unwrap_err(),
            ParseError::Malformed(_)
        ));
        // ... and so is the cross product of individually-legal axes
        // (64 in-range batches x 16 gpu counts x default 6 targets x 2)
        let batches = (16..16 + MAX_AXIS_ENTRIES).map(|b| b.to_string()).collect::<Vec<_>>().join(",");
        let gpus = (1..=MAX_GPU_ENTRIES).map(|g| g.to_string()).collect::<Vec<_>>().join(",");
        let line = format!(
            r#"{{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{{"Conv2D":1}},"anchor_lat_bmin":5,"profile_bmax":{{"Conv2D":2}},"anchor_lat_bmax":10,"batches":[{batches}],"gpu_counts":[{gpus}]}}"#
        );
        assert!(matches!(
            Request::parse(&line).unwrap_err(),
            ParseError::Malformed(_)
        ));
    }

    #[test]
    fn response_lines() {
        let r = Response::ok_obj(|o| {
            o.set("latency_ms", crate::util::Json::Num(12.5));
        });
        assert!(r.to_line().contains("\"ok\":true"));
        let e = Response::Err("boom".into());
        assert!(e.to_line().contains("\"ok\":false"));
        let k = Response::err_kind("unknown_op", "unknown op `nope`");
        let line = k.to_line();
        assert!(line.contains("\"ok\":false"));
        assert!(line.contains("\"kind\":\"unknown_op\""));
    }
}
