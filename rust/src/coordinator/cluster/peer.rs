//! Blocking line-protocol client for one backend, with lazy reconnect.
//!
//! A [`Peer`] owns (at most) one TCP connection to a single backend and
//! speaks the same newline-delimited JSON the backend serves to
//! clients — the route tier is a protocol-transparent proxy, so request
//! lines are forwarded verbatim and reply lines relayed back verbatim.
//!
//! Connections are pooled across calls and re-established lazily: a
//! call on a dead pooled connection retries exactly once on a fresh
//! socket (the backend's idle sweep may have closed it between calls),
//! then surfaces the error so the router can fail over to the next ring
//! owner.
//!
//! Failpoints (chaos tests, `docs/RESILIENCE.md`): every call checks
//! the shared `cluster.peer.send` point *and* the per-backend
//! `cluster.peer.send.<addr>` point, so a test can partition one
//! backend while the rest of the fleet keeps answering.

use crate::util::failpoint::{self, Hit};
use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// One backend endpoint: address, pooled connection, timeouts.
pub struct Peer {
    addr: String,
    /// Dynamic failpoint name `cluster.peer.send.<addr>` (built once —
    /// [`failpoint::check`] takes any `&str`).
    fp_name: String,
    timeout: Duration,
    conn: Option<BufReader<TcpStream>>,
}

impl Peer {
    pub fn new(addr: &str, timeout: Duration) -> Peer {
        Peer {
            addr: addr.to_string(),
            fp_name: format!("cluster.peer.send.{addr}"),
            timeout,
            conn: None,
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn connect(&self) -> io::Result<BufReader<TcpStream>> {
        let sa = self.addr.to_socket_addrs()?.next().ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidInput, "unresolvable backend address")
        })?;
        let stream = TcpStream::connect_timeout(&sa, self.timeout)?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        Ok(BufReader::new(stream))
    }

    /// Write one request line, read one reply line (newline stripped).
    fn exchange(conn: &mut BufReader<TcpStream>, line: &str) -> io::Result<String> {
        let stream = conn.get_mut();
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        let mut reply = String::new();
        if conn.read_line(&mut reply)? == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "backend closed the connection",
            ));
        }
        while reply.ends_with('\n') || reply.ends_with('\r') {
            reply.pop();
        }
        Ok(reply)
    }

    /// One request/reply round trip. Errors mean "this backend did not
    /// answer" — the caller decides whether to fail over.
    pub fn call(&mut self, line: &str) -> io::Result<String> {
        for name in ["cluster.peer.send", self.fp_name.as_str()] {
            match failpoint::check(name) {
                Some(Hit::ReturnErr) | Some(Hit::PartialWrite(_)) => {
                    // injected partition: drop the pooled connection so a
                    // later disarm starts clean
                    self.conn = None;
                    return Err(io::Error::new(
                        io::ErrorKind::ConnectionReset,
                        format!("failpoint `{name}` fired: injected peer fault"),
                    ));
                }
                None => {}
            }
        }
        let reused = self.conn.is_some();
        let mut conn = match self.conn.take() {
            Some(c) => c,
            None => self.connect()?,
        };
        match Self::exchange(&mut conn, line) {
            Ok(reply) => {
                self.conn = Some(conn);
                Ok(reply)
            }
            // the pooled connection may simply have been idle-closed by
            // the backend between calls — one fresh-socket retry
            // distinguishes "stale pool entry" from "backend down"
            Err(_) if reused => {
                drop(conn);
                let mut fresh = self.connect()?;
                let reply = Self::exchange(&mut fresh, line)?;
                self.conn = Some(fresh);
                Ok(reply)
            }
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// One-shot echo server: accepts a single connection, answers every
    /// line with a fixed reply, then exits.
    fn echo_backend(reply: &'static str) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = stream;
            let mut line = String::new();
            while {
                line.clear();
                reader.read_line(&mut line).unwrap_or(0) > 0
            } {
                out.write_all(reply.as_bytes()).unwrap();
                out.write_all(b"\n").unwrap();
            }
        });
        (addr, h)
    }

    #[test]
    fn call_round_trips_and_pools_the_connection() {
        let (addr, h) = echo_backend(r#"{"ok":true}"#);
        let mut peer = Peer::new(&addr, Duration::from_secs(5));
        assert_eq!(peer.call(r#"{"op":"health"}"#).unwrap(), r#"{"ok":true}"#);
        assert_eq!(peer.call(r#"{"op":"health"}"#).unwrap(), r#"{"ok":true}"#);
        assert!(peer.conn.is_some(), "connection must be pooled");
        drop(peer);
        h.join().unwrap();
    }

    #[test]
    fn per_backend_failpoint_injects_a_peer_fault() {
        let (addr, h) = echo_backend(r#"{"ok":true}"#);
        let mut peer = Peer::new(&addr, Duration::from_secs(5));
        assert!(peer.call(r#"{"op":"health"}"#).is_ok());
        let fp = format!("cluster.peer.send.{addr}");
        failpoint::configure(&fp, failpoint::Action::ReturnErr);
        let err = peer.call(r#"{"op":"health"}"#).unwrap_err();
        assert!(err.to_string().contains("injected peer fault"), "{err}");
        assert!(peer.conn.is_none(), "injected fault must drop the pool");
        failpoint::clear(&fp);
        assert!(peer.call(r#"{"op":"health"}"#).is_ok(), "recovers after disarm");
        drop(peer);
        h.join().unwrap();
    }

    #[test]
    fn dead_backend_surfaces_an_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // nothing listens here any more
        let mut peer = Peer::new(&addr, Duration::from_millis(200));
        assert!(peer.call(r#"{"op":"health"}"#).is_err());
    }
}
