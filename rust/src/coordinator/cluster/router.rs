//! The route tier (`repro route`): a protocol-transparent front process
//! that shards requests by `(anchor, target)` across N `repro serve`
//! backends over the existing line protocol.
//!
//! Dataflow (see `docs/ARCHITECTURE.md` §Cluster for the full diagram):
//!
//! * **Sharded ops** (`predict`, `predict_batch_size`,
//!   `predict_pixel_size`, `hint`) route to the [`Ring`] owner of their
//!   shard key; if the owner is ejected or fails mid-call, the router
//!   walks the rendezvous failover order and counts a `retry`. A predict
//!   answered by a fallback owner is also buffered as a cache `hint` for
//!   the primary, replayed when it rejoins — so its cache is warm again
//!   the moment it returns.
//! * **Fan-out ops** (`ingest`, and the two-phase `onboard`/`reload`
//!   publish) go to every healthy backend. A publish first runs the
//!   `dry_run` validation gate on every node (phase 1); only if every
//!   node accepts does the real publish run (phase 2), and the router
//!   verifies all nodes landed on the same `registry_epoch`. Any
//!   rejection or divergence is reported as a structured
//!   [`Response::ClusterErr`] with one [`NodeReport`] per node — the
//!   fleet is never left on a torn epoch by a candidate that some nodes
//!   would refuse.
//! * **Any-node ops** (`stats`, `metrics`, `instances`, `recommend`,
//!   `plan`) go to the first healthy backend — this state is replicated,
//!   not sharded.
//! * **Inline ops**: `health` and `cluster_stats` are answered by the
//!   router itself.
//!
//! All mutable router state (membership health, per-backend counters,
//! pending hints) lives behind **one** `Mutex<ClusterState>`;
//! `cluster_stats` snapshots everything under a single acquisition, so
//! derived invariants (`forwarded == Σ backend.requests`) hold in every
//! snapshot — the torn-read hazard the PR 7 connection gauges hit is
//! structurally excluded here.

use super::health;
use super::peer::Peer;
use super::ring::Ring;
use crate::coordinator::protocol::{
    ClusterBackend, HintRequest, NodeReport, PredictRequest, Request, Response,
};
use crate::predictor::Member;
use crate::util::Json;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Cap on hints buffered for ejected shard owners. Oldest entries are
/// dropped first — a hint is an optimization, never required state.
const MAX_PENDING_HINTS: usize = 256;

/// Configuration for [`serve_cluster`].
#[derive(Debug, Clone)]
pub struct RouteOptions {
    /// Listen address of the router itself.
    pub addr: String,
    /// Backend `host:port` addresses (sorted + deduped into the ring).
    pub backends: Vec<String>,
    /// Health-probe cadence.
    pub probe_interval: Duration,
    /// Consecutive failed probes before a backend is ejected.
    pub fail_threshold: u32,
    /// Per-call connect/read/write timeout toward a backend.
    pub call_timeout: Duration,
}

impl Default for RouteOptions {
    fn default() -> Self {
        RouteOptions {
            addr: "127.0.0.1:7979".to_string(),
            backends: Vec::new(),
            probe_interval: Duration::from_millis(500),
            fail_threshold: 2,
            call_timeout: Duration::from_secs(5),
        }
    }
}

/// Per-backend view under the cluster lock.
pub(crate) struct BackendState {
    pub addr: String,
    pub healthy: bool,
    pub consecutive_failures: u32,
    /// Requests this backend answered through the router.
    pub requests: u64,
    /// Last `registry_epoch` seen from this backend (probe or publish);
    /// `None` until the first successful probe.
    pub epoch: Option<u64>,
}

/// All mutable router state — ONE lock, snapshotted in one acquisition.
pub(crate) struct ClusterState {
    pub backends: Vec<BackendState>,
    pub requests: u64,
    pub forwarded: u64,
    pub retries: u64,
    pub ejections: u64,
    pub rejoins: u64,
    pub no_backend: u64,
    pub hints_replayed: u64,
    /// Hints waiting for an ejected shard owner: `(backend idx, line)`.
    pub pending_hints: VecDeque<(usize, String)>,
}

impl ClusterState {
    fn new(backends: &[String]) -> ClusterState {
        ClusterState {
            backends: backends
                .iter()
                .map(|a| BackendState {
                    addr: a.clone(),
                    healthy: true,
                    consecutive_failures: 0,
                    requests: 0,
                    epoch: None,
                })
                .collect(),
            requests: 0,
            forwarded: 0,
            retries: 0,
            ejections: 0,
            rejoins: 0,
            no_backend: 0,
            hints_replayed: 0,
            pending_hints: VecDeque::new(),
        }
    }
}

/// State shared between connection threads and the health prober.
pub(crate) struct Shared {
    pub ring: Ring,
    pub state: Mutex<ClusterState>,
    /// Request-path clients, index-aligned with `ring.backends()`.
    pub peers: Vec<Mutex<Peer>>,
    pub fail_threshold: u32,
    pub call_timeout: Duration,
    pub shutdown: AtomicBool,
}

/// Running route tier; `stop()` joins the accept and prober threads.
pub struct RouteHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    prober: Option<JoinHandle<()>>,
}

impl RouteHandle {
    /// The bound listen address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, stop probing, join both threads. In-flight
    /// connection threads finish their current client naturally.
    pub fn stop(mut self) {
        // ordering: shutdown latch polled by the accept/prober loops;
        // exact publication timing only affects when they notice.
        self.shared.shutdown.store(true, Ordering::Relaxed);
        // unblock the accept loop with one throwaway connection
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.prober.take() {
            let _ = h.join();
        }
    }
}

/// Boot the route tier: bind, spawn the health prober and the accept
/// loop (thread per connection — the router is I/O-bound fan-out, not a
/// reactor workload).
pub fn serve_cluster(opts: RouteOptions) -> std::io::Result<RouteHandle> {
    let ring = Ring::new(opts.backends.clone());
    if ring.is_empty() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            "repro route needs at least one backend (--backends a,b,c)",
        ));
    }
    let listener = TcpListener::bind(&opts.addr)?;
    let addr = listener.local_addr()?;
    let peers = ring
        .backends()
        .iter()
        .map(|a| Mutex::new(Peer::new(a, opts.call_timeout)))
        .collect();
    let state = Mutex::new(ClusterState::new(ring.backends()));
    let shared = Arc::new(Shared {
        ring,
        state,
        peers,
        fail_threshold: opts.fail_threshold.max(1),
        call_timeout: opts.call_timeout,
        shutdown: AtomicBool::new(false),
    });
    let prober = {
        let shared = shared.clone();
        let interval = opts.probe_interval;
        std::thread::spawn(move || health::prober_loop(&shared, interval))
    };
    let accept = {
        let shared = shared.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                // ordering: shutdown latch — see RouteHandle::stop.
                if shared.shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let shared = shared.clone();
                std::thread::spawn(move || handle_conn(&shared, stream));
            }
        })
    };
    Ok(RouteHandle {
        addr,
        shared,
        accept: Some(accept),
        prober: Some(prober),
    })
}

/// Serve one client connection: one request line in, one reply line out.
fn handle_conn(shared: &Shared, stream: TcpStream) {
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = handle_line(shared, trimmed);
        if out.write_all(reply.as_bytes()).is_err() || out.write_all(b"\n").is_err() {
            return;
        }
    }
}

/// Encode a router-originated response as one line (no newline).
fn encode(resp: &Response) -> String {
    let mut out = Vec::new();
    resp.encode(&mut out);
    String::from_utf8_lossy(&out).into_owned()
}

/// Route one request line; returns the reply line (no newline).
pub(crate) fn handle_line(shared: &Shared, line: &str) -> String {
    let req = match Request::parse_dom(line) {
        Ok(r) => r,
        Err(e) => return encode(&Response::err_kind(e.kind(), format!("bad request: {e}"))),
    };
    shared.state.lock().unwrap().requests += 1;
    match req {
        Request::Health => encode(&Response::Health),
        Request::ClusterStats => encode(&cluster_stats(shared)),
        Request::Predict(p) => {
            let key = Ring::shard_key(p.anchor.key(), p.target.key());
            route_sharded(shared, line, key, Some(&p))
        }
        Request::Hint(h) => {
            let key = Ring::shard_key(h.anchor.key(), h.target.key());
            route_sharded(shared, line, key, None)
        }
        Request::PredictBatchSize { instance, .. } | Request::PredictPixelSize { instance, .. } => {
            // interpolation is keyed by a single instance; both sides of
            // the shard key collapse to it
            let key = Ring::shard_key(instance.key(), instance.key());
            route_sharded(shared, line, key, None)
        }
        Request::Stats | Request::Metrics | Request::Instances => route_any(shared, line),
        Request::Recommend { .. } | Request::Plan { .. } => route_any(shared, line),
        Request::Ingest(_) => broadcast_ingest(shared, line),
        Request::Onboard { dry_run, .. } | Request::Reload { dry_run } => {
            two_phase_publish(shared, line, dry_run)
        }
    }
}

/// Single-acquisition snapshot for `cluster_stats` — every derived
/// invariant (healthy count, `forwarded == Σ requests`) holds because
/// nothing can move between the reads.
fn cluster_stats(shared: &Shared) -> Response {
    let st = shared.state.lock().unwrap();
    Response::ClusterStats {
        requests: st.requests,
        forwarded: st.forwarded,
        retries: st.retries,
        ejections: st.ejections,
        rejoins: st.rejoins,
        no_backend: st.no_backend,
        hints_pending: st.pending_hints.len() as u64,
        hints_replayed: st.hints_replayed,
        healthy_backends: st.backends.iter().filter(|b| b.healthy).count(),
        backends: st
            .backends
            .iter()
            .map(|b| ClusterBackend {
                addr: b.addr.clone(),
                healthy: b.healthy,
                requests: b.requests,
            })
            .collect(),
    }
}

/// Health snapshot under one acquisition.
fn healthy_mask(shared: &Shared) -> Vec<bool> {
    let st = shared.state.lock().unwrap();
    st.backends.iter().map(|b| b.healthy).collect()
}

/// One forwarded call; on success the forward counters move together
/// under a single lock acquisition (the `cluster_stats` invariant).
fn call_backend(shared: &Shared, i: usize, line: &str) -> std::io::Result<String> {
    let reply = shared.peers[i].lock().unwrap().call(line);
    if reply.is_ok() {
        let mut st = shared.state.lock().unwrap();
        st.forwarded += 1;
        st.backends[i].requests += 1;
    }
    reply
}

/// Walk the ring's failover order, skipping ejected backends. A predict
/// answered by a fallback owner leaves a buffered cache hint for the
/// primary (replayed on rejoin by the health prober).
fn route_sharded(
    shared: &Shared,
    line: &str,
    key: u64,
    predict: Option<&PredictRequest>,
) -> String {
    let order = shared.ring.owners(key);
    let healthy = healthy_mask(shared);
    let primary = order.first().copied();
    for &i in &order {
        if !healthy[i] {
            continue;
        }
        match call_backend(shared, i, line) {
            Ok(reply) => {
                if let (Some(p), Some(pr)) = (predict, primary) {
                    if pr != i {
                        buffer_hint_for(shared, pr, p, &reply);
                    }
                }
                return reply;
            }
            Err(_) => {
                shared.state.lock().unwrap().retries += 1;
            }
        }
    }
    shared.state.lock().unwrap().no_backend += 1;
    encode(&Response::err_kind(
        "no_backend",
        "no healthy backend for this shard — every ring owner is ejected or failed",
    ))
}

/// Forward to the first healthy backend (replicated, unsharded state).
fn route_any(shared: &Shared, line: &str) -> String {
    let healthy = healthy_mask(shared);
    for (i, ok) in healthy.iter().enumerate() {
        if !ok {
            continue;
        }
        match call_backend(shared, i, line) {
            Ok(reply) => return reply,
            Err(_) => {
                shared.state.lock().unwrap().retries += 1;
            }
        }
    }
    shared.state.lock().unwrap().no_backend += 1;
    encode(&Response::err_kind(
        "no_backend",
        "no healthy backend left to answer this request",
    ))
}

/// A successful predict served by a *fallback* owner: rebuild it as a
/// `hint` line for the primary so its cache is warm again on rejoin.
fn buffer_hint_for(shared: &Shared, primary: usize, p: &PredictRequest, reply: &str) {
    let Ok(j) = Json::parse(reply) else { return };
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        return;
    }
    let (Ok(latency_ms), Ok(member)) = (j.req_f64("latency_ms"), j.req_str("member")) else {
        return;
    };
    let Some(member) = Member::from_name(member) else {
        return;
    };
    // the hint must carry the epoch the primary will serve under; until
    // its first probe we do not know it, so skip (hints are best-effort)
    let epoch = {
        let st = shared.state.lock().unwrap();
        st.backends[primary].epoch
    };
    let Some(epoch) = epoch else { return };
    let hint = Request::Hint(HintRequest {
        epoch,
        anchor: p.anchor,
        target: p.target,
        anchor_latency_ms: p.anchor_latency_ms,
        latency_ms,
        member,
        profile: p.profile.clone(),
    });
    let line = hint.to_json().to_string();
    let mut st = shared.state.lock().unwrap();
    if st.pending_hints.len() >= MAX_PENDING_HINTS {
        st.pending_hints.pop_front();
    }
    st.pending_hints.push_back((primary, line));
}

/// Fan an `ingest` line out to every healthy backend — staging areas
/// are per-node, and each node's `onboard` validation gate needs the
/// same corpus.
fn broadcast_ingest(shared: &Shared, line: &str) -> String {
    let healthy = healthy_mask(shared);
    let mut nodes: Vec<NodeReport> = Vec::new();
    let mut first_ok: Option<String> = None;
    for (i, ok) in healthy.iter().enumerate() {
        if !ok {
            continue;
        }
        let addr = shared.ring.backends()[i].clone();
        match call_backend(shared, i, line) {
            Ok(reply) => {
                let accepted = Json::parse(&reply)
                    .ok()
                    .and_then(|j| j.get("ok").and_then(Json::as_bool))
                    == Some(true);
                if accepted && first_ok.is_none() {
                    first_ok = Some(reply.clone());
                }
                nodes.push(NodeReport {
                    addr,
                    epoch: None,
                    ok: accepted,
                    error: if accepted { String::new() } else { reply },
                });
            }
            Err(e) => nodes.push(NodeReport {
                addr,
                epoch: None,
                ok: false,
                error: e.to_string(),
            }),
        }
    }
    if nodes.is_empty() {
        shared.state.lock().unwrap().no_backend += 1;
        return encode(&Response::err_kind(
            "no_backend",
            "no healthy backend left to stage this measurement",
        ));
    }
    match (nodes.iter().all(|n| n.ok), first_ok) {
        (true, Some(reply)) => reply,
        _ => encode(&Response::cluster_err(
            "internal_error",
            "ingest fan-out failed on one or more nodes",
            nodes,
        )),
    }
}

/// Two-phase fleet publish for `onboard`/`reload`:
///
/// 1. **Check** — the same line with `dry_run:true` runs every node's
///    validation gate without swapping anything. Any rejection aborts
///    with a `validation_failed` [`Response::ClusterErr`]; the whole
///    fleet keeps serving the old epoch.
/// 2. **Publish** — the real line goes to every node; all replies must
///    be `ok` and agree on the new `registry_epoch`, else the divergence
///    is reported per node as `epoch_divergence`.
///
/// A client line that itself carries `dry_run:true` stops after phase 1
/// and reports the per-node check verdicts.
fn two_phase_publish(shared: &Shared, line: &str, client_dry_run: bool) -> String {
    let healthy = healthy_mask(shared);
    let idx: Vec<usize> =
        (0..healthy.len()).filter(|&i| healthy[i]).collect();
    if idx.is_empty() {
        shared.state.lock().unwrap().no_backend += 1;
        return encode(&Response::err_kind(
            "no_backend",
            "no healthy backend left to publish to",
        ));
    }
    // phase 1: every node runs the validation gate, nothing swaps
    let dry_line = match Json::parse(line) {
        Ok(mut j) => {
            j.set("dry_run", Json::Bool(true));
            j.to_string()
        }
        Err(e) => return encode(&Response::Err(format!("unparseable publish line: {e:#}"))),
    };
    let mut nodes: Vec<NodeReport> = Vec::new();
    let mut first_ok: Option<String> = None;
    for &i in &idx {
        let addr = shared.ring.backends()[i].clone();
        match call_backend(shared, i, &dry_line) {
            Ok(reply) => {
                let j = Json::parse(&reply).ok();
                let accepted =
                    j.as_ref().and_then(|j| j.get("ok").and_then(Json::as_bool)) == Some(true);
                let epoch = j
                    .as_ref()
                    .and_then(|j| j.get("epoch").and_then(Json::as_f64))
                    .map(|e| e as u64);
                if accepted && first_ok.is_none() {
                    first_ok = Some(reply.clone());
                }
                nodes.push(NodeReport {
                    addr,
                    epoch,
                    ok: accepted,
                    error: if accepted { String::new() } else { reply },
                });
            }
            Err(e) => nodes.push(NodeReport {
                addr,
                epoch: None,
                ok: false,
                error: e.to_string(),
            }),
        }
    }
    if !nodes.iter().all(|n| n.ok) {
        return encode(&Response::cluster_err(
            "validation_failed",
            "a node's validation gate rejected the candidate — the fleet keeps the old epoch",
            nodes,
        ));
    }
    if client_dry_run {
        // the client only asked for the check; report the first verdict
        return first_ok.unwrap_or_else(|| encode(&Response::Health));
    }
    // phase 2: the real publish, everywhere
    let mut nodes: Vec<NodeReport> = Vec::new();
    let mut first_ok: Option<String> = None;
    for &i in &idx {
        let addr = shared.ring.backends()[i].clone();
        match call_backend(shared, i, line) {
            Ok(reply) => {
                let j = Json::parse(&reply).ok();
                let accepted =
                    j.as_ref().and_then(|j| j.get("ok").and_then(Json::as_bool)) == Some(true);
                let epoch = j
                    .as_ref()
                    .and_then(|j| j.get("epoch").and_then(Json::as_f64))
                    .map(|e| e as u64);
                if accepted {
                    if first_ok.is_none() {
                        first_ok = Some(reply.clone());
                    }
                    if let Some(e) = epoch {
                        shared.state.lock().unwrap().backends[i].epoch = Some(e);
                    }
                }
                nodes.push(NodeReport {
                    addr,
                    epoch,
                    ok: accepted,
                    error: if accepted { String::new() } else { reply },
                });
            }
            Err(e) => nodes.push(NodeReport {
                addr,
                epoch: None,
                ok: false,
                error: e.to_string(),
            }),
        }
    }
    let epochs: Vec<u64> = nodes.iter().filter_map(|n| n.epoch).collect();
    let agreed = nodes.iter().all(|n| n.ok)
        && epochs.len() == nodes.len()
        && epochs.windows(2).all(|w| w[0] == w[1]);
    match (agreed, first_ok) {
        (true, Some(reply)) => reply,
        _ => encode(&Response::cluster_err(
            "epoch_divergence",
            "fleet publish diverged — nodes disagree on the new registry epoch",
            nodes,
        )),
    }
}
