//! Multi-node serving tier: the `repro route` front process.
//!
//! A cluster is N independent `repro serve` backends behind one (or
//! more) stateless routers. Prediction state is keyed by
//! `(anchor, target)`, so it shards cleanly: the [`ring`] maps each
//! pair to an owning backend (rendezvous hashing — minimal churn on
//! membership change), [`peer`] speaks the existing line protocol to
//! backends, [`health`] probes membership and replays buffered cache
//! hints into rejoining owners, and [`router`] ties it together:
//! sharded forwards with failover, two-phase epoch-agreed publishes,
//! and the router-local `cluster_stats` op.
//!
//! The deterministic cluster test harness lives in
//! `tests/cluster_util/` (stub backends on real ephemeral-port
//! listeners) and `tests/cluster.rs`; chaos coverage reuses the
//! `cluster.peer.send[.<addr>]` failpoints (`docs/RESILIENCE.md`).

pub mod health;
pub mod peer;
pub mod ring;
pub mod router;

pub use peer::Peer;
pub use ring::Ring;
pub use router::{serve_cluster, RouteHandle, RouteOptions};
