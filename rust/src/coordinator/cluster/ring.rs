//! Rendezvous (highest-random-weight) hash ring for the route tier.
//!
//! The cluster shards prediction state by `(anchor, target)` pair: the
//! shard key is [`seed_of`] over the two instance keys — the *same*
//! identity the in-process dispatcher uses to pin a pair to a predict
//! lane, so a pair that hashes together locally also hashes together
//! across the fleet. Each backend address is scored against the shard
//! key with a splitmix64-style finalizer; the backend with the highest
//! score owns the key, and the full descending-score order is the
//! failover order.
//!
//! Rendezvous hashing gives the minimal-churn property for free, with
//! no virtual-node bookkeeping: removing one backend remaps *only* the
//! keys that backend owned (every other backend's scores are
//! untouched), and adding one steals only the keys it now wins. The
//! property tests below pin both guarantees plus the balance bound.

use crate::util::seed_of;

/// Immutable membership snapshot with per-backend score seeds.
///
/// The ring is built once over the full *configured* membership and
/// never rebuilt on health transitions: the router walks
/// [`Ring::owners`] in order and skips unhealthy backends, which is
/// exactly HRW failover. When the backend comes back, the walk finds it
/// first again — rejoin restores its shard with zero remapping of
/// anyone else's keys.
#[derive(Debug, Clone)]
pub struct Ring {
    backends: Vec<String>,
    seeds: Vec<u64>,
}

impl Ring {
    /// Build a ring over `backends` (sorted + deduped, so the index
    /// order is stable regardless of configuration order).
    pub fn new(mut backends: Vec<String>) -> Ring {
        backends.sort();
        backends.dedup();
        let seeds = backends.iter().map(|b| seed_of(&[b.as_str()])).collect();
        Ring { backends, seeds }
    }

    /// Number of configured backends.
    pub fn len(&self) -> usize {
        self.backends.len()
    }

    /// True when no backends are configured.
    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }

    /// The sorted backend addresses (index-aligned with [`Ring::owners`]).
    pub fn backends(&self) -> &[String] {
        &self.backends
    }

    /// Shard key of an `(anchor, target)` pair — [`seed_of`] over both
    /// instance keys, matching the dispatcher's predict-lane identity.
    pub fn shard_key(anchor: &str, target: &str) -> u64 {
        seed_of(&[anchor, target])
    }

    /// Per-(backend, key) rendezvous score: mix the backend's seed with
    /// the shard key, then run a splitmix64 finalizer so single-bit key
    /// differences avalanche across the whole word.
    fn score(seed: u64, key: u64) -> u64 {
        let mut z = seed ^ key.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// All backend indices in descending score order for `key`: the
    /// first entry owns the shard, the rest are the failover order.
    pub fn owners(&self, key: u64) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.backends.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse((Self::score(self.seeds[i], key), i)));
        idx
    }

    /// The owning backend index for `key`, if any backend is configured.
    pub fn owner(&self, key: u64) -> Option<usize> {
        (0..self.backends.len()).max_by_key(|&i| (Self::score(self.seeds[i], key), i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic membership/key generator (no rand crate by design).
    fn lcg(state: &mut u64) -> u64 {
        *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *state
    }

    fn members(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:7070")).collect()
    }

    #[test]
    fn owner_is_first_of_owners_and_deterministic() {
        let ring = Ring::new(members(5));
        let mut s = 42u64;
        for _ in 0..1000 {
            let key = lcg(&mut s);
            let order = ring.owners(key);
            assert_eq!(order.len(), 5);
            assert_eq!(ring.owner(key), Some(order[0]));
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3, 4], "owners is a permutation");
            assert_eq!(order, ring.owners(key), "stable across calls");
        }
    }

    #[test]
    fn membership_order_and_duplicates_do_not_change_ownership() {
        let a = Ring::new(members(4));
        let mut shuffled = members(4);
        shuffled.reverse();
        shuffled.push(shuffled[0].clone());
        let b = Ring::new(shuffled);
        assert_eq!(a.backends(), b.backends());
        let mut s = 7u64;
        for _ in 0..500 {
            let key = lcg(&mut s);
            assert_eq!(a.owner(key), b.owner(key));
        }
    }

    /// Documented balance bound: with 10k uniform keys over n backends
    /// (3..=16), every backend's share stays within ±40% of fair. For a
    /// uniform hash the binomial stddev at n=16 is ~4% of the mean, so
    /// ±40% is ~10 sigma — a failure means the mixer is broken, not bad
    /// luck.
    #[test]
    fn balance_within_documented_bounds_across_3_to_16_backends() {
        const KEYS: usize = 10_000;
        for n in 3..=16usize {
            let ring = Ring::new(members(n));
            let mut counts = vec![0usize; n];
            let mut s = 0xD1CE_5EEDu64 ^ n as u64;
            for _ in 0..KEYS {
                counts[ring.owner(lcg(&mut s)).unwrap()] += 1;
            }
            let fair = KEYS as f64 / n as f64;
            for (i, &c) in counts.iter().enumerate() {
                let share = c as f64 / fair;
                assert!(
                    (0.6..=1.4).contains(&share),
                    "n={n} backend {i} holds {c} keys ({share:.2}x fair)"
                );
            }
        }
    }

    /// Minimal churn on loss: removing one backend remaps only the keys
    /// that backend owned. Every other key keeps its owner *address*.
    #[test]
    fn removing_one_backend_remaps_only_its_keys() {
        let full = Ring::new(members(8));
        for gone in 0..8usize {
            let survivors: Vec<String> =
                members(8).into_iter().enumerate().filter(|(i, _)| *i != gone).map(|(_, m)| m).collect();
            let shrunk = Ring::new(survivors);
            let mut s = 0xBEEFu64 ^ gone as u64;
            let mut moved = 0usize;
            for _ in 0..2000 {
                let key = lcg(&mut s);
                let before = &full.backends()[full.owner(key).unwrap()];
                let after = &shrunk.backends()[shrunk.owner(key).unwrap()];
                if before == &full.backends()[gone] {
                    moved += 1; // had to move — its owner is gone
                } else {
                    assert_eq!(before, after, "key not owned by the lost backend moved");
                }
            }
            assert!(moved > 0, "the lost backend owned at least some keys");
        }
    }

    /// Minimal churn on join: an added backend only steals keys for
    /// itself — no key moves between two pre-existing backends.
    #[test]
    fn adding_one_backend_steals_only_for_itself() {
        let small = Ring::new(members(6));
        let mut grown_members = members(6);
        grown_members.push("10.0.1.99:7070".to_string());
        let grown = Ring::new(grown_members);
        let mut s = 0xF00Du64;
        let mut stolen = 0usize;
        for _ in 0..2000 {
            let key = lcg(&mut s);
            let before = &small.backends()[small.owner(key).unwrap()];
            let after = &grown.backends()[grown.owner(key).unwrap()];
            if after == "10.0.1.99:7070" {
                stolen += 1;
            } else {
                assert_eq!(before, after, "key moved between pre-existing backends");
            }
        }
        assert!(stolen > 0, "the new backend won at least some keys");
    }

    /// Failover-order consistency over seeded random membership walks:
    /// dropping a backend from the membership yields exactly the old
    /// owners order with that backend deleted — so walking owners() and
    /// skipping the unhealthy is equivalent to rebuilding the ring.
    #[test]
    fn owners_order_survives_membership_deletion() {
        let mut s = 0xACE5u64;
        for _ in 0..20 {
            let n = 3 + (lcg(&mut s) % 10) as usize;
            let full = Ring::new(members(n));
            let gone = (lcg(&mut s) % n as u64) as usize;
            let survivors: Vec<String> =
                full.backends().iter().filter(|b| *b != &full.backends()[gone]).cloned().collect();
            let shrunk = Ring::new(survivors);
            for _ in 0..200 {
                let key = lcg(&mut s);
                let expect: Vec<&String> = full
                    .owners(key)
                    .into_iter()
                    .filter(|&i| i != gone)
                    .map(|i| &full.backends()[i])
                    .collect();
                let got: Vec<&String> =
                    shrunk.owners(key).into_iter().map(|i| &shrunk.backends()[i]).collect();
                assert_eq!(expect, got);
            }
        }
    }

    #[test]
    fn shard_key_matches_dispatcher_identity() {
        // same fnv1a-over-joined-parts identity as dispatch::lane_of
        assert_eq!(Ring::shard_key("p3.2xlarge", "g4dn.xlarge"), seed_of(&["p3.2xlarge", "g4dn.xlarge"]));
        assert_ne!(
            Ring::shard_key("p3.2xlarge", "g4dn.xlarge"),
            Ring::shard_key("g4dn.xlarge", "p3.2xlarge"),
            "pair key is ordered"
        );
    }

    #[test]
    fn empty_ring_is_safe() {
        let ring = Ring::new(Vec::new());
        assert!(ring.is_empty());
        assert_eq!(ring.owner(1), None);
        assert!(ring.owners(1).is_empty());
    }
}
