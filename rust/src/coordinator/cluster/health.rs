//! Health prober for the route tier: per-interval `stats` probes with
//! ejection/rejoin hysteresis and cache-hint replay.
//!
//! Probing with `stats` (not `health`) buys the epoch for free: every
//! successful probe refreshes the backend's last-seen `registry_epoch`,
//! which the router needs to build cache hints for that backend.
//!
//! Transitions are hysteretic: a backend is ejected after
//! `fail_threshold` *consecutive* probe failures and rejoins on the
//! first success afterwards. On rejoin, every cache hint buffered for
//! that backend while it was away is replayed into it — predicts its
//! shard missed during the outage were answered (colder) by fallback
//! owners, and the replays re-warm the returning owner's cache.
//!
//! The prober owns its own [`Peer`] per backend, so probes never
//! contend with request-path forwards on a connection.

use super::peer::Peer;
use super::router::Shared;
use crate::util::Json;
use std::sync::atomic::Ordering;
use std::time::Duration;

/// What one probe observation did to a backend's membership state.
enum Transition {
    None,
    Ejected,
    Rejoined,
}

/// Apply one probe result under the cluster lock (single acquisition —
/// the counters and the flag can never be observed torn).
fn note_probe(shared: &Shared, i: usize, result: Result<Option<u64>, ()>) -> Transition {
    let mut st = shared.state.lock().unwrap();
    let b = &mut st.backends[i];
    match result {
        Ok(epoch) => {
            b.consecutive_failures = 0;
            if let Some(e) = epoch {
                b.epoch = Some(e);
            }
            if !b.healthy {
                b.healthy = true;
                st.rejoins += 1;
                return Transition::Rejoined;
            }
            Transition::None
        }
        Err(()) => {
            b.consecutive_failures += 1;
            if b.healthy && b.consecutive_failures >= shared.fail_threshold {
                b.healthy = false;
                st.ejections += 1;
                return Transition::Ejected;
            }
            Transition::None
        }
    }
}

/// Replay every hint buffered for backend `i` (called right after its
/// rejoin). Hints are drained under one lock acquisition, sent outside
/// the lock, and counted as replayed whether the backend applied them
/// or not — an epoch-mismatch drop on the backend is still a delivery.
fn replay_hints(shared: &Shared, i: usize, peer: &mut Peer) {
    let mine: Vec<String> = {
        let mut st = shared.state.lock().unwrap();
        let (mine, rest): (Vec<_>, Vec<_>) =
            st.pending_hints.drain(..).partition(|(owner, _)| *owner == i);
        st.pending_hints = rest.into_iter().collect();
        mine.into_iter().map(|(_, line)| line).collect()
    };
    for line in mine {
        if peer.call(&line).is_ok() {
            let mut st = shared.state.lock().unwrap();
            st.hints_replayed += 1;
            st.forwarded += 1;
            st.backends[i].requests += 1;
        }
    }
}

/// The prober thread body: probe every backend each interval until the
/// router shuts down.
pub(crate) fn prober_loop(shared: &Shared, interval: Duration) {
    let mut peers: Vec<Peer> = shared
        .ring
        .backends()
        .iter()
        .map(|a| Peer::new(a, shared.call_timeout))
        .collect();
    // ordering: shutdown latch — see RouteHandle::stop; the prober only
    // needs to notice eventually.
    while !shared.shutdown.load(Ordering::Relaxed) {
        for (i, peer) in peers.iter_mut().enumerate() {
            let result = match peer.call(r#"{"op":"stats"}"#) {
                Ok(reply) => {
                    let epoch = Json::parse(&reply)
                        .ok()
                        .filter(|j| j.get("ok").and_then(Json::as_bool) == Some(true))
                        .and_then(|j| j.get("registry_epoch").and_then(|e| e.as_f64()))
                        .map(|e| e as u64);
                    // a reachable socket answering garbage (or a router
                    // misconfigured to probe itself) is not healthy
                    match epoch {
                        Some(e) => Ok(Some(e)),
                        None => Err(()),
                    }
                }
                Err(_) => Err(()),
            };
            if let Transition::Rejoined = note_probe(shared, i, result) {
                replay_hints(shared, i, peer);
            }
        }
        std::thread::sleep(interval);
    }
}
