//! L3 coordinator: the PROFET prediction *service* (paper Sec IV).
//!
//! The paper ships PROFET as a serverless endpoint (S3 + API Gateway +
//! Lambda). Here the same serving semantics run as a self-contained TCP
//! service speaking newline-delimited JSON:
//!
//! * [`server`] — accept loop, one lightweight thread per connection;
//! * [`router`] — request parsing/validation and dispatch;
//! * [`batcher`] — the inference engine: a single worker thread owns the
//!   PJRT [`crate::runtime::Runtime`] (whose handles are not `Send`) plus
//!   the model registry, and coalesces concurrent predict requests for the
//!   same (anchor, target) pair into one fixed-shape MLP artifact
//!   execution (the `b_pred`-row batch the HLO was lowered with). It also
//!   owns the advisor state — the sharded phase-1 prediction cache and the
//!   multi-GPU scaling table — behind the `recommend`/`plan` ops.
//!
//! Python never appears anywhere on this path: requests go JSON → feature
//! vector → HLO executable → JSON.

mod batcher;
mod protocol;
mod router;
mod server;

pub use batcher::{Batcher, BatcherStats};
pub use protocol::{ParseError, PredictRequest, Request, Response};
pub use router::route;
pub use server::{serve, ServerHandle, MAX_LINE_BYTES};
