//! L3 coordinator: the PROFET prediction *service* (paper Sec IV).
//!
//! The paper ships PROFET as a serverless endpoint (S3 + API Gateway +
//! Lambda). Here the same serving semantics run as a self-contained TCP
//! service speaking newline-delimited JSON (the end-to-end dataflow
//! narrative, with diagrams, lives in `docs/ARCHITECTURE.md`; the wire
//! reference in `docs/PROTOCOL.md`):
//!
//! * [`cluster`] — the multi-node tier (`repro route`): a stateless
//!   front process that rendezvous-hash-shards requests by
//!   `(anchor, target)` across N backends over this same protocol, with
//!   health-checked failover, two-phase epoch-agreed fleet publishes,
//!   and peer cache-hint replay;
//! * [`server`] — the admission loop: enforces the connection budget
//!   (best-effort nonblocking `overloaded` rejection) and hands accepted
//!   sockets to the reactor pool; `stop()` gracefully drains in-flight
//!   connections (flushes every accepted request's response); an
//!   optional model-dir watcher hot-reloads the registry when the
//!   directory changes;
//! * [`reactor`] — the readiness-polled connection tier: a few epoll
//!   threads own all sockets (idle keep-alive connections cost file
//!   descriptors, not threads), frame request lines nonblockingly,
//!   answer warm predicts inline, and flush engine completions back on
//!   writable readiness;
//! * [`router`] — request parsing/validation and dispatch over the
//!   zero-allocation streaming wire layer (borrowed decode, typed
//!   responses encoded straight into per-connection buffers; warm
//!   `predict`s answered from the shared prediction cache without an
//!   engine round trip — see `protocol.rs` §Wire path); full lane
//!   queues answer with a structured `overloaded` error (backpressure);
//! * [`registry`] — the live model registry: epoch-stamped `Arc<Profet>`
//!   snapshots, validation-gated hot swaps (`reload`), and the staged
//!   online-onboarding path (`ingest` → `onboard`) that brings a new GPU
//!   instance type into a running service without dropping a request;
//! * [`dispatch`] — the engine replica pool: N predict lanes + one
//!   advisor lane + one trainer lane, each replica owning its own
//!   non-`Send` PJRT [`crate::runtime::Runtime`]. Phase-1 `predict`
//!   jobs route by (anchor, target) affinity so dynamic batching still
//!   coalesces; `recommend`/`plan` sweeps run on the advisor lane and
//!   registry writes (`ingest`/`onboard`/`reload`) on the trainer lane,
//!   so neither sweeps nor multi-second training jobs can ever
//!   head-of-line-block predict traffic;
//! * [`lane`] — the per-replica work loops: the dynamic batcher (one
//!   fixed-shape MLP artifact execution per coalesced (epoch, anchor,
//!   target) group — the `b_pred`-row batch the HLO was lowered with),
//!   the FIFO advisor loop, and the FIFO trainer loop. The sharded
//!   phase-1 prediction cache and the multi-GPU scaling table are shared
//!   (`Arc`) across all replicas.
//!
//! Python never appears anywhere on this path: requests go JSON → feature
//! vector → HLO executable → JSON.

pub mod cluster;
pub mod dispatch;
pub mod lane;
pub mod protocol;
pub mod reactor;
pub mod registry;
pub mod router;
pub mod server;

pub use cluster::{serve_cluster, RouteHandle, RouteOptions};
pub use dispatch::{ConnStats, EnginePool, EngineStats, Job, PoolOptions, Reply, SubmitError};
pub use protocol::{
    parse_line, ParseError, ParsedLine, PredictRequest, PredictView, Request, Response,
    WireScratch,
};
pub use registry::{
    IngestRequest, ModelRegistry, ModelSnapshot, OnboardOptions, OnboardReport, RegistryError,
    StagingArea,
};
pub use router::{respond, respond_or_submit, route, ConnScratch, RouteOutcome};
pub use server::{serve, serve_with, ServeOptions, ServerHandle, MAX_LINE_BYTES};
