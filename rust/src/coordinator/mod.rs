//! L3 coordinator: the PROFET prediction *service* (paper Sec IV).
//!
//! The paper ships PROFET as a serverless endpoint (S3 + API Gateway +
//! Lambda). Here the same serving semantics run as a self-contained TCP
//! service speaking newline-delimited JSON:
//!
//! * [`server`] — accept loop, one lightweight thread per connection,
//!   bounded by a connection budget; `stop()` gracefully drains in-flight
//!   connections (joins their handlers after flushing responses);
//! * [`router`] — request parsing/validation and dispatch over the
//!   zero-allocation streaming wire layer (borrowed decode, typed
//!   responses encoded straight into per-connection buffers; warm
//!   `predict`s answered from the shared prediction cache without an
//!   engine round trip — see `protocol.rs` §Wire path); full lane
//!   queues answer with a structured `overloaded` error (backpressure);
//! * [`dispatch`] — the engine replica pool: N predict lanes + one
//!   advisor lane, each replica owning its own non-`Send` PJRT
//!   [`crate::runtime::Runtime`] + model registry. Phase-1 `predict`
//!   jobs route by (anchor, target) affinity so dynamic batching still
//!   coalesces; `recommend`/`plan` sweeps run on the advisor lane so a
//!   sweep can never head-of-line-block predict traffic;
//! * [`lane`] — the per-replica work loops: the dynamic batcher (one
//!   fixed-shape MLP artifact execution per coalesced (anchor, target)
//!   group — the `b_pred`-row batch the HLO was lowered with) and the
//!   FIFO advisor loop. The sharded phase-1 prediction cache and the
//!   multi-GPU scaling table are shared (`Arc`) across all replicas.
//!
//! Python never appears anywhere on this path: requests go JSON → feature
//! vector → HLO executable → JSON.

mod dispatch;
mod lane;
mod protocol;
mod router;
mod server;

pub use dispatch::{EnginePool, EngineStats, Job, PoolOptions, SubmitError};
pub use protocol::{
    parse_line, ParseError, ParsedLine, PredictRequest, PredictView, Request, Response,
    WireScratch,
};
pub use router::{respond, route, ConnScratch};
pub use server::{serve, serve_with, ServeOptions, ServerHandle, MAX_LINE_BYTES};
