//! Live model registry: epoch-stamped, hot-swappable [`Profet`] model
//! sets plus the on-disk staging area behind online GPU onboarding.
//!
//! PROFET's premise is that cloud GPU catalogues move faster than anyone
//! can re-benchmark them — yet until this module existed, the serving
//! stack loaded its trained models exactly once at
//! [`EnginePool::spawn`](crate::coordinator::dispatch::EnginePool::spawn)
//! and never again: onboarding a new instance type meant killing a
//! process that had learned to drain gracefully and answer warm predicts
//! with zero allocations. The registry turns the model set into a live,
//! versioned subsystem:
//!
//! * **Epoch-stamped snapshots.** The current model set is an
//!   `Arc<Profet>` tagged with a monotonically increasing epoch. Readers
//!   ([`ModelRegistry::snapshot`]) take a lightweight lock just long
//!   enough to clone the `Arc` — one refcount bump per request, no
//!   allocation, and never blocked behind model loading or training
//!   (swaps prepare the candidate entirely outside the lock). A request
//!   keeps the snapshot it started with, so an in-flight predict is
//!   always answered by the epoch that admitted it, however many swaps
//!   land while it waits in a lane queue.
//! * **Validation before swap.** A candidate only becomes current after
//!   [`ModelRegistry::validate`]: every `(anchor, target)` ensemble must
//!   predict a finite, positive latency for a canned probe profile, and
//!   every batch/pixel model must interpolate finitely. A candidate that
//!   fails leaves the old epoch serving — a bad `reload` or `onboard` can
//!   degrade nothing.
//! * **Implicit cache invalidation.** The registry epoch is a component
//!   of every phase-1 [`CacheKey`](crate::advisor::CacheKey): publishing
//!   a new epoch makes all old entries unreachable without flushing (or
//!   even locking) the shared prediction cache. Stale entries age out by
//!   FIFO eviction.
//! * **Staging + onboarding.** [`StagingArea`] persists profiled anchor
//!   measurements per `(anchor, target)` pair (the `ingest` op) under
//!   `<model_dir>/staging/`; [`ModelRegistry::onboard`] turns the staged
//!   measurements into a corpus, retrains exactly the affected pairs via
//!   [`Profet::retrain_pairs`] (frozen feature space, identical seed
//!   derivation to [`Profet::train`]), persists the merged model set, and
//!   publishes it as a new epoch. Training runs on the coordinator's
//!   dedicated trainer lane, so it can never block predict traffic.
//! * **Crash safety.** Model persistence goes through [`Profet::save`]'s
//!   temp-sibling + fsync + manifest-last rename protocol, and
//!   [`ModelRegistry::open`]/[`ModelRegistry::reload`] sweep orphaned
//!   temp dirs a crashed save left behind. Staged measurements are a
//!   checksummed append log whose replay skips (and counts) torn or
//!   corrupt lines instead of failing the onboard. Fault coverage lives
//!   in `tests/chaos.rs`; the invariants are documented in
//!   `docs/RESILIENCE.md`.
//!
//! The registry is deliberately runtime-free: everything needing the
//! non-`Send` PJRT [`Runtime`] (probe validation, training) borrows one
//! from the calling lane.

use crate::data::{Corpus, Entry, RunData};
use crate::gpu::Instance;
use crate::models::ModelId;
use crate::obs::{Obs, OpClass, Stage, Temp};
use crate::predictor::{Profet, TrainOptions};
use crate::runtime::Runtime;
use crate::sim::Workload;
use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::fmt;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// One coherent view of the model set: the epoch it was published at plus
/// the models themselves. Cloning is one `Arc` refcount bump — this is
/// what every request captures at admission and carries through the lane
/// queues, so concurrent swaps never change the models under a request.
#[derive(Clone)]
pub struct ModelSnapshot {
    /// Monotonic publish counter; starts at 1 for the initial load.
    pub epoch: u64,
    pub profet: Arc<Profet>,
}

/// Why a registry mutation was refused. Split out so the serving layer
/// can answer with distinct structured error kinds instead of one opaque
/// string.
#[derive(Debug)]
pub enum RegistryError {
    /// `onboard` found no staged measurements for the requested pair(s).
    NoStagedData,
    /// The candidate failed the pre-publish validation gate; the previous
    /// epoch is still serving.
    Rejected(anyhow::Error),
    /// Anything else (I/O, training failure, malformed staging data); the
    /// previous epoch is still serving.
    Other(anyhow::Error),
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegistryError::NoStagedData => {
                write!(f, "no staged measurements — send `ingest` lines first")
            }
            RegistryError::Rejected(e) => write!(f, "candidate rejected: {e:#}"),
            RegistryError::Other(e) => write!(f, "{e:#}"),
        }
    }
}

impl std::error::Error for RegistryError {}

/// Hyper-parameters for online onboarding (smaller than a full offline
/// `repro train` — staged corpora are small and the trainer lane should
/// turn them around in seconds).
#[derive(Debug, Clone)]
pub struct OnboardOptions {
    pub n_trees: usize,
    pub dnn_epochs: usize,
    pub poly_order: usize,
    pub seed: u64,
}

impl Default for OnboardOptions {
    fn default() -> OnboardOptions {
        OnboardOptions {
            n_trees: 40,
            dnn_epochs: 25,
            poly_order: 2,
            seed: 0xB0A7,
        }
    }
}

/// What an `onboard` published.
#[derive(Debug, Clone)]
pub struct OnboardReport {
    /// The newly current epoch.
    pub epoch: u64,
    /// Pairs retrained and published.
    pub pairs: Vec<(Instance, Instance)>,
    /// Staged measurements consumed across those pairs.
    pub staged: usize,
}

/// The minimum staged measurements per pair before `onboard` will try to
/// train (the ensemble itself requires ≥ 20 paired observations; checking
/// here gives a precise error before any training cost is paid).
pub const MIN_STAGED_PER_PAIR: usize = 20;

// ---------------------------------------------------------------------------
// Staging area
// ---------------------------------------------------------------------------

/// One profiled measurement for a device pair, as carried by the `ingest`
/// op: the anchor-side aggregated profile + latency and the target-side
/// ground-truth latency for one known workload.
#[derive(Debug, Clone, PartialEq)]
pub struct IngestRequest {
    pub anchor: Instance,
    pub target: Instance,
    pub model: ModelId,
    pub batch: usize,
    pub pixels: usize,
    pub profile: BTreeMap<String, f64>,
    pub anchor_latency_ms: f64,
    pub target_latency_ms: f64,
}

impl IngestRequest {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.name().into()));
        o.set("batch", Json::Num(self.batch as f64));
        o.set("pixels", Json::Num(self.pixels as f64));
        o.set("anchor_latency_ms", Json::Num(self.anchor_latency_ms));
        o.set("target_latency_ms", Json::Num(self.target_latency_ms));
        let mut prof = Json::obj();
        for (k, v) in &self.profile {
            prof.set(k, Json::Num(*v));
        }
        o.set("profile", prof);
        o
    }
}

/// Append-only on-disk staging for ingested measurements: one JSONL file
/// per `(anchor, target)` pair under `<model_dir>/staging/`. Writes are
/// serialized by construction — only the coordinator's single trainer
/// lane touches the staging area — so no file locking is needed.
///
/// Each line is `<16-hex-fnv1a> <json>`: the checksum lets replay detect
/// a torn tail (a crash mid-append) and skip it instead of failing the
/// whole onboard. Legacy lines that start directly with `{` (written
/// before the checksum existed) are still accepted. An append onto a
/// file whose last record is torn first terminates the torn bytes with a
/// newline, so one crash never corrupts later measurements.
///
/// Per-pair line counts are cached in memory (seeded from the file on
/// first touch), so an N-measurement ingest stream costs N appends, not
/// the N² line re-counts a count-by-re-reading scheme would.
pub struct StagingArea {
    dir: PathBuf,
    counts: Mutex<BTreeMap<(Instance, Instance), usize>>,
}

impl StagingArea {
    pub fn new(model_dir: &Path) -> StagingArea {
        StagingArea {
            dir: model_dir.join("staging"),
            counts: Mutex::new(BTreeMap::new()),
        }
    }

    /// The staging directory (`<model_dir>/staging`).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn pair_path(&self, anchor: Instance, target: Instance) -> PathBuf {
        self.dir
            .join(format!("{}_{}.jsonl", anchor.key(), target.key()))
    }

    /// Append one measurement; returns the total staged count for the
    /// pair afterwards. The record is checksummed (see the type docs) so
    /// a crash mid-append leaves a tail that replay skips, not a poisoned
    /// file.
    pub fn append(&self, req: &IngestRequest) -> Result<usize> {
        use std::io::{Read as _, Seek as _, SeekFrom};
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating {}", self.dir.display()))?;
        // seed the cached count from disk BEFORE the write so the
        // increment below lands on the right base (and a failed write
        // leaves the count untouched)
        let base = self.count(req.anchor, req.target);
        let path = self.pair_path(req.anchor, req.target);
        let mut f = std::fs::OpenOptions::new()
            .read(true)
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        // heal a torn tail left by a crashed append: if the file doesn't
        // end in a newline, start this record on a fresh line so the torn
        // bytes stay isolated on their own (checksum-invalid, skipped)
        // line instead of fusing with this record
        let needs_sep = f.metadata()?.len() > 0 && {
            f.seek(SeekFrom::End(-1))?;
            let mut last = [0u8; 1];
            f.read_exact(&mut last)?;
            last[0] != b'\n'
        };
        let json = req.to_json().to_string();
        let mut line = String::with_capacity(json.len() + 18);
        if needs_sep {
            line.push('\n');
        }
        line.push_str(&format!("{:016x} {json}\n", crate::util::fnv1a(json.as_bytes())));
        match crate::fp!("registry.staging.append") {
            Some(crate::util::failpoint::Hit::ReturnErr) => {
                anyhow::bail!("failpoint registry.staging.append: injected append failure")
            }
            Some(crate::util::failpoint::Hit::PartialWrite(n)) => {
                let n = n.min(line.len());
                f.write_all(&line.as_bytes()[..n])?;
                f.flush()?;
                anyhow::bail!("failpoint registry.staging.append: torn append after {n} bytes")
            }
            None => {}
        }
        f.write_all(line.as_bytes())?;
        f.flush()?;
        let n = base + 1;
        self.counts
            .lock()
            .unwrap()
            .insert((req.anchor, req.target), n);
        Ok(n)
    }

    /// Staged measurement count for one pair (0 when nothing staged).
    /// Served from the in-memory counter once a pair has been touched;
    /// cold pairs (e.g. staged by a previous process) are counted from
    /// the file once and cached. Only checksum-valid lines count, so a
    /// torn tail can never inflate the [`MIN_STAGED_PER_PAIR`] gate.
    pub fn count(&self, anchor: Instance, target: Instance) -> usize {
        if let Some(&n) = self.counts.lock().unwrap().get(&(anchor, target)) {
            return n;
        }
        let n = match std::fs::read_to_string(self.pair_path(anchor, target)) {
            Ok(text) => text
                .lines()
                .filter(|l| !l.trim().is_empty() && parse_staged_line(l).is_some())
                .count(),
            Err(_) => 0,
        };
        self.counts.lock().unwrap().insert((anchor, target), n);
        n
    }

    /// Every pair with at least one staged measurement, sorted.
    pub fn staged_pairs(&self) -> Vec<(Instance, Instance)> {
        let mut pairs = Vec::new();
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return pairs;
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(stem) = name.strip_suffix(".jsonl") else {
                continue;
            };
            let Some((a, t)) = stem.split_once('_') else {
                continue;
            };
            if let (Some(a), Some(t)) = (Instance::from_key(a), Instance::from_key(t)) {
                pairs.push((a, t));
            }
        }
        pairs.sort_unstable();
        pairs
    }

    /// Materialize the staged measurements for `pairs` as a training
    /// corpus: each measurement becomes one entry with an anchor run
    /// (profile + latency) and a target run (ground-truth latency; the
    /// target-side profile is not collected by `ingest` and is not needed
    /// for cross-instance training). Returns the corpus and the total
    /// measurement count.
    ///
    /// Torn or corrupt lines (checksum mismatch, unparseable JSON, a
    /// shape that doesn't decode) are skipped and counted — losing one
    /// measurement to a crash must never fail the onboard that consumes
    /// the other N.
    pub fn corpus_for(&self, pairs: &[(Instance, Instance)]) -> Result<(Corpus, usize)> {
        let mut corpus = Corpus::default();
        let mut total = 0usize;
        for &(anchor, target) in pairs {
            let path = self.pair_path(anchor, target);
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let mut skipped = 0usize;
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                let Some(entry) =
                    parse_staged_line(line).and_then(|j| entry_of(&j, anchor, target))
                else {
                    skipped += 1;
                    continue;
                };
                corpus.entries.push(entry);
                total += 1;
            }
            if skipped > 0 {
                eprintln!(
                    "registry: skipped {skipped} torn/corrupt staged line(s) in {}",
                    path.display()
                );
            }
        }
        Ok((corpus, total))
    }

    /// Drop the staged measurements for one pair (after a successful
    /// onboard consumed them).
    pub fn clear(&self, anchor: Instance, target: Instance) -> Result<()> {
        let path = self.pair_path(anchor, target);
        if path.exists() {
            std::fs::remove_file(&path)
                .with_context(|| format!("removing {}", path.display()))?;
        }
        self.counts.lock().unwrap().remove(&(anchor, target));
        Ok(())
    }
}

/// Validate one staged line and return its JSON payload. Checksummed
/// lines are `<16-hex-fnv1a> <json>`; legacy lines start directly with
/// `{` and carry no checksum. `None` means torn/corrupt (truncated hex
/// prefix, checksum mismatch, unparseable JSON) — callers skip it.
fn parse_staged_line(line: &str) -> Option<Json> {
    let line = line.trim_end();
    let json = if line.starts_with('{') {
        line // legacy, pre-checksum format
    } else {
        let hex = line.get(..16)?;
        let rest = line.get(16..)?.strip_prefix(' ')?;
        let sum = u64::from_str_radix(hex, 16).ok()?;
        if sum != crate::util::fnv1a(rest.as_bytes()) {
            return None;
        }
        rest
    };
    Json::parse(json).ok()
}

/// Decode one staged measurement into a corpus entry; `None` for a
/// payload whose shape doesn't decode (treated like a torn line by
/// [`StagingArea::corpus_for`]).
fn entry_of(j: &Json, anchor: Instance, target: Instance) -> Option<Entry> {
    let model = ModelId::from_name(j.req_str("model").ok()?)?;
    let workload = Workload::new(model, j.req_usize("batch").ok()?, j.req_usize("pixels").ok()?);
    let mut profile = BTreeMap::new();
    if let Some(Json::Obj(m)) = j.get("profile") {
        for (op, v) in m {
            profile.insert(op.clone(), v.as_f64()?);
        }
    }
    let mut runs = BTreeMap::new();
    runs.insert(
        anchor,
        RunData {
            profile,
            latency_ms: j.req_f64("anchor_latency_ms").ok()?,
        },
    );
    runs.insert(
        target,
        RunData {
            profile: BTreeMap::new(),
            latency_ms: j.req_f64("target_latency_ms").ok()?,
        },
    );
    Some(Entry { workload, runs })
}

// ---------------------------------------------------------------------------
// The registry
// ---------------------------------------------------------------------------

/// The canned probe profile for the validation gate: a plausible
/// aggregated CNN profile every healthy cross-instance ensemble must map
/// to a finite, positive latency. Ops unknown to a model's feature space
/// vectorize to zero, so the probe also exercises the
/// frozen-vocabulary path an onboarded model serves with.
fn probe_profile() -> BTreeMap<String, f64> {
    BTreeMap::from([
        ("Conv2D".to_string(), 120.0),
        ("MatMul".to_string(), 45.0),
        ("Relu".to_string(), 12.0),
        ("FusedBatchNormV3".to_string(), 20.0),
    ])
}

/// Anchor latency the probe profile is presented at.
const PROBE_ANCHOR_LATENCY_MS: f64 = 200.0;

/// Epoch-stamped, hot-swappable holder of the current [`Profet`] model
/// set. See the [module docs](self) for the full design; in short:
/// readers clone an `Arc` under a lightweight lock and keep that snapshot
/// for the life of their request, writers validate a candidate end to end
/// and then swap the `Arc` in one short critical section.
pub struct ModelRegistry {
    current: Mutex<ModelSnapshot>,
    /// Lock-free mirror of the current epoch (for `stats` and hot paths
    /// that only need the number).
    epoch: AtomicU64,
    /// Unix milliseconds of the last successful publish after the initial
    /// load; `0` until the first `reload`/`onboard` lands.
    last_reload_unix_ms: AtomicU64,
    /// Fingerprint of the model dir contents at the last load/publish —
    /// lets the mtime watcher skip reloads for directories it has already
    /// seen (including the registry's own `onboard` saves).
    dir_fingerprint: AtomicU64,
    model_dir: PathBuf,
    staging: StagingArea,
    /// Latency observatory for timing the publish critical section
    /// ([`Stage::RegistrySwap`]). Wired in by
    /// [`EnginePool::spawn_with_registry`](crate::coordinator::dispatch::EnginePool::spawn_with_registry);
    /// a registry used standalone (tests, offline tools) simply skips the
    /// recording.
    obs: OnceLock<Arc<Obs>>,
}

impl ModelRegistry {
    /// Load the initial epoch from `model_dir` (manifest-checked by
    /// [`Profet::load`]). The full runtime probe gate runs on the trainer
    /// lane once it has a [`Runtime`] — see
    /// [`ModelRegistry::validate`].
    pub fn open(model_dir: PathBuf) -> Result<ModelRegistry> {
        // a crash mid-save (see `Profet::save`) can leave orphaned
        // `<dir>.tmp.<pid>.<seq>` staging siblings behind; sweep them
        // before the load so they never accumulate across restarts
        let swept = crate::predictor::sweep_orphaned_saves(&model_dir);
        if swept > 0 {
            eprintln!(
                "registry: swept {swept} orphaned save dir(s) beside {}",
                model_dir.display()
            );
        }
        let profet = Profet::load(&model_dir)
            .with_context(|| format!("models: {}", model_dir.display()))?;
        Ok(ModelRegistry::with_model(profet, model_dir))
    }

    /// Wrap an already-built model set (tests; also the path `serve`
    /// takes when it trained in-process). Epoch starts at 1.
    pub fn with_model(profet: Profet, model_dir: PathBuf) -> ModelRegistry {
        let reg = ModelRegistry {
            current: Mutex::new(ModelSnapshot {
                epoch: 1,
                profet: Arc::new(profet),
            }),
            epoch: AtomicU64::new(1),
            last_reload_unix_ms: AtomicU64::new(0),
            dir_fingerprint: AtomicU64::new(0),
            staging: StagingArea::new(&model_dir),
            model_dir,
            obs: OnceLock::new(),
        };
        reg.dir_fingerprint
            .store(dir_fingerprint(&reg.model_dir), Ordering::SeqCst);
        reg
    }

    /// The model directory this registry loads from and persists to.
    pub fn model_dir(&self) -> &Path {
        &self.model_dir
    }

    /// The staging area for `ingest`ed measurements.
    pub fn staging(&self) -> &StagingArea {
        &self.staging
    }

    /// Attach the latency observatory that publish critical sections
    /// report to. First caller wins; later calls are ignored (the
    /// registry outlives no pool, so this only matters in tests that
    /// share a registry across pools).
    pub(crate) fn set_obs(&self, obs: Arc<Obs>) {
        let _ = self.obs.set(obs);
    }

    /// Clone the current snapshot: one short lock, one `Arc` refcount
    /// bump, zero allocations. Requests call this exactly once at
    /// admission and carry the snapshot with them.
    pub fn snapshot(&self) -> ModelSnapshot {
        self.current.lock().unwrap().clone()
    }

    /// The current epoch (lock-free; for `stats` and monitoring).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Unix ms of the last successful post-boot publish (0 = never).
    pub fn last_reload_unix_ms(&self) -> u64 {
        self.last_reload_unix_ms.load(Ordering::SeqCst)
    }

    /// The pre-publish validation gate: every cross-instance ensemble
    /// must map the canned probe profile to a finite, positive latency,
    /// and every batch/pixel model must interpolate finitely across the
    /// modeled batch/pixel range. Pure read over the candidate — run it
    /// before [`ModelRegistry::swap`] (or use
    /// [`ModelRegistry::publish`], which does both).
    pub fn validate(rt: &Runtime, profet: &Profet) -> Result<()> {
        anyhow::ensure!(
            !profet.cross.is_empty(),
            "candidate has no cross-instance models"
        );
        let probe = probe_profile();
        for (&(a, t), _) in &profet.cross {
            let (lat, _member) = profet
                .predict_cross(rt, a, t, &probe, PROBE_ANCHOR_LATENCY_MS)
                .with_context(|| format!("probe predict {a}->{t} failed"))?;
            anyhow::ensure!(
                lat.is_finite() && lat > 0.0,
                "probe predict {a}->{t} returned non-finite/non-positive latency {lat}"
            );
        }
        for (&g, _) in &profet.scale {
            for (b, p) in [(16usize, 32usize), (64, 64), (256, 256)] {
                let vb = profet.predict_batch_size(g, b, 10.0, 100.0)?;
                let vp = profet.predict_pixel_size(g, p, 10.0, 100.0)?;
                anyhow::ensure!(
                    vb.is_finite() && vp.is_finite(),
                    "probe interpolation on {g} returned non-finite latency"
                );
            }
        }
        Ok(())
    }

    /// Atomically publish an (already validated) candidate as the new
    /// current epoch and return that epoch. The lock is held only for the
    /// pointer swap — readers are never blocked behind loading,
    /// validation, or training, all of which happen before this call.
    ///
    /// Prefer [`ModelRegistry::publish`], which runs the validation gate
    /// first; `swap` exists for callers that have already validated (or
    /// measured) the candidate through other means.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use repro::coordinator::registry::ModelRegistry;
    /// use repro::predictor::Profet;
    ///
    /// let registry = ModelRegistry::open("models".into())?;
    /// let candidate = Profet::load("models_v2")?;
    /// let rt = repro::runtime::load_default()?;
    /// ModelRegistry::validate(&rt, &candidate)?; // gate first ...
    /// let epoch = registry.swap(candidate);      // ... then swap
    /// assert_eq!(epoch, registry.epoch());
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn swap(&self, profet: Profet) -> u64 {
        self.swap_with_fingerprint(profet, dir_fingerprint(&self.model_dir))
    }

    /// [`ModelRegistry::swap`] recording `fp` as the model-dir
    /// fingerprint instead of re-scanning the directory. Callers that
    /// loaded the candidate from disk pass the fingerprint captured
    /// **before** the load: if the directory changed while the candidate
    /// was loading/validating, the stored value won't match the current
    /// contents and the watcher's next conditional reload picks the new
    /// state up — a post-publish re-scan would absorb that change
    /// unloaded and make the watcher skip it forever.
    fn swap_with_fingerprint(&self, profet: Profet, fp: u64) -> u64 {
        let profet = Arc::new(profet);
        let t0 = Instant::now();
        let next = {
            let mut cur = self.current.lock().unwrap();
            let next = cur.epoch + 1;
            *cur = ModelSnapshot {
                epoch: next,
                profet,
            };
            next
        };
        if let Some(obs) = self.obs.get() {
            // the pause every in-flight snapshot() briefly contends with
            obs.record(Stage::RegistrySwap, OpClass::Other, Temp::Cold, t0.elapsed());
        }
        self.epoch.store(next, Ordering::SeqCst);
        self.last_reload_unix_ms
            .store(unix_ms(), Ordering::SeqCst);
        self.dir_fingerprint.store(fp, Ordering::SeqCst);
        next
    }

    /// Validate, then swap. On a gate failure the current epoch keeps
    /// serving untouched.
    pub fn publish(&self, rt: &Runtime, profet: Profet) -> Result<u64, RegistryError> {
        ModelRegistry::validate(rt, &profet).map_err(RegistryError::Rejected)?;
        Ok(self.swap(profet))
    }

    /// Re-load the model directory and publish it as a new epoch (the
    /// `reload` op). With `only_if_changed` (the mtime watcher's mode) a
    /// directory whose fingerprint matches the last load/publish is
    /// skipped, returning `Ok(None)`.
    pub fn reload(
        &self,
        rt: &Runtime,
        only_if_changed: bool,
    ) -> Result<Option<u64>, RegistryError> {
        // recover first: a crashed save leaves orphaned temp siblings
        // (never a torn serving dir — see `Profet::save`); sweeping here
        // keeps long-lived watched processes tidy without a restart.
        // Orphans live BESIDE the model dir, so this can't perturb the
        // fingerprint captured below.
        let swept = crate::predictor::sweep_orphaned_saves(&self.model_dir);
        if swept > 0 {
            eprintln!(
                "registry: swept {swept} orphaned save dir(s) beside {}",
                self.model_dir.display()
            );
        }
        // capture the fingerprint BEFORE loading: this is the directory
        // state the candidate corresponds to. A concurrent writer racing
        // the load changes the live fingerprint past this value, so the
        // next conditional reload re-reads the finished directory instead
        // of silently absorbing a half-copied one.
        let fp = dir_fingerprint(&self.model_dir);
        if only_if_changed && fp == self.dir_fingerprint.load(Ordering::SeqCst) {
            return Ok(None);
        }
        let candidate = Profet::load(&self.model_dir)
            .with_context(|| format!("reloading {}", self.model_dir.display()))
            .map_err(RegistryError::Rejected)?;
        ModelRegistry::validate(rt, &candidate).map_err(RegistryError::Rejected)?;
        Ok(Some(self.swap_with_fingerprint(candidate, fp)))
    }

    /// Online onboarding (the `onboard` op): train the staged pairs
    /// (all of them, or just `pair`), merge into the current model set,
    /// persist, and publish. Consumed staging files are cleared only
    /// after the new epoch is live. On any failure the current epoch
    /// keeps serving and the staged measurements stay for a retry.
    pub fn onboard(
        &self,
        rt: &Runtime,
        pair: Option<(Instance, Instance)>,
        opts: &OnboardOptions,
    ) -> Result<OnboardReport, RegistryError> {
        let (candidate, pairs, staged_n) = self.train_staged_candidate(rt, pair, opts)?;
        candidate
            .save(&self.model_dir)
            .with_context(|| format!("persisting {}", self.model_dir.display()))
            .map_err(RegistryError::Other)?;
        let epoch = self.swap(candidate);
        for &(a, t) in &pairs {
            // post-publish cleanup: a failure here leaves harmless
            // already-consumed files behind, never a half-published epoch
            let _ = self.staging.clear(a, t);
        }
        Ok(OnboardReport {
            epoch,
            pairs,
            staged: staged_n,
        })
    }

    /// Dry-run `onboard` (the `dry_run` wire flag): the full
    /// train-and-validate pipeline, but nothing persisted, published, or
    /// cleared — staging stays intact for the real run. Returns
    /// `(pairs, staged)` counts mirroring [`OnboardReport`]. The route
    /// tier uses this as every node's phase-1 vote before a fleet-wide
    /// publish.
    pub fn check_onboard(
        &self,
        rt: &Runtime,
        pair: Option<(Instance, Instance)>,
        opts: &OnboardOptions,
    ) -> Result<(usize, usize), RegistryError> {
        let (_, pairs, staged_n) = self.train_staged_candidate(rt, pair, opts)?;
        Ok((pairs.len(), staged_n))
    }

    /// Dry-run `reload`: load and validate the on-disk candidate without
    /// swapping it in. The serving epoch is untouched either way.
    pub fn check_reload(&self, rt: &Runtime) -> Result<(), RegistryError> {
        let candidate = Profet::load(&self.model_dir)
            .with_context(|| format!("reloading {}", self.model_dir.display()))
            .map_err(RegistryError::Rejected)?;
        ModelRegistry::validate(rt, &candidate).map_err(RegistryError::Rejected)?;
        Ok(())
    }

    /// Shared `onboard`/`check_onboard` front half: resolve staged
    /// pairs, gate their counts, train the merged candidate, and run the
    /// validation probe — no side effects on disk or the serving epoch.
    fn train_staged_candidate(
        &self,
        rt: &Runtime,
        pair: Option<(Instance, Instance)>,
        opts: &OnboardOptions,
    ) -> Result<(Profet, Vec<(Instance, Instance)>, usize), RegistryError> {
        let pairs = self.staged_pairs_for(pair)?;
        for &(a, t) in &pairs {
            let n = self.staging.count(a, t);
            if n < MIN_STAGED_PER_PAIR {
                return Err(RegistryError::Other(anyhow!(
                    "pair {a}->{t} has {n} staged measurement(s); needs ≥ {MIN_STAGED_PER_PAIR}"
                )));
            }
        }
        let (corpus, staged_n) = self
            .staging
            .corpus_for(&pairs)
            .map_err(RegistryError::Other)?;
        let train_idx: Vec<usize> = (0..corpus.entries.len()).collect();
        let base = self.snapshot();
        let train_opts = TrainOptions {
            anchors: Vec::new(), // unused by retrain_pairs
            targets: Vec::new(),
            clustering: true, // unused: the feature space is frozen
            poly_order: opts.poly_order,
            n_trees: opts.n_trees,
            dnn_epochs: opts.dnn_epochs,
            seed: opts.seed,
        };
        let candidate = base
            .profet
            .retrain_pairs(rt, &corpus, &train_idx, &pairs, &train_opts)
            .map_err(RegistryError::Other)?;
        // gate BEFORE persisting: a rejected candidate must not overwrite
        // the on-disk models backing the currently serving epoch (it
        // would also put the --model-dir-watch poller into a rejected-
        // reload loop)
        ModelRegistry::validate(rt, &candidate).map_err(RegistryError::Rejected)?;
        Ok((candidate, pairs, staged_n))
    }

    /// Resolve which staged pairs an `onboard` should train: everything
    /// staged, or just `pair` when given. Empty resolution is the
    /// distinct [`RegistryError::NoStagedData`] so the wire can answer
    /// with its own error kind.
    fn staged_pairs_for(
        &self,
        pair: Option<(Instance, Instance)>,
    ) -> Result<Vec<(Instance, Instance)>, RegistryError> {
        let staged = self.staging.staged_pairs();
        let pairs: Vec<(Instance, Instance)> = match pair {
            Some(p) => staged.into_iter().filter(|&q| q == p).collect(),
            None => staged,
        };
        if pairs.is_empty() {
            return Err(RegistryError::NoStagedData);
        }
        Ok(pairs)
    }
}

/// Current wall clock as unix milliseconds.
fn unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Order-independent fingerprint of the model dir's top-level `*.json`
/// files (name, mtime, size). Subdirectories — notably `staging/` — are
/// excluded on purpose: ingesting measurements must not look like a model
/// change to the `--model-dir-watch` poller.
pub(crate) fn dir_fingerprint(dir: &Path) -> u64 {
    let mut acc = 0u64;
    let Ok(entries) = std::fs::read_dir(dir) else {
        return 0;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if !path.is_file() || path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let Ok(meta) = entry.metadata() else { continue };
        let mtime = meta
            .modified()
            .ok()
            .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let name = entry.file_name();
        let mut h = crate::util::fnv1a(name.to_string_lossy().as_bytes());
        h ^= mtime.rotate_left(17) ^ meta.len().rotate_left(41);
        acc = acc.wrapping_add(h);
    }
    acc
}

/// A model-free `Profet` over an empty vocabulary — registry/dispatch
/// mechanics tests don't need trained models (everything that does is
/// covered by the runtime-gated integration tests).
#[cfg(test)]
pub(crate) fn empty_profet() -> Profet {
    Profet {
        feature_space: crate::features::FeatureSpace::fit(&[], false, 4).unwrap(),
        cross: BTreeMap::new(),
        scale: BTreeMap::new(),
    }
}

/// A registry over [`empty_profet`] in a scratch temp dir (test seam for
/// the dispatcher's mock pools).
#[cfg(test)]
pub(crate) fn test_registry(tag: &str) -> ModelRegistry {
    let dir = std::env::temp_dir().join(format!("repro_testreg_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).ok();
    ModelRegistry::with_model(empty_profet(), dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "repro_registry_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    // the failpoint registry is process-global and lib tests run in
    // parallel: every test that either arms `registry.staging.append` or
    // calls `StagingArea::append` takes this lock so an armed window
    // can't fail an unrelated test's append.
    static FP_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn fp_lock() -> std::sync::MutexGuard<'static, ()> {
        // a panicking holder (failed assert) must not wedge later tests
        FP_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn ingest(anchor: Instance, target: Instance, batch: usize) -> IngestRequest {
        IngestRequest {
            anchor,
            target,
            model: ModelId::ALL[0],
            batch,
            pixels: 64,
            profile: BTreeMap::from([("Conv2D".to_string(), batch as f64)]),
            anchor_latency_ms: 10.0 + batch as f64,
            target_latency_ms: 5.0 + batch as f64,
        }
    }

    #[test]
    fn snapshot_epoch_and_swap_are_coherent() {
        let dir = temp_dir("swap");
        let reg = ModelRegistry::with_model(empty_profet(), dir);
        assert_eq!(reg.epoch(), 1);
        assert_eq!(reg.last_reload_unix_ms(), 0);
        let before = reg.snapshot();
        assert_eq!(before.epoch, 1);

        let e2 = reg.swap(empty_profet());
        assert_eq!(e2, 2);
        assert_eq!(reg.epoch(), 2);
        assert!(reg.last_reload_unix_ms() > 0);
        // the pre-swap snapshot still points at the old epoch's models —
        // in-flight requests are answered by the epoch they started on
        assert_eq!(before.epoch, 1);
        assert_eq!(reg.snapshot().epoch, 2);
    }

    #[test]
    fn concurrent_readers_never_observe_a_torn_snapshot() {
        let dir = temp_dir("race");
        let reg = Arc::new(ModelRegistry::with_model(empty_profet(), dir));
        let stop = Arc::new(AtomicU64::new(0));
        let mut joins = Vec::new();
        for _ in 0..4 {
            let reg = reg.clone();
            let stop = stop.clone();
            joins.push(std::thread::spawn(move || {
                let mut last = 0u64;
                while stop.load(Ordering::Relaxed) == 0 {
                    let snap = reg.snapshot();
                    // epochs only move forward under concurrent swaps
                    assert!(snap.epoch >= last, "{} < {last}", snap.epoch);
                    last = snap.epoch;
                }
            }));
        }
        for _ in 0..50 {
            reg.swap(empty_profet());
        }
        stop.store(1, Ordering::Relaxed);
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(reg.epoch(), 51);
    }

    #[test]
    fn staging_append_count_pairs_corpus_roundtrip() {
        let _g = fp_lock();
        let dir = temp_dir("staging");
        let staging = StagingArea::new(&dir);
        assert_eq!(staging.count(Instance::G4dn, Instance::G5), 0);
        assert!(staging.staged_pairs().is_empty());

        for b in [16, 32, 64] {
            let n = staging.append(&ingest(Instance::G4dn, Instance::G5, b)).unwrap();
            assert_eq!(n, [16, 32, 64].iter().position(|&x| x == b).unwrap() + 1);
        }
        staging.append(&ingest(Instance::P3, Instance::Ac1, 128)).unwrap();
        assert_eq!(
            staging.staged_pairs(),
            vec![(Instance::G4dn, Instance::G5), (Instance::P3, Instance::Ac1)]
        );

        let (corpus, total) = staging
            .corpus_for(&[(Instance::G4dn, Instance::G5)])
            .unwrap();
        assert_eq!(total, 3);
        assert_eq!(corpus.entries.len(), 3);
        let e = &corpus.entries[0];
        assert_eq!(e.workload.batch, 16);
        let anchor_run = &e.runs[&Instance::G4dn];
        assert_eq!(anchor_run.profile["Conv2D"], 16.0);
        assert_eq!(anchor_run.latency_ms, 26.0);
        assert_eq!(e.runs[&Instance::G5].latency_ms, 21.0);

        staging.clear(Instance::G4dn, Instance::G5).unwrap();
        assert_eq!(staging.count(Instance::G4dn, Instance::G5), 0);
        assert_eq!(staging.staged_pairs(), vec![(Instance::P3, Instance::Ac1)]);
    }

    #[test]
    fn torn_or_corrupt_staged_tail_is_skipped_not_fatal() {
        let _g = fp_lock();
        let dir = temp_dir("torn");
        let staging = StagingArea::new(&dir);
        for b in [16, 32] {
            staging.append(&ingest(Instance::G4dn, Instance::G5, b)).unwrap();
        }
        let path = staging.dir().join("g4dn_g5.jsonl");
        let text = std::fs::read_to_string(&path).unwrap();
        // every written line carries a checksum over its JSON payload
        for line in text.lines() {
            let (hex, rest) = line.split_at(16);
            assert_eq!(
                u64::from_str_radix(hex, 16).unwrap(),
                crate::util::fnv1a(rest[1..].as_bytes())
            );
        }
        // a legacy (pre-checksum) line still replays; a torn tail — the
        // first bytes of a checksummed record, no newline — does not
        let legacy = ingest(Instance::G4dn, Instance::G5, 64).to_json().to_string();
        let torn = &text.lines().next().unwrap()[..25];
        std::fs::write(&path, format!("{text}{legacy}\n{torn}")).unwrap();

        // a fresh staging area (cold count cache) sees only valid lines
        let staging = StagingArea::new(&dir);
        assert_eq!(staging.count(Instance::G4dn, Instance::G5), 3);
        let (corpus, total) = staging
            .corpus_for(&[(Instance::G4dn, Instance::G5)])
            .unwrap();
        assert_eq!(total, 3);
        assert_eq!(corpus.entries.len(), 3);
        assert_eq!(corpus.entries[2].workload.batch, 64);

        // appending onto the torn tail heals it: the new record starts on
        // its own line and the torn bytes stay isolated and skipped
        staging.append(&ingest(Instance::G4dn, Instance::G5, 128)).unwrap();
        let staging = StagingArea::new(&dir);
        assert_eq!(staging.count(Instance::G4dn, Instance::G5), 4);
        let (corpus, total) = staging
            .corpus_for(&[(Instance::G4dn, Instance::G5)])
            .unwrap();
        assert_eq!(total, 4);
        assert_eq!(corpus.entries[3].workload.batch, 128);
    }

    #[test]
    fn injected_torn_append_is_invisible_to_replay() {
        let _g = fp_lock();
        let dir = temp_dir("fpappend");
        let staging = StagingArea::new(&dir);
        staging.append(&ingest(Instance::G4dn, Instance::G5, 16)).unwrap();
        crate::util::failpoint::configure(
            "registry.staging.append",
            crate::util::failpoint::Action::PartialWrite(10),
        );
        let r = staging.append(&ingest(Instance::G4dn, Instance::G5, 32));
        crate::util::failpoint::clear("registry.staging.append");
        assert!(r.is_err(), "torn append must surface as an error");
        // the torn half-record is invisible to a cold recount and replay
        let staging = StagingArea::new(&dir);
        assert_eq!(staging.count(Instance::G4dn, Instance::G5), 1);
        let (_, total) = staging
            .corpus_for(&[(Instance::G4dn, Instance::G5)])
            .unwrap();
        assert_eq!(total, 1);
        // and the next append lands cleanly after the torn bytes
        assert_eq!(
            staging.append(&ingest(Instance::G4dn, Instance::G5, 64)).unwrap(),
            2
        );
        let (corpus, total) = staging
            .corpus_for(&[(Instance::G4dn, Instance::G5)])
            .unwrap();
        assert_eq!(total, 2);
        assert_eq!(corpus.entries[1].workload.batch, 64);
    }

    #[test]
    fn onboard_without_staged_data_is_a_distinct_error() {
        let _g = fp_lock();
        let dir = temp_dir("nostage");
        let reg = ModelRegistry::with_model(empty_profet(), dir);
        // no runtime needed: the staged-pairs check fires before training
        match reg.staged_pairs_for(None) {
            Err(RegistryError::NoStagedData) => {}
            other => panic!("expected NoStagedData, got {other:?}"),
        }
        // a pair filter that matches nothing staged is the same error
        reg.staging()
            .append(&ingest(Instance::G4dn, Instance::G5, 16))
            .unwrap();
        match reg.staged_pairs_for(Some((Instance::P3, Instance::Ac1))) {
            Err(RegistryError::NoStagedData) => {}
            other => panic!("expected NoStagedData, got {other:?}"),
        }
        assert_eq!(
            reg.staged_pairs_for(None).unwrap(),
            vec![(Instance::G4dn, Instance::G5)]
        );
    }

    #[test]
    fn ingest_does_not_disturb_the_model_dir_fingerprint() {
        let _g = fp_lock();
        let dir = temp_dir("fingerprint");
        std::fs::write(dir.join("feature_space.json"), "{}").unwrap();
        let before = dir_fingerprint(&dir);
        assert_ne!(before, 0);
        // staged measurements land in a subdirectory the watcher ignores
        let staging = StagingArea::new(&dir);
        staging.append(&ingest(Instance::G4dn, Instance::G5, 16)).unwrap();
        assert_eq!(dir_fingerprint(&dir), before);
        // touching a top-level model file does change it
        std::fs::write(dir.join("cross_g4dn_g5.json"), "{}").unwrap();
        assert_ne!(dir_fingerprint(&dir), before);
    }
}
