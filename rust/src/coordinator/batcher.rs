//! Inference engine + request batcher.
//!
//! One worker thread owns the PJRT runtime and the trained PROFET models
//! (the xla handles are not `Send`, so they never leave this thread).
//! Connection threads submit [`Job`]s through an mpsc channel; the worker
//! drains the queue, groups phase-1 predictions by (anchor, target), and
//! runs each group as ONE batched MLP artifact execution — the dynamic
//! batching that keeps the fixed-shape `b_pred` HLO fed.

use crate::coordinator::protocol::{PredictRequest, Response};
use crate::gpu::Instance;
use crate::predictor::Profet;
use crate::runtime::Runtime;
use crate::util::Json;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Work item submitted to the engine thread.
pub enum Job {
    Predict(PredictRequest, Sender<Response>),
    BatchSize {
        instance: Instance,
        batch: usize,
        t_min: f64,
        t_max: f64,
        reply: Sender<Response>,
    },
    PixelSize {
        instance: Instance,
        pixels: usize,
        t_min: f64,
        t_max: f64,
        reply: Sender<Response>,
    },
    Shutdown,
}

/// Serving statistics (exposed for tests/monitoring).
#[derive(Debug, Default)]
pub struct BatcherStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of group sizes — requests served per artifact execution.
    pub batched_requests: AtomicU64,
}

/// Handle to the engine thread.
pub struct Batcher {
    tx: Sender<Job>,
    pub stats: Arc<BatcherStats>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Batching window: how long the worker waits to coalesce more requests
/// after the first one arrives.
const BATCH_WINDOW: Duration = Duration::from_millis(2);

impl Batcher {
    /// Spawn the engine thread: loads artifacts + the model directory
    /// inside the thread (nothing non-Send crosses).
    pub fn spawn(artifact_dir: PathBuf, model_dir: PathBuf) -> Result<Batcher> {
        let (tx, rx) = channel::<Job>();
        let stats = Arc::new(BatcherStats::default());
        let stats2 = stats.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("profet-engine".into())
            .spawn(move || {
                let rt = match Runtime::load(&artifact_dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("runtime: {e:#}")));
                        return;
                    }
                };
                let profet = match Profet::load(&model_dir) {
                    Ok(p) => p,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("models: {e:#}")));
                        return;
                    }
                };
                let _ = ready_tx.send(Ok(()));
                engine_loop(rt, profet, rx, &stats2);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died"))?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(Batcher {
            tx,
            stats,
            join: Some(join),
        })
    }

    pub fn submit(&self, job: Job) {
        let _ = self.tx.send(job);
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_loop(rt: Runtime, profet: Profet, rx: Receiver<Job>, stats: &BatcherStats) {
    loop {
        // block for the first job
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return,
        };
        let mut predicts: BTreeMap<(Instance, Instance), Vec<(PredictRequest, Sender<Response>)>> =
            BTreeMap::new();
        let mut immediate = Vec::new();
        let mut shutdown = false;
        let absorb = |job: Job,
                          predicts: &mut BTreeMap<
            (Instance, Instance),
            Vec<(PredictRequest, Sender<Response>)>,
        >,
                          immediate: &mut Vec<Job>,
                          shutdown: &mut bool| {
            match job {
                Job::Predict(req, reply) => {
                    predicts.entry((req.anchor, req.target)).or_default().push((req, reply));
                }
                Job::Shutdown => *shutdown = true,
                other => immediate.push(other),
            }
        };
        absorb(first, &mut predicts, &mut immediate, &mut shutdown);
        // coalesce within the window
        let deadline = std::time::Instant::now() + BATCH_WINDOW;
        while let Some(remaining) = deadline.checked_duration_since(std::time::Instant::now()) {
            match rx.recv_timeout(remaining) {
                Ok(j) => absorb(j, &mut predicts, &mut immediate, &mut shutdown),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // immediate (non-batched) jobs
        for job in immediate {
            match job {
                Job::BatchSize {
                    instance,
                    batch,
                    t_min,
                    t_max,
                    reply,
                } => {
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let resp = match profet.predict_batch_size(instance, batch, t_min, t_max) {
                        Ok(v) => Response::ok_obj(|o| {
                            o.set("latency_ms", Json::Num(v));
                        }),
                        Err(e) => Response::Err(format!("{e:#}")),
                    };
                    let _ = reply.send(resp);
                }
                Job::PixelSize {
                    instance,
                    pixels,
                    t_min,
                    t_max,
                    reply,
                } => {
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let resp = match profet.predict_pixel_size(instance, pixels, t_min, t_max) {
                        Ok(v) => Response::ok_obj(|o| {
                            o.set("latency_ms", Json::Num(v));
                        }),
                        Err(e) => Response::Err(format!("{e:#}")),
                    };
                    let _ = reply.send(resp);
                }
                _ => {}
            }
        }

        // batched phase-1 predictions: one artifact execution per group
        for ((anchor, target), group) in predicts {
            stats.requests.fetch_add(group.len() as u64, Ordering::Relaxed);
            let Some(model) = profet.cross.get(&(anchor, target)) else {
                for (_, reply) in group {
                    let _ = reply.send(Response::Err(format!(
                        "no model for {anchor}->{target}"
                    )));
                }
                continue;
            };
            let rows: Vec<Vec<f64>> = group
                .iter()
                .map(|(r, _)| profet.feature_space.vectorize(&r.profile))
                .collect();
            let lats: Vec<f64> = group.iter().map(|(r, _)| r.anchor_latency_ms).collect();
            let feats = match crate::ml::FeatureMatrix::from_rows(&rows) {
                Ok(m) => m,
                Err(e) => {
                    let msg = format!("feature matrix: {e:#}");
                    for (_, reply) in group {
                        let _ = reply.send(Response::Err(msg.clone()));
                    }
                    continue;
                }
            };
            match model.predict_batch(&rt, &feats, &lats) {
                Ok(preds) => {
                    stats.batches.fetch_add(1, Ordering::Relaxed);
                    stats
                        .batched_requests
                        .fetch_add(group.len() as u64, Ordering::Relaxed);
                    for ((_, reply), (v, member)) in group.into_iter().zip(preds) {
                        let _ = reply.send(Response::ok_obj(|o| {
                            o.set("latency_ms", Json::Num(v));
                            o.set("member", Json::Str(member.name().into()));
                        }));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for (_, reply) in group {
                        let _ = reply.send(Response::Err(msg.clone()));
                    }
                }
            }
        }

        if shutdown {
            return;
        }
    }
}
