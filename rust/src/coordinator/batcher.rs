//! Inference engine + request batcher.
//!
//! One worker thread owns the PJRT runtime and the trained PROFET models
//! (the xla handles are not `Send`, so they never leave this thread).
//! Connection threads submit [`Job`]s through an mpsc channel; the worker
//! drains the queue, groups phase-1 predictions by (anchor, target), and
//! runs each group as ONE batched MLP artifact execution — the dynamic
//! batching that keeps the fixed-shape `b_pred` HLO fed.
//!
//! The engine also owns the advisor state: the sharded phase-1
//! [`PredictionCache`] (consulted before every ensemble execution —
//! repeat traffic short-circuits to a stored, bitwise-identical
//! prediction; within one batch, duplicate requests collapse to one row)
//! and the memoized multi-GPU [`ScalingTable`] behind the `recommend` /
//! `plan` ops.

use crate::advisor::{
    self, CacheKey, CacheStats, Candidate, Objective, PlanChoice, PredictionCache, SweepRequest,
    TrainingJob,
};
use crate::coordinator::protocol::{PredictRequest, Response};
use crate::gpu::Instance;
use crate::predictor::Profet;
use crate::runtime::Runtime;
use crate::sim::multigpu::ScalingTable;
use crate::util::Json;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Work item submitted to the engine thread.
pub enum Job {
    Predict(PredictRequest, Sender<Response>),
    BatchSize {
        instance: Instance,
        batch: usize,
        t_min: f64,
        t_max: f64,
        reply: Sender<Response>,
    },
    PixelSize {
        instance: Instance,
        pixels: usize,
        t_min: f64,
        t_max: f64,
        reply: Sender<Response>,
    },
    Recommend {
        query: SweepRequest,
        top_k: usize,
        reply: Sender<Response>,
    },
    Plan {
        query: SweepRequest,
        job: TrainingJob,
        objective: Objective,
        reply: Sender<Response>,
    },
    Shutdown,
}

/// Serving statistics (exposed for tests/monitoring).
#[derive(Debug, Default)]
pub struct BatcherStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of group sizes — *unique* predictions computed per artifact
    /// execution (cache hits and in-batch duplicates don't count).
    pub batched_requests: AtomicU64,
    /// Phase-1 prediction-cache hit/miss counters (predict + advisor).
    pub cache: CacheStats,
}

/// Handle to the engine thread.
pub struct Batcher {
    tx: Sender<Job>,
    pub stats: Arc<BatcherStats>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Batching window: how long the worker waits to coalesce more requests
/// after the first one arrives.
const BATCH_WINDOW: Duration = Duration::from_millis(2);

/// Phase-1 prediction cache shape: shards bound lock scope, the total
/// capacity bounds memory. Each entry carries the canonical quantized
/// profile bytes (collision-proof equality), ~1-2 KB for a realistic
/// aggregated profile, so 32k entries cap the cache around tens of MB.
const CACHE_SHARDS: usize = 16;
const CACHE_CAPACITY: usize = 32_768;

impl Batcher {
    /// Spawn the engine thread: loads artifacts + the model directory
    /// inside the thread (nothing non-Send crosses).
    pub fn spawn(artifact_dir: PathBuf, model_dir: PathBuf) -> Result<Batcher> {
        let (tx, rx) = channel::<Job>();
        let stats = Arc::new(BatcherStats::default());
        let stats2 = stats.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("profet-engine".into())
            .spawn(move || {
                let rt = match Runtime::load(&artifact_dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("runtime: {e:#}")));
                        return;
                    }
                };
                let profet = match Profet::load(&model_dir) {
                    Ok(p) => p,
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("models: {e:#}")));
                        return;
                    }
                };
                let _ = ready_tx.send(Ok(()));
                engine_loop(rt, profet, rx, &stats2);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died"))?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(Batcher {
            tx,
            stats,
            join: Some(join),
        })
    }

    pub fn submit(&self, job: Job) {
        let _ = self.tx.send(job);
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_loop(rt: Runtime, profet: Profet, rx: Receiver<Job>, stats: &BatcherStats) {
    let cache = PredictionCache::new(CACHE_SHARDS, CACHE_CAPACITY);
    let scaling = ScalingTable::new();
    loop {
        // block for the first job
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return,
        };
        let mut predicts: BTreeMap<(Instance, Instance), Vec<(PredictRequest, Sender<Response>)>> =
            BTreeMap::new();
        let mut immediate = Vec::new();
        let mut shutdown = false;
        let absorb = |job: Job,
                          predicts: &mut BTreeMap<
            (Instance, Instance),
            Vec<(PredictRequest, Sender<Response>)>,
        >,
                          immediate: &mut Vec<Job>,
                          shutdown: &mut bool| {
            match job {
                Job::Predict(req, reply) => {
                    predicts.entry((req.anchor, req.target)).or_default().push((req, reply));
                }
                Job::Shutdown => *shutdown = true,
                other => immediate.push(other),
            }
        };
        absorb(first, &mut predicts, &mut immediate, &mut shutdown);
        // coalesce within the window
        let deadline = std::time::Instant::now() + BATCH_WINDOW;
        while let Some(remaining) = deadline.checked_duration_since(std::time::Instant::now()) {
            match rx.recv_timeout(remaining) {
                Ok(j) => absorb(j, &mut predicts, &mut immediate, &mut shutdown),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // immediate (non-phase-1-batched) jobs
        for job in immediate {
            match job {
                Job::BatchSize {
                    instance,
                    batch,
                    t_min,
                    t_max,
                    reply,
                } => {
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let resp = match profet.predict_batch_size(instance, batch, t_min, t_max) {
                        Ok(v) => Response::ok_obj(|o| {
                            o.set("latency_ms", Json::Num(v));
                        }),
                        Err(e) => Response::Err(format!("{e:#}")),
                    };
                    let _ = reply.send(resp);
                }
                Job::PixelSize {
                    instance,
                    pixels,
                    t_min,
                    t_max,
                    reply,
                } => {
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let resp = match profet.predict_pixel_size(instance, pixels, t_min, t_max) {
                        Ok(v) => Response::ok_obj(|o| {
                            o.set("latency_ms", Json::Num(v));
                        }),
                        Err(e) => Response::Err(format!("{e:#}")),
                    };
                    let _ = reply.send(resp);
                }
                Job::Recommend {
                    query,
                    top_k,
                    reply,
                } => {
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let resp =
                        match advisor::sweep(&rt, &profet, &cache, &stats.cache, &scaling, &query) {
                            Ok(cands) if cands.is_empty() => Response::err_kind(
                                "no_candidates",
                                "no feasible (target, batch, pixels, gpus) candidate",
                            ),
                            Ok(cands) => recommend_response(&cands, top_k),
                            Err(e) => Response::Err(format!("{e:#}")),
                        };
                    let _ = reply.send(resp);
                }
                Job::Plan {
                    query,
                    job,
                    objective,
                    reply,
                } => {
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    let resp =
                        match advisor::sweep(&rt, &profet, &cache, &stats.cache, &scaling, &query) {
                            Ok(cands) if cands.is_empty() => Response::err_kind(
                                "no_candidates",
                                "no feasible (target, batch, pixels, gpus) candidate",
                            ),
                            Ok(cands) => match advisor::plan(&cands, &job, &objective) {
                                Some(choice) => plan_response(&cands, &choice),
                                None => Response::err_kind(
                                    "infeasible",
                                    "no candidate satisfies the constraint",
                                ),
                            },
                            Err(e) => Response::Err(format!("{e:#}")),
                        };
                    let _ = reply.send(resp);
                }
                _ => {}
            }
        }

        // batched phase-1 predictions: cache-first, then one artifact
        // execution per (anchor, target) group over the *unique* misses
        for ((anchor, target), group) in predicts {
            stats.requests.fetch_add(group.len() as u64, Ordering::Relaxed);
            let Some(model) = profet.cross.get(&(anchor, target)) else {
                for (_, reply) in group {
                    let _ = reply.send(Response::Err(format!(
                        "no model for {anchor}->{target}"
                    )));
                }
                continue;
            };
            let mut results: Vec<Option<(f64, crate::predictor::Member)>> =
                vec![None; group.len()];
            // unique missing keys, in first-seen order; waiters per key
            let mut miss_keys: Vec<CacheKey> = Vec::new();
            let mut miss_rows: Vec<Vec<f64>> = Vec::new();
            let mut miss_lats: Vec<f64> = Vec::new();
            let mut waiters: BTreeMap<CacheKey, Vec<usize>> = BTreeMap::new();
            for (i, (req, _)) in group.iter().enumerate() {
                let key = CacheKey::of(anchor, target, req.anchor_latency_ms, &req.profile);
                if let Some(v) = cache.get(&key, &stats.cache) {
                    results[i] = Some(v);
                    continue;
                }
                if !waiters.contains_key(&key) {
                    miss_keys.push(key.clone());
                    miss_rows.push(profet.feature_space.vectorize(&req.profile));
                    miss_lats.push(req.anchor_latency_ms);
                }
                waiters.entry(key).or_default().push(i);
            }
            if !miss_rows.is_empty() {
                let executed = crate::ml::FeatureMatrix::from_rows(&miss_rows)
                    .and_then(|feats| model.predict_batch(&rt, &feats, &miss_lats));
                match executed {
                    Ok(preds) => {
                        stats.batches.fetch_add(1, Ordering::Relaxed);
                        stats
                            .batched_requests
                            .fetch_add(miss_keys.len() as u64, Ordering::Relaxed);
                        for (key, pred) in miss_keys.into_iter().zip(preds) {
                            for &i in &waiters[&key] {
                                results[i] = Some(pred);
                            }
                            cache.insert(key, pred);
                        }
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        for (i, (_, reply)) in group.into_iter().enumerate() {
                            let resp = match results[i] {
                                Some((v, member)) => ok_prediction(v, member),
                                None => Response::Err(msg.clone()),
                            };
                            let _ = reply.send(resp);
                        }
                        continue;
                    }
                }
            }
            for (i, (_, reply)) in group.into_iter().enumerate() {
                let resp = match results[i] {
                    Some((v, member)) => ok_prediction(v, member),
                    None => Response::Err("prediction missing from batch".into()),
                };
                let _ = reply.send(resp);
            }
        }

        if shutdown {
            return;
        }
    }
}

fn ok_prediction(latency_ms: f64, member: crate::predictor::Member) -> Response {
    Response::ok_obj(|o| {
        o.set("latency_ms", Json::Num(latency_ms));
        o.set("member", Json::Str(member.name().into()));
    })
}

fn candidate_json(c: &Candidate, on_frontier: bool) -> Json {
    let mut o = Json::obj();
    o.set("target", Json::Str(c.target.key().into()));
    o.set("batch", Json::Num(c.batch as f64));
    o.set("pixels", Json::Num(c.pixels as f64));
    o.set("n_gpus", Json::Num(c.n_gpus as f64));
    o.set("pricing", Json::Str(c.pricing.key().into()));
    o.set("latency_ms", Json::Num(c.latency_ms));
    o.set("imgs_per_s", Json::Num(c.imgs_per_s));
    o.set("price_hr", Json::Num(c.price_hr));
    o.set("cost_per_img_usd", Json::Num(c.cost_per_img_usd));
    o.set("on_frontier", Json::Bool(on_frontier));
    o
}

/// Rank candidates (cost-efficiency first, then speed, then a stable tie
/// key), tag Pareto-frontier membership — computed over the FULL candidate
/// set, before any `top_k` truncation — and serialize.
fn recommend_response(cands: &[Candidate], top_k: usize) -> Response {
    let points: Vec<(f64, f64)> = cands.iter().map(Candidate::objectives).collect();
    let frontier: std::collections::BTreeSet<usize> =
        advisor::pareto_frontier(&points).into_iter().collect();
    let order = advisor::rank_candidates(cands);
    let take = if top_k == 0 { order.len() } else { top_k.min(order.len()) };
    Response::ok_obj(|o| {
        o.set(
            "candidates",
            Json::Arr(
                order[..take]
                    .iter()
                    .map(|&i| candidate_json(&cands[i], frontier.contains(&i)))
                    .collect(),
            ),
        );
        o.set("n_candidates", Json::Num(cands.len() as f64));
        o.set("frontier_size", Json::Num(frontier.len() as f64));
    })
}

fn plan_response(cands: &[Candidate], choice: &PlanChoice) -> Response {
    // one membership bit only — a direct dominance scan, not a full frontier
    let pt = cands[choice.index].objectives();
    let on_frontier = cands
        .iter()
        .all(|q| !advisor::dominates(q.objectives(), pt));
    Response::ok_obj(|o| {
        o.set("choice", candidate_json(&cands[choice.index], on_frontier));
        o.set("hours", Json::Num(choice.hours));
        o.set("cost_usd", Json::Num(choice.cost_usd));
        o.set("epochs", Json::Num(choice.epochs));
        o.set("n_considered", Json::Num(cands.len() as f64));
    })
}
