//! Request router: parses a protocol line straight off the wire (no DOM),
//! answers cheap queries inline, serves warm phase-1 `predict`s from the
//! shared prediction cache without ever materializing the request, and
//! forwards the rest to the [`EnginePool`].
//!
//! The hot loop is allocation-free: [`respond`] / [`respond_or_submit`]
//! decode through the per-connection [`ConnScratch`] (borrowed field
//! names/profile keys, reusable index vectors), snapshot the model
//! registry (one `Arc` refcount bump — the epoch it yields becomes part
//! of the cache key, so a registry swap implicitly invalidates every
//! older entry), build the cache key in a reusable byte buffer, and
//! encode the typed [`Response`] directly into the reused output buffer.
//! A steady-state cache-hit `predict` round trip touches the heap zero
//! times (enforced by `tests/wire_alloc.rs`).
//!
//! Two calling conventions over one routing core:
//!
//! * [`respond`] / [`route`] — **blocking**: cold requests park the
//!   calling thread on a channel until the lane replies. Used by
//!   embedding callers (benches, examples, the model-dir watcher).
//! * [`respond_or_submit`] — **nonblocking**: a cold request is handed
//!   to its lane with a caller-built [`Reply`] (the reactor passes a
//!   completion-queue reply) and [`RouteOutcome::Pending`] is returned;
//!   the response comes back through that reply later. Warm/inline
//!   requests encode immediately and return [`RouteOutcome::Done`].
//!
//! On a cache miss, the captured [`ModelSnapshot`] travels with the job:
//! however long the request waits in a lane queue, it is answered by the
//! model epoch that admitted it.

use crate::advisor::{CacheKey, CacheKeyScratch};
use crate::coordinator::dispatch::{EnginePool, Job, Reply, SubmitError};
use crate::coordinator::protocol::{parse_line, ParsedLine, Request, Response, WireScratch};
use crate::coordinator::registry::ModelSnapshot;
use crate::obs::{MetricsSnapshot, OpClass, Stage, Temp};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

#[inline]
fn ns_of(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

/// Per-connection reusable buffers: decode scratch, cache-key scratch,
/// and the encoded-response output buffer. All capacities persist across
/// lines, so warm traffic allocates nothing in the wire layer.
#[derive(Default)]
pub struct ConnScratch {
    wire: WireScratch,
    keys: CacheKeyScratch,
    /// The encoded, newline-terminated response line after [`respond`].
    pub out: Vec<u8>,
}

/// What [`respond_or_submit`] did with the line.
pub enum RouteOutcome {
    /// The reply is encoded in `scratch.out` — write it out now.
    Done,
    /// The request went to an engine lane; its response arrives through
    /// the [`Reply`] the caller supplied.
    Pending,
}

/// Routing result before the caller decides how to wait.
enum Handled {
    Inline(Response),
    Submitted,
}

/// Handle one request line end to end: decode, serve, and encode the
/// newline-terminated reply into `scratch.out` (blocking while the
/// engine works, same as the old `route`).
pub fn respond(pool: &EnginePool, line: &str, scratch: &mut ConnScratch) {
    let ConnScratch { wire, keys, out } = scratch;
    let mut waiter: Option<Receiver<Response>> = None;
    let handled = handle_line(pool, line, wire, keys, || {
        let (tx, rx) = channel();
        waiter = Some(rx);
        Reply::channel(tx)
    });
    block_on(handled, waiter).encode_line(out);
}

/// Handle one request line without ever blocking the caller: warm and
/// inline requests encode their reply into `scratch.out` immediately;
/// cold requests are submitted to their engine lane carrying the
/// [`Reply`] built by `reply` (called at most once, only on submission).
/// Submit failures (`overloaded`, engine gone) are encoded inline — the
/// caller never waits for a reply that will not come.
pub fn respond_or_submit(
    pool: &EnginePool,
    line: &str,
    scratch: &mut ConnScratch,
    reply: impl FnOnce() -> Reply,
) -> RouteOutcome {
    let ConnScratch { wire, keys, out } = scratch;
    match handle_line(pool, line, wire, keys, reply) {
        Handled::Inline(resp) => {
            resp.encode_line(out);
            RouteOutcome::Done
        }
        Handled::Submitted => RouteOutcome::Pending,
    }
}

/// Handle one request line; blocking. Compatibility entry point over
/// fresh scratch buffers — servers use the scratch-reusing variants.
pub fn route(pool: &EnginePool, line: &str) -> Response {
    let mut wire = WireScratch::default();
    let mut keys = CacheKeyScratch::default();
    let mut waiter: Option<Receiver<Response>> = None;
    let handled = handle_line(pool, line, &mut wire, &mut keys, || {
        let (tx, rx) = channel();
        waiter = Some(rx);
        Reply::channel(tx)
    });
    block_on(handled, waiter)
}

fn block_on(handled: Handled, waiter: Option<Receiver<Response>>) -> Response {
    match handled {
        Handled::Inline(resp) => resp,
        Handled::Submitted => match waiter {
            Some(rx) => rx
                .recv()
                .unwrap_or_else(|_| Response::Err("engine gone".into())),
            // unreachable: Submitted implies the reply closure ran
            None => Response::Err("engine gone".into()),
        },
    }
}

/// Submit one engine job. A full lane queue is surfaced as the
/// structured `overloaded` error — load is shed at the dispatcher,
/// never buffered unboundedly.
///
/// Stamps the job's [`ReqMeta`](crate::coordinator::dispatch::ReqMeta)
/// with the op class before handoff and, when the observatory samples
/// this request, attaches a trace context pre-seeded with the parse
/// duration so the eventual slow dump attributes the full lifecycle.
fn submit(
    pool: &EnginePool,
    op: OpClass,
    parse_ns: u64,
    reply: impl FnOnce() -> Reply,
    make: impl FnOnce(Reply) -> Job,
) -> Handled {
    let mut r = reply();
    {
        let meta = r.meta_mut();
        meta.op = op;
        meta.temp = Temp::Cold;
        // absolute expiry from the server-wide deadline budget; the lane
        // sheds the job at dequeue if the queue wait alone blew it
        meta.deadline = pool.default_deadline().map(|d| meta.submitted + d);
        meta.trace = pool.obs().maybe_trace();
        if let Some(t) = meta.trace.as_deref_mut() {
            t.note(Stage::Parse, parse_ns);
        }
    }
    match pool.submit(make(r)) {
        Ok(()) => Handled::Submitted,
        Err(SubmitError::Overloaded) => Handled::Inline(Response::err_kind(
            "overloaded",
            "engine queue is full — shed load and retry",
        )),
        Err(SubmitError::Gone) => Handled::Inline(Response::Err("engine gone".into())),
    }
}

fn handle_line(
    pool: &EnginePool,
    line: &str,
    wire: &mut WireScratch,
    keys: &mut CacheKeyScratch,
    reply: impl FnOnce() -> Reply,
) -> Handled {
    let t0 = Instant::now();
    let parsed = parse_line(line, wire);
    let parse_ns = ns_of(t0.elapsed());
    match parsed {
        Err(e) => {
            pool.obs()
                .record_ns(Stage::Parse, OpClass::Other, Temp::Cold, parse_ns);
            Handled::Inline(Response::err_kind(e.kind(), format!("bad request: {e}")))
        }
        Ok(ParsedLine::Predict(view)) => {
            // cache fast path: the key only needs the current epoch (one
            // lock-free atomic load — the registry mutex stays off the
            // warm path entirely), keyed over the borrowed profile spans
            // directly — a warm hit never materializes the request or
            // touches a lane
            let lk0 = Instant::now();
            let key = keys.key(
                pool.registry().epoch(),
                view.anchor,
                view.target,
                view.anchor_latency_ms,
                view.pairs(),
            );
            let hit = pool.cache().peek(&key);
            let lookup_ns = ns_of(lk0.elapsed());
            // warm vs cold decides the temperature of both cells; the
            // recordings themselves are two relaxed atomic adds each, so
            // the warm round trip stays allocation-free
            let temp = if hit.is_some() { Temp::Warm } else { Temp::Cold };
            let obs = pool.obs();
            obs.record_ns(Stage::Parse, OpClass::Predict, temp, parse_ns);
            obs.record_ns(Stage::WarmLookup, OpClass::Predict, temp, lookup_ns);
            if let Some((latency_ms, member)) = hit {
                let stats = &pool.stats;
                // ordering: stats-only counters read by the metrics
                // snapshot; they order nothing.
                stats.requests.fetch_add(1, Ordering::Relaxed);
                stats.cache.hits.fetch_add(1, Ordering::Relaxed);
                return Handled::Inline(Response::Prediction { latency_ms, member });
            }
            // miss: NOW pin the request to a full snapshot (Arc clone)
            // and hand off to the batching lane, which re-checks the
            // cache under the snapshot's epoch and counts the miss. (A
            // swap racing this admission just means the request is
            // served — and cached — under the newer epoch, exactly as if
            // it had arrived a moment later.)
            let snap: ModelSnapshot = pool.registry().snapshot();
            submit(pool, OpClass::Predict, parse_ns, reply, |r| {
                Job::Predict(view.materialize(), snap, r)
            })
        }
        Ok(ParsedLine::Req(req)) => {
            pool.obs()
                .record_ns(Stage::Parse, op_class_of(&req), Temp::Cold, parse_ns);
            route_request(pool, req, parse_ns, reply)
        }
    }
}

/// Observatory op class of a materialized request. Cheap queries and
/// wire-level infrastructure all aggregate under [`OpClass::Other`];
/// the phase-2 interpolation ops ride under [`OpClass::Predict`].
fn op_class_of(req: &Request) -> OpClass {
    match req {
        Request::Health | Request::Stats | Request::Instances | Request::Metrics => OpClass::Other,
        Request::Predict(_)
        | Request::PredictBatchSize { .. }
        | Request::PredictPixelSize { .. } => OpClass::Predict,
        Request::Recommend { .. } => OpClass::Recommend,
        Request::Plan { .. } => OpClass::Plan,
        Request::Ingest(_) => OpClass::Ingest,
        Request::Onboard { .. } => OpClass::Onboard,
        Request::Reload { .. } => OpClass::Reload,
        Request::Hint(_) | Request::ClusterStats => OpClass::Other,
    }
}

/// Serve an already-materialized request (everything but the borrowed
/// `predict` fast path above).
fn route_request(
    pool: &EnginePool,
    req: Request,
    parse_ns: u64,
    reply: impl FnOnce() -> Reply,
) -> Handled {
    match req {
        Request::Health => Handled::Inline(Response::Health),
        Request::Stats => {
            let s = &pool.stats;
            let reg = pool.registry();
            // ordering: every load in this arm is a stats-only gauge
            // read; approximate, independently-raced values are the
            // contract of the stats snapshot.
            let requests = s.requests.load(Ordering::Relaxed);
            let batches = s.batches.load(Ordering::Relaxed);
            let batched = s.batched_requests.load(Ordering::Relaxed);
            // the two connection gauges are maintained by different
            // threads, so read `open` once and clamp `active` to it:
            // every derived triple then satisfies active + idle == open
            // instead of occasionally publishing a torn pair
            let open_conns = s.conns.open.load(Ordering::Relaxed);
            let active_conns = s.conns.active.load(Ordering::Relaxed).min(open_conns);
            Handled::Inline(Response::Stats {
                requests,
                artifact_batches: batches,
                avg_batch_fill: if batches > 0 {
                    batched as f64 / batches as f64
                } else {
                    0.0
                },
                overloaded: s.overloaded.load(Ordering::Relaxed), // ordering: stats-only gauge
                predict_lanes: pool.predict_lanes(),
                cache_hits: s.cache.hits.load(Ordering::Relaxed), // ordering: stats-only gauge
                cache_misses: s.cache.misses.load(Ordering::Relaxed), // ordering: stats-only gauge
                registry_epoch: reg.epoch(),
                last_reload: reg.last_reload_unix_ms(),
                open_conns,
                active_conns,
                idle_conns: open_conns - active_conns,
                lane_restarts: s.lane_restarts.load(Ordering::Relaxed), // ordering: stats-only gauge
                evictions: s.conns.evicted.load(Ordering::Relaxed), // ordering: stats-only gauge
                hints_applied: s.hints_applied.load(Ordering::Relaxed), // ordering: stats-only gauge
                reactor_threads: s.conns.reactor_threads.load(Ordering::Relaxed), // ordering: stats-only gauge
                uptime_s: pool.obs().uptime_s(),
                version: env!("CARGO_PKG_VERSION"),
            })
        }
        Request::Metrics => {
            let s = &pool.stats;
            let obs = pool.obs();
            // ordering: stats-only gauge reads — same contract as the
            // stats arm above; `active` is clamped to `open` to avoid
            // publishing a torn pair.
            let open = s.conns.open.load(Ordering::Relaxed);
            let active = s.conns.active.load(Ordering::Relaxed).min(open);
            // byte-sorted by name — the encoder emits them in list order
            let gauges = vec![
                ("active_conns", active as f64),
                ("cache_hits", s.cache.hits.load(Ordering::Relaxed) as f64), // ordering: stats-only gauge
                ("cache_misses", s.cache.misses.load(Ordering::Relaxed) as f64), // ordering: stats-only gauge
                ("evictions", s.conns.evicted.load(Ordering::Relaxed) as f64), // ordering: stats-only gauge
                ("hints_applied", s.hints_applied.load(Ordering::Relaxed) as f64), // ordering: stats-only gauge
                ("idle_conns", (open - active) as f64),
                ("lane_restarts", s.lane_restarts.load(Ordering::Relaxed) as f64), // ordering: stats-only gauge
                ("open_conns", open as f64),
                ("overloaded", s.overloaded.load(Ordering::Relaxed) as f64), // ordering: stats-only gauge
                ("predict_lanes", pool.predict_lanes() as f64),
                ("registry_epoch", pool.registry().epoch() as f64),
                ("requests", s.requests.load(Ordering::Relaxed) as f64), // ordering: stats-only gauge
            ];
            Handled::Inline(Response::Metrics(Box::new(MetricsSnapshot {
                uptime_s: obs.uptime_s(),
                gauges,
                stages: obs.stage_summaries(),
                slow: obs.slow_traces(),
            })))
        }
        Request::Instances => Handled::Inline(Response::Instances),
        Request::Predict(p) => {
            let snap = pool.registry().snapshot();
            submit(pool, OpClass::Predict, parse_ns, reply, |r| {
                Job::Predict(p, snap, r)
            })
        }
        Request::PredictBatchSize {
            instance,
            batch,
            t_min,
            t_max,
        } => {
            let snap = pool.registry().snapshot();
            submit(pool, OpClass::Predict, parse_ns, reply, |r| Job::BatchSize {
                instance,
                batch,
                t_min,
                t_max,
                snap,
                reply: r,
            })
        }
        Request::PredictPixelSize {
            instance,
            pixels,
            t_min,
            t_max,
        } => {
            let snap = pool.registry().snapshot();
            submit(pool, OpClass::Predict, parse_ns, reply, |r| Job::PixelSize {
                instance,
                pixels,
                t_min,
                t_max,
                snap,
                reply: r,
            })
        }
        Request::Recommend { query, top_k } => {
            let snap = pool.registry().snapshot();
            submit(pool, OpClass::Recommend, parse_ns, reply, |r| Job::Recommend {
                query,
                top_k,
                snap,
                reply: r,
            })
        }
        Request::Plan {
            query,
            job,
            objective,
        } => {
            let snap = pool.registry().snapshot();
            submit(pool, OpClass::Plan, parse_ns, reply, |r| Job::Plan {
                query,
                job,
                objective,
                snap,
                reply: r,
            })
        }
        Request::Ingest(req) => submit(pool, OpClass::Ingest, parse_ns, reply, |r| Job::Ingest {
            req,
            reply: r,
        }),
        Request::Onboard { pair, dry_run } => {
            submit(pool, OpClass::Onboard, parse_ns, reply, |r| Job::Onboard {
                pair,
                dry_run,
                reply: r,
            })
        }
        Request::Reload { dry_run } => submit(pool, OpClass::Reload, parse_ns, reply, |r| {
            Job::Reload {
                only_if_changed: false,
                dry_run,
                reply: r,
            }
        }),
        Request::Hint(h) => {
            // peer cache hint from the route tier: only useful if it was
            // computed under the epoch this node is serving — a stale
            // epoch means the models (and thus the value) changed, so
            // the hint is acknowledged but dropped
            let applied = h.epoch == pool.registry().epoch();
            if applied {
                let key = CacheKey::of(h.epoch, h.anchor, h.target, h.anchor_latency_ms, &h.profile);
                pool.cache().insert(key, (h.latency_ms, h.member));
                // ordering: stats-only counter read by the stats/metrics
                // snapshots; it orders nothing.
                pool.stats.hints_applied.fetch_add(1, Ordering::Relaxed);
            }
            Handled::Inline(Response::HintApplied { applied })
        }
        Request::ClusterStats => Handled::Inline(Response::err_kind(
            "bad_request",
            "cluster_stats is answered by the route tier — ask a `repro route` process",
        )),
    }
}
