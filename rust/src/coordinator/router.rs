//! Request router: parses a protocol line, answers cheap queries inline,
//! and forwards prediction/advisor work to the [`Batcher`] engine.

use crate::coordinator::batcher::{Batcher, Job};
use crate::coordinator::protocol::{Request, Response};
use crate::gpu::Instance;
use crate::util::Json;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;

/// Handle one request line; blocking (waits for the engine when needed).
pub fn route(batcher: &Batcher, line: &str) -> Response {
    let req = match Request::parse(line) {
        Ok(r) => r,
        Err(e) => return Response::err_kind(e.kind(), format!("bad request: {e}")),
    };
    match req {
        Request::Health => Response::ok_obj(|o| {
            o.set("status", Json::Str("healthy".into()));
        }),
        Request::Stats => {
            let s = &batcher.stats;
            let requests = s.requests.load(Ordering::Relaxed);
            let batches = s.batches.load(Ordering::Relaxed);
            let batched = s.batched_requests.load(Ordering::Relaxed);
            let cache_hits = s.cache.hits.load(Ordering::Relaxed);
            let cache_misses = s.cache.misses.load(Ordering::Relaxed);
            Response::ok_obj(|o| {
                o.set("requests", Json::Num(requests as f64));
                o.set("artifact_batches", Json::Num(batches as f64));
                o.set(
                    "avg_batch_fill",
                    Json::Num(if batches > 0 {
                        batched as f64 / batches as f64
                    } else {
                        0.0
                    }),
                );
                o.set("cache_hits", Json::Num(cache_hits as f64));
                o.set("cache_misses", Json::Num(cache_misses as f64));
            })
        }
        Request::Instances => Response::ok_obj(|o| {
            o.set(
                "instances",
                Json::Arr(
                    Instance::ALL
                        .iter()
                        .map(|i| {
                            let mut e = Json::obj();
                            e.set("key", Json::Str(i.key().into()));
                            e.set("gpu", Json::Str(i.spec().gpu_model.into()));
                            e.set("price_hr", Json::Num(i.spec().price_hr));
                            e
                        })
                        .collect(),
                ),
            );
        }),
        Request::Predict(p) => {
            let (tx, rx) = channel();
            batcher.submit(Job::Predict(p, tx));
            rx.recv()
                .unwrap_or_else(|_| Response::Err("engine gone".into()))
        }
        Request::PredictBatchSize {
            instance,
            batch,
            t_min,
            t_max,
        } => {
            let (tx, rx) = channel();
            batcher.submit(Job::BatchSize {
                instance,
                batch,
                t_min,
                t_max,
                reply: tx,
            });
            rx.recv()
                .unwrap_or_else(|_| Response::Err("engine gone".into()))
        }
        Request::PredictPixelSize {
            instance,
            pixels,
            t_min,
            t_max,
        } => {
            let (tx, rx) = channel();
            batcher.submit(Job::PixelSize {
                instance,
                pixels,
                t_min,
                t_max,
                reply: tx,
            });
            rx.recv()
                .unwrap_or_else(|_| Response::Err("engine gone".into()))
        }
        Request::Recommend { query, top_k } => {
            let (tx, rx) = channel();
            batcher.submit(Job::Recommend {
                query,
                top_k,
                reply: tx,
            });
            rx.recv()
                .unwrap_or_else(|_| Response::Err("engine gone".into()))
        }
        Request::Plan {
            query,
            job,
            objective,
        } => {
            let (tx, rx) = channel();
            batcher.submit(Job::Plan {
                query,
                job,
                objective,
                reply: tx,
            });
            rx.recv()
                .unwrap_or_else(|_| Response::Err("engine gone".into()))
        }
    }
}
