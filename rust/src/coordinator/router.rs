//! Request router: parses a protocol line straight off the wire (no DOM),
//! answers cheap queries inline, serves warm phase-1 `predict`s from the
//! shared prediction cache without ever materializing the request, and
//! forwards the rest to the [`EnginePool`].
//!
//! The hot loop is allocation-free: [`respond`] decodes through the
//! per-connection [`ConnScratch`] (borrowed field names/profile keys,
//! reusable index vectors), snapshots the model registry (one `Arc`
//! refcount bump — the epoch it yields becomes part of the cache key, so
//! a registry swap implicitly invalidates every older entry), builds the
//! cache key in a reusable byte buffer, and encodes the typed
//! [`Response`] directly into the reused output buffer. A steady-state
//! cache-hit `predict` round trip touches the heap zero times (enforced
//! by `tests/wire_alloc.rs`).
//!
//! On a cache miss, the captured [`ModelSnapshot`] travels with the job:
//! however long the request waits in a lane queue, it is answered by the
//! model epoch that admitted it.

use crate::advisor::CacheKeyScratch;
use crate::coordinator::dispatch::{EnginePool, Job, SubmitError};
use crate::coordinator::protocol::{parse_line, ParsedLine, Request, Response, WireScratch};
use crate::coordinator::registry::ModelSnapshot;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Sender};

/// Per-connection reusable buffers: decode scratch, cache-key scratch,
/// and the encoded-response output buffer. All capacities persist across
/// lines, so warm traffic allocates nothing in the wire layer.
#[derive(Default)]
pub struct ConnScratch {
    wire: WireScratch,
    keys: CacheKeyScratch,
    /// The encoded, newline-terminated response line after [`respond`].
    pub out: Vec<u8>,
}

/// Submit one engine job and wait for its reply. A full lane queue is
/// surfaced as the structured `overloaded` error — load is shed at the
/// dispatcher, never buffered unboundedly.
fn ask(pool: &EnginePool, make: impl FnOnce(Sender<Response>) -> Job) -> Response {
    let (tx, rx) = channel();
    match pool.submit(make(tx)) {
        Ok(()) => rx
            .recv()
            .unwrap_or_else(|_| Response::Err("engine gone".into())),
        Err(SubmitError::Overloaded) => Response::err_kind(
            "overloaded",
            "engine queue is full — shed load and retry",
        ),
        Err(SubmitError::Gone) => Response::Err("engine gone".into()),
    }
}

/// Handle one request line end to end: decode, serve, and encode the
/// newline-terminated reply into `scratch.out` (blocking while the
/// engine works, same as the old `route`).
pub fn respond(pool: &EnginePool, line: &str, scratch: &mut ConnScratch) {
    let ConnScratch { wire, keys, out } = scratch;
    let resp = route_scratch(pool, line, wire, keys);
    resp.encode_line(out);
}

/// Handle one request line; blocking. Compatibility entry point over
/// fresh scratch buffers — servers use [`respond`] with per-connection
/// scratch instead.
pub fn route(pool: &EnginePool, line: &str) -> Response {
    let mut wire = WireScratch::default();
    let mut keys = CacheKeyScratch::default();
    route_scratch(pool, line, &mut wire, &mut keys)
}

fn route_scratch(
    pool: &EnginePool,
    line: &str,
    wire: &mut WireScratch,
    keys: &mut CacheKeyScratch,
) -> Response {
    match parse_line(line, wire) {
        Err(e) => Response::err_kind(e.kind(), format!("bad request: {e}")),
        Ok(ParsedLine::Predict(view)) => {
            // cache fast path: the key only needs the current epoch (one
            // lock-free atomic load — the registry mutex stays off the
            // warm path entirely), keyed over the borrowed profile spans
            // directly — a warm hit never materializes the request or
            // touches a lane
            let key = keys.key(
                pool.registry().epoch(),
                view.anchor,
                view.target,
                view.anchor_latency_ms,
                view.pairs(),
            );
            if let Some((latency_ms, member)) = pool.cache().peek(&key) {
                let stats = &pool.stats;
                stats.requests.fetch_add(1, Ordering::Relaxed);
                stats.cache.hits.fetch_add(1, Ordering::Relaxed);
                return Response::Prediction { latency_ms, member };
            }
            // miss: NOW pin the request to a full snapshot (Arc clone)
            // and hand off to the batching lane, which re-checks the
            // cache under the snapshot's epoch and counts the miss. (A
            // swap racing this admission just means the request is
            // served — and cached — under the newer epoch, exactly as if
            // it had arrived a moment later.)
            let snap: ModelSnapshot = pool.registry().snapshot();
            ask(pool, |tx| Job::Predict(view.materialize(), snap, tx))
        }
        Ok(ParsedLine::Req(req)) => route_request(pool, req),
    }
}

/// Serve an already-materialized request (everything but the borrowed
/// `predict` fast path above).
fn route_request(pool: &EnginePool, req: Request) -> Response {
    match req {
        Request::Health => Response::Health,
        Request::Stats => {
            let s = &pool.stats;
            let reg = pool.registry();
            let requests = s.requests.load(Ordering::Relaxed);
            let batches = s.batches.load(Ordering::Relaxed);
            let batched = s.batched_requests.load(Ordering::Relaxed);
            Response::Stats {
                requests,
                artifact_batches: batches,
                avg_batch_fill: if batches > 0 {
                    batched as f64 / batches as f64
                } else {
                    0.0
                },
                overloaded: s.overloaded.load(Ordering::Relaxed),
                predict_lanes: pool.predict_lanes(),
                cache_hits: s.cache.hits.load(Ordering::Relaxed),
                cache_misses: s.cache.misses.load(Ordering::Relaxed),
                registry_epoch: reg.epoch(),
                last_reload: reg.last_reload_unix_ms(),
            }
        }
        Request::Instances => Response::Instances,
        Request::Predict(p) => {
            let snap = pool.registry().snapshot();
            ask(pool, |tx| Job::Predict(p, snap, tx))
        }
        Request::PredictBatchSize {
            instance,
            batch,
            t_min,
            t_max,
        } => {
            let snap = pool.registry().snapshot();
            ask(pool, |tx| Job::BatchSize {
                instance,
                batch,
                t_min,
                t_max,
                snap,
                reply: tx,
            })
        }
        Request::PredictPixelSize {
            instance,
            pixels,
            t_min,
            t_max,
        } => {
            let snap = pool.registry().snapshot();
            ask(pool, |tx| Job::PixelSize {
                instance,
                pixels,
                t_min,
                t_max,
                snap,
                reply: tx,
            })
        }
        Request::Recommend { query, top_k } => {
            let snap = pool.registry().snapshot();
            ask(pool, |tx| Job::Recommend {
                query,
                top_k,
                snap,
                reply: tx,
            })
        }
        Request::Plan {
            query,
            job,
            objective,
        } => {
            let snap = pool.registry().snapshot();
            ask(pool, |tx| Job::Plan {
                query,
                job,
                objective,
                snap,
                reply: tx,
            })
        }
        Request::Ingest(req) => ask(pool, |tx| Job::Ingest { req, reply: tx }),
        Request::Onboard { pair } => ask(pool, |tx| Job::Onboard { pair, reply: tx }),
        Request::Reload => ask(pool, |tx| Job::Reload {
            only_if_changed: false,
            reply: tx,
        }),
    }
}
