//! Request router: parses a protocol line, answers cheap queries inline,
//! and forwards prediction/advisor work to the [`EnginePool`].

use crate::coordinator::dispatch::{EnginePool, Job, SubmitError};
use crate::coordinator::protocol::{Request, Response};
use crate::gpu::Instance;
use crate::util::Json;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Sender};

/// Submit one engine job and wait for its reply. A full lane queue is
/// surfaced as the structured `overloaded` error — load is shed at the
/// dispatcher, never buffered unboundedly.
fn ask(pool: &EnginePool, make: impl FnOnce(Sender<Response>) -> Job) -> Response {
    let (tx, rx) = channel();
    match pool.submit(make(tx)) {
        Ok(()) => rx
            .recv()
            .unwrap_or_else(|_| Response::Err("engine gone".into())),
        Err(SubmitError::Overloaded) => Response::err_kind(
            "overloaded",
            "engine queue is full — shed load and retry",
        ),
        Err(SubmitError::Gone) => Response::Err("engine gone".into()),
    }
}

/// Handle one request line; blocking (waits for the engine when needed).
pub fn route(pool: &EnginePool, line: &str) -> Response {
    let req = match Request::parse(line) {
        Ok(r) => r,
        Err(e) => return Response::err_kind(e.kind(), format!("bad request: {e}")),
    };
    match req {
        Request::Health => Response::ok_obj(|o| {
            o.set("status", Json::Str("healthy".into()));
        }),
        Request::Stats => {
            let s = &pool.stats;
            let requests = s.requests.load(Ordering::Relaxed);
            let batches = s.batches.load(Ordering::Relaxed);
            let batched = s.batched_requests.load(Ordering::Relaxed);
            let overloaded = s.overloaded.load(Ordering::Relaxed);
            let cache_hits = s.cache.hits.load(Ordering::Relaxed);
            let cache_misses = s.cache.misses.load(Ordering::Relaxed);
            let lanes = pool.predict_lanes();
            Response::ok_obj(|o| {
                o.set("requests", Json::Num(requests as f64));
                o.set("artifact_batches", Json::Num(batches as f64));
                o.set(
                    "avg_batch_fill",
                    Json::Num(if batches > 0 {
                        batched as f64 / batches as f64
                    } else {
                        0.0
                    }),
                );
                o.set("overloaded", Json::Num(overloaded as f64));
                o.set("predict_lanes", Json::Num(lanes as f64));
                o.set("cache_hits", Json::Num(cache_hits as f64));
                o.set("cache_misses", Json::Num(cache_misses as f64));
            })
        }
        Request::Instances => Response::ok_obj(|o| {
            o.set(
                "instances",
                Json::Arr(
                    Instance::ALL
                        .iter()
                        .map(|i| {
                            let mut e = Json::obj();
                            e.set("key", Json::Str(i.key().into()));
                            e.set("gpu", Json::Str(i.spec().gpu_model.into()));
                            e.set("price_hr", Json::Num(i.spec().price_hr));
                            e
                        })
                        .collect(),
                ),
            );
        }),
        Request::Predict(p) => ask(pool, |tx| Job::Predict(p, tx)),
        Request::PredictBatchSize {
            instance,
            batch,
            t_min,
            t_max,
        } => ask(pool, |tx| Job::BatchSize {
            instance,
            batch,
            t_min,
            t_max,
            reply: tx,
        }),
        Request::PredictPixelSize {
            instance,
            pixels,
            t_min,
            t_max,
        } => ask(pool, |tx| Job::PixelSize {
            instance,
            pixels,
            t_min,
            t_max,
            reply: tx,
        }),
        Request::Recommend { query, top_k } => ask(pool, |tx| Job::Recommend {
            query,
            top_k,
            reply: tx,
        }),
        Request::Plan {
            query,
            job,
            objective,
        } => ask(pool, |tx| Job::Plan {
            query,
            job,
            objective,
            reply: tx,
        }),
    }
}
