//! TCP server: accept loop + one thread per connection, newline-delimited
//! JSON in/out. Connections share the [`Batcher`] engine handle.
//!
//! Request lines are length-bounded ([`MAX_LINE_BYTES`]): a client that
//! streams an endless unterminated line cannot buffer arbitrary bytes in
//! the server — the oversized line is discarded as it arrives, answered
//! with a structured `line_too_long` error, and the connection keeps
//! serving subsequent well-formed lines.

use crate::coordinator::batcher::{Batcher, BatcherStats};
use crate::coordinator::protocol::Response;
use crate::coordinator::router::route;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;

/// Upper bound on one request line (advisor requests carry four profile
/// objects comfortably under 64 KiB; 1 MiB leaves an order of magnitude
/// of headroom).
pub const MAX_LINE_BYTES: usize = 1024 * 1024;

/// Running server handle: local address + shutdown flag.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    /// Engine statistics (requests served, artifact batches executed).
    pub stats: Arc<BatcherStats>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and wait for the accept loop to exit.
    pub fn stop(mut self) {
        self.shutdown
            .store(true, std::sync::atomic::Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown
            .store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start the service: binds `addr` (use port 0 for ephemeral), spawns the
/// engine and the accept loop, returns immediately.
pub fn serve(addr: &str, artifact_dir: PathBuf, model_dir: PathBuf) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    let batcher = Arc::new(Batcher::spawn(artifact_dir, model_dir)?);
    let stats = batcher.stats.clone();
    let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let shutdown2 = shutdown.clone();

    let join = std::thread::Builder::new()
        .name("profet-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if shutdown2.load(std::sync::atomic::Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let b = batcher.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, &b);
                });
            }
        })?;

    Ok(ServerHandle {
        addr: local,
        stats,
        shutdown,
        join: Some(join),
    })
}

fn handle_conn(stream: TcpStream, batcher: &Batcher) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        buf.clear();
        let resp = match read_line_bounded(&mut reader, &mut buf, MAX_LINE_BYTES)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong => Response::err_kind(
                "line_too_long",
                format!("request line exceeds {MAX_LINE_BYTES} bytes"),
            ),
            LineRead::Line => match std::str::from_utf8(&buf) {
                Ok(line) if line.trim().is_empty() => continue,
                Ok(line) => route(batcher, line),
                // lossy replacement would silently mangle profile keys;
                // reject like any other malformed payload
                Err(_) => {
                    Response::err_kind("bad_request", "request line is not valid UTF-8")
                }
            },
        };
        writer.write_all(resp.to_line().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

enum LineRead {
    /// A complete line (newline stripped) is in the buffer.
    Line,
    /// The line exceeded `max`; its bytes were discarded up to and
    /// including the terminating newline (or EOF).
    TooLong,
    /// Clean end of stream with no pending bytes.
    Eof,
}

/// `read_line` with a hard cap: never holds more than `max` line bytes
/// (plus the reader's fixed internal buffer) regardless of what the peer
/// sends. Oversized lines are drained, not buffered.
fn read_line_bounded<R: BufRead>(
    reader: &mut R,
    buf: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    loop {
        let (consume, found_newline, overflow) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                return Ok(if buf.is_empty() {
                    LineRead::Eof
                } else {
                    LineRead::Line // final unterminated line
                });
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if buf.len() + pos > max {
                        (pos + 1, true, true)
                    } else {
                        buf.extend_from_slice(&available[..pos]);
                        (pos + 1, true, false)
                    }
                }
                None => {
                    if buf.len() + available.len() > max {
                        (available.len(), false, true)
                    } else {
                        buf.extend_from_slice(available);
                        (available.len(), false, false)
                    }
                }
            }
        };
        reader.consume(consume);
        if overflow {
            if !found_newline {
                drain_until_newline(reader)?;
            }
            return Ok(LineRead::TooLong);
        }
        if found_newline {
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Ok(LineRead::Line);
        }
    }
}

/// Discard bytes up to and including the next newline (or EOF).
fn drain_until_newline<R: BufRead>(reader: &mut R) -> std::io::Result<()> {
    loop {
        let (consume, done) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                return Ok(());
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => (pos + 1, true),
                None => (available.len(), false),
            }
        };
        reader.consume(consume);
        if done {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::{drain_until_newline, read_line_bounded, LineRead};
    use std::io::BufReader;

    fn reader(bytes: &[u8]) -> BufReader<std::io::Cursor<Vec<u8>>> {
        // tiny internal buffer so lines span many fill_buf() rounds
        BufReader::with_capacity(8, std::io::Cursor::new(bytes.to_vec()))
    }

    #[test]
    fn reads_lines_and_strips_terminators() {
        let mut r = reader(b"alpha\nbeta\r\n\ngamma");
        let mut buf = Vec::new();
        for expect in [&b"alpha"[..], b"beta", b"", b"gamma"] {
            buf.clear();
            assert!(matches!(
                read_line_bounded(&mut r, &mut buf, 64).unwrap(),
                LineRead::Line
            ));
            assert_eq!(buf, expect);
        }
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 64).unwrap(),
            LineRead::Eof
        ));
    }

    #[test]
    fn oversized_line_is_rejected_and_stream_recovers() {
        let mut input = vec![b'x'; 1000];
        input.push(b'\n');
        input.extend_from_slice(b"ok\n");
        let mut r = reader(&input);
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::TooLong
        ));
        // the bounded reader never buffered more than the cap
        assert!(buf.len() <= 100, "{}", buf.len());
        // and the next line parses normally
        buf.clear();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::Line
        ));
        assert_eq!(buf, b"ok");
    }

    #[test]
    fn oversized_line_at_exact_boundary() {
        // a line of exactly `max` bytes is allowed
        let mut input = vec![b'y'; 100];
        input.push(b'\n');
        let mut r = reader(&input);
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::Line
        ));
        assert_eq!(buf.len(), 100);
        // one byte more is not
        let mut input = vec![b'y'; 101];
        input.push(b'\n');
        let mut r = reader(&input);
        buf.clear();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::TooLong
        ));
    }

    #[test]
    fn unterminated_oversized_line_hits_eof() {
        let input = vec![b'z'; 500];
        let mut r = reader(&input);
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::TooLong
        ));
        buf.clear(); // the connection loop clears between lines
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::Eof
        ));
    }

    #[test]
    fn final_unterminated_line_is_returned() {
        let mut r = reader(b"tail-no-newline");
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 64).unwrap(),
            LineRead::Line
        ));
        assert_eq!(buf, b"tail-no-newline");
    }

    #[test]
    fn drain_stops_at_newline() {
        let mut r = reader(b"aaaaaaaaaaaaaaaaaaaa\nnext");
        drain_until_newline(&mut r).unwrap();
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 64).unwrap(),
            LineRead::Line
        ));
        assert_eq!(buf, b"next");
    }
}

