//! TCP server: accept loop + one thread per connection, newline-delimited
//! JSON in/out. Connections share the [`Batcher`] engine handle.

use crate::coordinator::batcher::{Batcher, BatcherStats};
use crate::coordinator::router::route;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;

/// Running server handle: local address + shutdown flag.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    /// Engine statistics (requests served, artifact batches executed).
    pub stats: Arc<BatcherStats>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and wait for the accept loop to exit.
    pub fn stop(mut self) {
        self.shutdown
            .store(true, std::sync::atomic::Ordering::SeqCst);
        // poke the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown
            .store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start the service: binds `addr` (use port 0 for ephemeral), spawns the
/// engine and the accept loop, returns immediately.
pub fn serve(addr: &str, artifact_dir: PathBuf, model_dir: PathBuf) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    let batcher = Arc::new(Batcher::spawn(artifact_dir, model_dir)?);
    let stats = batcher.stats.clone();
    let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let shutdown2 = shutdown.clone();

    let join = std::thread::Builder::new()
        .name("profet-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if shutdown2.load(std::sync::atomic::Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let b = batcher.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, &b);
                });
            }
        })?;

    Ok(ServerHandle {
        addr: local,
        stats,
        shutdown,
        join: Some(join),
    })
}

fn handle_conn(stream: TcpStream, batcher: &Batcher) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = route(batcher, &line);
        writer.write_all(resp.to_line().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}
