//! TCP server: accept loop + readiness-polled connection reactor,
//! newline-delimited JSON in/out. Connections share the [`EnginePool`]
//! replica handle.
//!
//! The accept thread does admission only: it enforces the **connection
//! budget** ([`ServeOptions::max_connections`], tracked by the
//! `stats.conns.open` gauge) — past it, a connection is answered with one
//! best-effort nonblocking `overloaded` error line and closed — and hands
//! every admitted socket to a [`ReactorPool`] reactor thread
//! (round-robin). The reactors own all sockets from there: nonblocking
//! line-framed reads, warm predicts answered inline on the reactor
//! thread, cold requests dispatched to engine lanes with completions
//! flushed back on writable readiness (see [`crate::coordinator::reactor`]
//! for the full state machine). Ten thousand idle keep-alive connections
//! cost ten thousand file descriptors — not threads.
//!
//! Request lines are length-bounded ([`MAX_LINE_BYTES`]): a client that
//! streams an endless unterminated line cannot buffer arbitrary bytes in
//! the server — the oversized line is discarded as it arrives, answered
//! with a structured `line_too_long` error, and the connection keeps
//! serving subsequent well-formed lines.
//!
//! [`ServerHandle::stop`] is a **graceful drain**: it stops accepting,
//! half-closes (read side) every live connection, serves whatever
//! complete lines were already buffered, flushes every in-flight engine
//! response, and only then returns — accepted requests never lose their
//! replies. A peer that stopped reading its replies is bounded by
//! [`ServeOptions::write_stall_timeout`], so it cannot wedge the drain.
//!
//! With [`ServeOptions::model_dir_watch`] set, a watcher thread polls the
//! model directory on that interval and submits a conditional `reload`
//! job (trainer lane) whenever the directory fingerprint moves — dropping
//! a freshly trained model dir in place hot-swaps the registry epoch with
//! no operator interaction and no restart. The fingerprint ignores the
//! `staging/` subdirectory, so `ingest` traffic never looks like a model
//! change.

use crate::coordinator::dispatch::{EnginePool, EngineStats, Job, PoolOptions, Reply};
use crate::coordinator::protocol::Response;
use crate::coordinator::reactor::{ReactorConfig, ReactorPool};
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Upper bound on one request line (advisor requests carry four profile
/// objects comfortably under 64 KiB; 1 MiB leaves an order of magnitude
/// of headroom).
pub const MAX_LINE_BYTES: usize = 1024 * 1024;

/// Server configuration: engine-pool shape + connection tier knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub pool: PoolOptions,
    /// Maximum simultaneously served connections; connection number
    /// `max_connections + 1` gets a structured `overloaded` line and is
    /// closed immediately.
    pub max_connections: usize,
    /// Reactor threads owning the sockets; `0` (the default) sizes from
    /// the host: one reactor per four cores, capped at 4.
    pub reactor_threads: usize,
    /// Evict a connection that completes no request line for this long.
    /// `None` (the default) keeps idle keep-alive connections forever —
    /// they cost a file descriptor each, nothing more.
    pub idle_timeout: Option<Duration>,
    /// Close a connection whose reply backlog makes no write progress
    /// for this long — a peer that stops *reading* cannot hold buffered
    /// responses (or the graceful drain) hostage.
    pub write_stall_timeout: Duration,
    /// Poll the model directory on this interval and hot-reload it
    /// (publish a new registry epoch) when its contents change. `None`
    /// (the default) disables the watcher; `repro serve
    /// --model-dir-watch <secs>` enables it.
    pub model_dir_watch: Option<Duration>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            pool: PoolOptions::default(),
            max_connections: 256,
            reactor_threads: 0,
            idle_timeout: None,
            write_stall_timeout: Duration::from_secs(30),
            model_dir_watch: None,
        }
    }
}

impl ServeOptions {
    /// `reactor_threads` with the `0 = auto` sentinel resolved: one
    /// reactor per four cores, at least 1, at most 4 (reactors are
    /// readiness-bound, not compute-bound — the engine lanes own the
    /// cores).
    pub fn resolved_reactor_threads(&self) -> usize {
        if self.reactor_threads > 0 {
            return self.reactor_threads;
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        ((cores + 3) / 4).clamp(1, 4)
    }
}

/// Running server handle: local address + shutdown/drain control.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    /// Engine statistics (requests served, artifact batches executed,
    /// cache hits/misses, overload rejections, connection gauges) —
    /// shared across replicas and reactors.
    pub stats: Arc<EngineStats>,
    shutdown: Arc<AtomicBool>,
    reactors: Option<Arc<ReactorPool>>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Dropping the sender wakes the model-dir watcher (if any)
    /// immediately; the join below then completes without waiting out a
    /// poll interval.
    watch_stop: Option<std::sync::mpsc::Sender<()>>,
    watch_join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Graceful drain: stop accepting, half-close every live connection,
    /// and wait for the reactors to flush every accepted request's
    /// response — a request that reached an engine lane is answered and
    /// written out before this returns. Idle peers see EOF; peers that
    /// stopped reading are bounded by the write-stall timeout.
    pub fn stop(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // stop the model-dir watcher first (dropping its channel wakes it)
        drop(self.watch_stop.take());
        if let Some(j) = self.watch_join.take() {
            let _ = j.join();
        }
        // poke the accept loop awake so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        // reactors: half-close, flush, close, join (see ReactorPool)
        if let Some(reactors) = self.reactors.take() {
            reactors.drain();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if self.join.is_some() || self.watch_join.is_some() || self.reactors.is_some() {
            self.drain();
        }
    }
}

/// Start the service with default options: binds `addr` (use port 0 for
/// ephemeral), spawns the engine pool, the reactors, and the accept
/// loop, returns immediately.
pub fn serve(addr: &str, artifact_dir: PathBuf, model_dir: PathBuf) -> Result<ServerHandle> {
    serve_with(addr, artifact_dir, model_dir, &ServeOptions::default())
}

/// [`serve`] with explicit pool sizing, connection budget, reactor
/// sizing, and optional model-dir watching.
pub fn serve_with(
    addr: &str,
    artifact_dir: PathBuf,
    model_dir: PathBuf,
    opts: &ServeOptions,
) -> Result<ServerHandle> {
    let pool = EnginePool::spawn(artifact_dir, model_dir, &opts.pool)?;
    serve_pool_opts(addr, pool, opts)
}

/// [`serve_pool_opts`] with default connection-tier knobs (the unit-test
/// seam: mock pools, no PJRT runtime required).
#[cfg(test)]
pub(crate) fn serve_pool(
    addr: &str,
    pool: EnginePool,
    max_connections: usize,
) -> Result<ServerHandle> {
    serve_pool_opts(
        addr,
        pool,
        &ServeOptions {
            max_connections,
            ..ServeOptions::default()
        },
    )
}

/// [`serve_pool_opts`] with a model-dir watcher (test seam).
#[cfg(test)]
pub(crate) fn serve_pool_watched(
    addr: &str,
    pool: EnginePool,
    max_connections: usize,
    watch: Option<Duration>,
) -> Result<ServerHandle> {
    serve_pool_opts(
        addr,
        pool,
        &ServeOptions {
            max_connections,
            model_dir_watch: watch,
            ..ServeOptions::default()
        },
    )
}

/// Admission loop + reactor pool over a pre-built engine pool, plus the
/// optional model-dir watch thread. (`opts.pool` is ignored here — the
/// pool is already running.)
pub(crate) fn serve_pool_opts(
    addr: &str,
    pool: EnginePool,
    opts: &ServeOptions,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    let pool = Arc::new(pool);
    let watch_pool = opts.model_dir_watch.map(|_| pool.clone());
    let stats = pool.stats.clone();
    let stats2 = stats.clone();
    let shutdown = Arc::new(AtomicBool::new(false));
    let shutdown2 = shutdown.clone();
    let cfg = ReactorConfig {
        threads: opts.resolved_reactor_threads(),
        idle_timeout: opts.idle_timeout,
        write_stall_timeout: opts.write_stall_timeout,
    };
    let reactors = Arc::new(ReactorPool::spawn(pool.clone(), &cfg)?);
    let reactors2 = reactors.clone();
    let max_connections = opts.max_connections.max(1);

    let join = std::thread::Builder::new()
        .name("profet-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if shutdown2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                // the open gauge is the budget: incremented here at
                // admission, decremented by the reactor at close.
                // ordering: the cap is advisory — a race can momentarily
                // admit one connection past the limit, which the budget
                // tolerates; nothing downstream synchronizes on the gauge.
                if stats2.conns.open.load(Ordering::Relaxed) as usize >= max_connections {
                    stats2.overloaded.fetch_add(1, Ordering::Relaxed);
                    reject_overloaded(stream, max_connections);
                    continue;
                }
                stats2.conns.open.fetch_add(1, Ordering::Relaxed);
                reactors2.adopt(stream);
            }
        })?;

    let (watch_stop, watch_join) = match (opts.model_dir_watch, watch_pool) {
        (Some(interval), Some(pool)) => {
            let (tx, rx) = std::sync::mpsc::channel::<()>();
            let join = std::thread::Builder::new()
                .name("profet-model-watch".into())
                .spawn(move || model_dir_watch_loop(&pool, interval, rx))?;
            (Some(tx), Some(join))
        }
        _ => (None, None),
    };

    Ok(ServerHandle {
        addr: local,
        stats,
        shutdown,
        reactors: Some(reactors),
        join: Some(join),
        watch_stop,
        watch_join,
    })
}

/// The model-dir watcher: every `interval`, submit a *conditional* reload
/// to the trainer lane (the registry skips it when the directory
/// fingerprint hasn't moved — including after the registry's own
/// `onboard` saves) and wait for the outcome before sleeping again, so at
/// most one watcher-initiated reload is ever in flight. Exits as soon as
/// the server handle drops its stop channel.
fn model_dir_watch_loop(pool: &EnginePool, interval: Duration, stop: std::sync::mpsc::Receiver<()>) {
    loop {
        match stop.recv_timeout(interval) {
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            // a stop signal or a dropped server handle ends the watch
            Ok(()) | Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
        }
        // failpoint `server.watch.tick`: fault one poll tick — return-err
        // skips it (a vanished/unreadable model dir looks the same: the
        // served epoch is untouched), delay stalls it, panic exercises the
        // catch_unwind below. Unarmed: one relaxed atomic load per tick.
        if crate::fp!("server.watch.tick").is_some() {
            eprintln!("model-dir watch: injected tick fault; keeping the served epoch");
            continue;
        }
        // a panic anywhere in the tick (including an injected one) must
        // not kill the watcher: the served epoch stays live and the next
        // interval tries again
        let tick = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let (tx, rx) = std::sync::mpsc::channel();
            if pool
                .submit(Job::Reload {
                    only_if_changed: true,
                    dry_run: false,
                    reply: Reply::channel(tx),
                })
                .is_err()
            {
                return; // trainer queue momentarily full — try next tick
            }
            match rx.recv() {
                Ok(Response::Reloaded { .. }) => {}
                Ok(Response::ErrKind { kind, msg }) => {
                    eprintln!("model-dir watch: reload refused ({kind}): {msg}");
                }
                Ok(Response::Err(msg)) => eprintln!("model-dir watch: reload failed: {msg}"),
                Ok(_) | Err(_) => {}
            }
        }));
        if tick.is_err() {
            eprintln!("model-dir watch: tick panicked; keeping the served epoch");
        }
    }
}

/// Answer a budget-rejected connection with one structured error line —
/// strictly best-effort and nonblocking: the accept thread must never
/// stall behind a peer's receive window (one short line into a fresh
/// socket's empty send buffer virtually always succeeds; if it cannot,
/// the peer just sees the close).
fn reject_overloaded(mut stream: TcpStream, max_connections: usize) {
    stream.set_nonblocking(true).ok();
    let resp = Response::err_kind(
        "overloaded",
        format!("connection budget of {max_connections} exhausted — retry later"),
    );
    let mut out = Vec::new();
    resp.encode_line(&mut out);
    let _ = stream.write(&out);
}

#[cfg(test)]
mod tests {
    use super::{serve_pool, serve_pool_opts, serve_pool_watched, ServeOptions, MAX_LINE_BYTES};
    use crate::coordinator::dispatch::{EnginePool, Job};
    use crate::util::Json;
    use std::io::{BufRead as _, BufReader, Read as _, Write as _};
    use std::net::TcpStream;
    use std::sync::mpsc::Receiver;
    use std::time::Duration;

    // ---- pool-backed server behavior (mock lanes, no PJRT needed) ----

    /// Mock lane: answers every job `ok`, optionally after a delay.
    fn slow_echo(
        delay: Duration,
    ) -> impl Fn(usize, &Receiver<Job>) + Send + Sync + Clone + 'static {
        move |_idx, rx| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    Job::Predict(_, _, reply) => {
                        std::thread::sleep(delay);
                        reply.send(crate::coordinator::protocol::Response::Latency {
                            latency_ms: 1.0,
                        });
                    }
                    other => {
                        std::thread::sleep(delay);
                        // reply ok to whatever carries a reply handle
                        match other {
                            Job::BatchSize { reply, .. }
                            | Job::PixelSize { reply, .. }
                            | Job::Recommend { reply, .. }
                            | Job::Plan { reply, .. } => {
                                reply.send(crate::coordinator::protocol::Response::Health);
                            }
                            _ => {}
                        }
                    }
                }
            }
        }
    }

    fn echo_pool(delay: Duration) -> EnginePool {
        let body = slow_echo(delay);
        EnginePool::mock(1, 16, 4, body.clone(), move |rx| body(0, rx))
    }

    fn predict_line() -> &'static str {
        r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":10.0,"profile":{"Conv2D":1.0}}"#
    }

    fn health_line() -> &'static str {
        r#"{"op":"health"}"#
    }

    /// Line framing over a real reactor connection: pipelined lines in
    /// one write, `\r\n` terminators stripped, blank lines skipped, and
    /// the final unterminated line served at EOF.
    #[test]
    fn pipelined_lines_crlf_and_final_unterminated_line() {
        let handle = serve_pool("127.0.0.1:0", echo_pool(Duration::ZERO), 8).unwrap();
        let mut stream = TcpStream::connect(handle.addr).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(health_line().as_bytes());
        payload.extend_from_slice(b"\r\n");
        payload.extend_from_slice(b"\n"); // blank line: skipped, no reply
        payload.extend_from_slice(health_line().as_bytes());
        payload.extend_from_slice(b"\n");
        payload.extend_from_slice(health_line().as_bytes()); // no newline
        stream.write_all(&payload).unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut reader = BufReader::new(stream);
        for _ in 0..3 {
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            assert!(resp.contains("\"status\":\"healthy\""), "{resp}");
        }
        let mut tail = String::new();
        assert_eq!(reader.read_line(&mut tail).unwrap(), 0, "expected EOF");
        handle.stop();
    }

    /// The 1 MiB line cap under the reactor: an oversized line gets the
    /// structured `line_too_long` error and the SAME connection keeps
    /// serving; a line of exactly `MAX_LINE_BYTES` is not oversized.
    #[test]
    fn oversized_line_is_rejected_and_connection_recovers() {
        let handle = serve_pool("127.0.0.1:0", echo_pool(Duration::ZERO), 8).unwrap();
        let mut stream = TcpStream::connect(handle.addr).unwrap();
        let mut garbage = vec![b'{'; MAX_LINE_BYTES + 128];
        garbage.push(b'\n');
        stream.write_all(&garbage).unwrap();
        stream.write_all(health_line().as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).unwrap();
        assert_eq!(j.req_str("kind").unwrap(), "line_too_long", "{resp}");
        resp.clear();
        reader.read_line(&mut resp).unwrap();
        assert!(resp.contains("\"status\":\"healthy\""), "{resp}");

        // exactly MAX_LINE_BYTES is allowed through the cap — it reaches
        // the parser (and fails there as malformed JSON, not as too-long)
        let mut exact = vec![b'{'; MAX_LINE_BYTES];
        exact.push(b'\n');
        stream.write_all(&exact).unwrap();
        resp.clear();
        reader.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).unwrap();
        assert_eq!(j.req_str("kind").unwrap(), "bad_request", "{resp}");
        handle.stop();
    }

    /// In-order replies on one connection: a pipelined inline op behind
    /// a slow engine job must wait for the engine reply (requests on one
    /// connection are answered in order).
    #[test]
    fn pipelined_inline_op_waits_behind_engine_job() {
        let handle = serve_pool("127.0.0.1:0", echo_pool(Duration::from_millis(150)), 8).unwrap();
        let mut stream = TcpStream::connect(handle.addr).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(predict_line().as_bytes());
        payload.extend_from_slice(b"\n");
        payload.extend_from_slice(health_line().as_bytes());
        payload.extend_from_slice(b"\n");
        stream.write_all(&payload).unwrap();
        let mut reader = BufReader::new(stream);
        let mut first = String::new();
        reader.read_line(&mut first).unwrap();
        assert!(first.contains("latency_ms"), "engine reply first: {first}");
        let mut second = String::new();
        reader.read_line(&mut second).unwrap();
        assert!(second.contains("healthy"), "inline op second: {second}");
        handle.stop();
    }

    /// Drain correctness with the full mix: an idle peer (sees EOF), a
    /// mid-request peer (its in-flight engine reply is flushed), and a
    /// peer that only reads after the drain (its reply was flushed into
    /// the socket before close).
    #[test]
    fn stop_drains_mixed_idle_midflight_and_late_reading_peers() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let picked = std::sync::Arc::new(AtomicUsize::new(0));
        let picked2 = picked.clone();
        let body = move |_idx: usize, rx: &Receiver<Job>| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    Job::Predict(_, _, reply) => {
                        picked2.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(300));
                        reply.send(crate::coordinator::protocol::Response::Latency {
                            latency_ms: 1.0,
                        });
                    }
                    _ => {}
                }
            }
        };
        let pool = EnginePool::mock(1, 16, 4, body.clone(), move |rx| body(0, rx));
        let handle = serve_pool("127.0.0.1:0", pool, 8).unwrap();
        let addr = handle.addr;

        // idle peer: connected, never sends
        let idle = TcpStream::connect(addr).unwrap();
        // mid-request peer: blocked reading its in-flight reply
        let midflight = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(predict_line().as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut reader = BufReader::new(stream);
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            resp
        });
        // late reader: sends a request but reads only after stop()
        let mut late = TcpStream::connect(addr).unwrap();
        late.write_all(predict_line().as_bytes()).unwrap();
        late.write_all(b"\n").unwrap();

        // wait until the engine provably owns both predicts
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while picked.load(Ordering::SeqCst) < 2 {
            assert!(
                std::time::Instant::now() < deadline,
                "requests never reached the mock engine"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        handle.stop();

        // mid-flight reply arrived (stop returned only after the flush)
        let resp = midflight.join().unwrap();
        let j = Json::parse(resp.trim()).expect("drained connection lost its response");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
        // the late reader's reply is sitting in its socket, then EOF
        let mut buf = String::new();
        let mut late_reader = BufReader::new(late);
        late_reader.read_line(&mut buf).unwrap();
        let j = Json::parse(buf.trim()).expect("late reader lost its response");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{buf}");
        buf.clear();
        assert_eq!(late_reader.read_line(&mut buf).unwrap(), 0, "expected EOF");
        // the idle peer was closed
        let mut b = [0u8; 1];
        let mut idle = idle;
        idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        assert_eq!(idle.read(&mut b).unwrap_or(0), 0, "idle peer not closed");
    }

    /// Slow-loris: a peer dribbling a partial line never completes a
    /// request, so the idle timeout evicts it — while a well-behaved
    /// connection on the SAME reactor thread keeps being served.
    #[test]
    fn slow_loris_partial_line_is_evicted_while_others_are_served() {
        let opts = ServeOptions {
            max_connections: 8,
            reactor_threads: 1,
            idle_timeout: Some(Duration::from_millis(150)),
            ..ServeOptions::default()
        };
        let handle = serve_pool_opts("127.0.0.1:0", echo_pool(Duration::ZERO), &opts).unwrap();
        let addr = handle.addr;

        let mut loris = TcpStream::connect(addr).unwrap();
        loris.write_all(b"{\"op\":").unwrap(); // partial line, never finished

        // the single reactor thread still serves a healthy connection
        let mut good = TcpStream::connect(addr).unwrap();
        good.write_all(health_line().as_bytes()).unwrap();
        good.write_all(b"\n").unwrap();
        let mut good_reader = BufReader::new(good);
        let mut resp = String::new();
        good_reader.read_line(&mut resp).unwrap();
        assert!(resp.contains("healthy"), "{resp}");

        // the dribbler is evicted by the idle timeout (partial bytes do
        // not count as activity), surfacing as EOF on its socket
        loris
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut b = [0u8; 1];
        let n = loris.read(&mut b).unwrap_or(0);
        assert_eq!(n, 0, "slow-loris connection was not evicted");
        assert!(
            handle
                .stats
                .conns
                .evicted
                .load(std::sync::atomic::Ordering::Relaxed)
                >= 1,
            "eviction counter not bumped"
        );
        handle.stop();
    }

    /// The `--model-dir-watch` poller submits *conditional* reload jobs
    /// to the trainer lane on its interval, and the graceful drain stops
    /// it immediately (no waiting out a poll period).
    #[test]
    fn model_dir_watcher_submits_conditional_reloads_and_stops_on_drain() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let reloads = std::sync::Arc::new(AtomicUsize::new(0));
        let r2 = reloads.clone();
        let advisor = move |rx: &Receiver<Job>| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    Job::Reload {
                        only_if_changed,
                        reply,
                        ..
                    } => {
                        assert!(only_if_changed, "watcher reloads must be conditional");
                        r2.fetch_add(1, Ordering::SeqCst);
                        reply.send(crate::coordinator::protocol::Response::Reloaded { epoch: 1 });
                    }
                    _ => {}
                }
            }
        };
        let body = slow_echo(Duration::ZERO);
        let pool = EnginePool::mock(1, 16, 8, body, advisor);
        let handle = serve_pool_watched(
            "127.0.0.1:0",
            pool,
            8,
            Some(Duration::from_millis(20)),
        )
        .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while reloads.load(Ordering::SeqCst) < 2 {
            assert!(
                std::time::Instant::now() < deadline,
                "watcher never polled the model dir"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        let t0 = std::time::Instant::now();
        handle.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "drain waited out the watcher interval"
        );
    }

    /// Watcher resilience (the deleted/unreadable-model-dir scenario): a
    /// reload that panics mid-tick gets its lane respawned and the watcher
    /// keeps polling; reloads that fail cleanly afterwards are logged and
    /// skipped. Through it all the served epoch keeps answering — no
    /// panic, no spurious reload, no wedged watcher.
    #[test]
    fn watcher_keeps_serving_when_reload_panics_or_fails() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ticks = std::sync::Arc::new(AtomicUsize::new(0));
        let t2 = ticks.clone();
        let advisor = move |rx: &Receiver<Job>| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    Job::Reload { reply, .. } => {
                        // tick 0: the model dir vanished so violently the
                        // lane panics — the supervisor must respawn it and
                        // the reply drop guard answers the watcher. Later
                        // ticks: a clean structured failure.
                        let n = t2.fetch_add(1, Ordering::SeqCst);
                        if n == 0 {
                            panic!("injected reload panic: model dir deleted mid-watch");
                        }
                        reply.send(crate::coordinator::protocol::Response::err_kind(
                            "validation_failed",
                            "model dir unreadable mid-watch",
                        ));
                    }
                    _ => {}
                }
            }
        };
        let body = slow_echo(Duration::ZERO);
        let pool = EnginePool::mock(1, 16, 8, body, advisor);
        let handle =
            serve_pool_watched("127.0.0.1:0", pool, 8, Some(Duration::from_millis(20))).unwrap();

        // the watcher must survive the panicking tick AND keep polling
        // through the cleanly-failing ones
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while ticks.load(Ordering::SeqCst) < 3 {
            assert!(
                std::time::Instant::now() < deadline,
                "watcher wedged after a failing reload tick"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            handle
                .stats
                .lane_restarts
                .load(std::sync::atomic::Ordering::Relaxed)
                >= 1,
            "panicking reload lane was not respawned"
        );

        // the old epoch keeps serving: a fresh connection still answers
        let mut stream = TcpStream::connect(handle.addr).unwrap();
        stream.write_all(health_line().as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(stream);
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert!(resp.contains("\"status\":\"healthy\""), "{resp}");
        handle.stop();
    }

    #[test]
    fn connection_budget_rejects_with_structured_overloaded() {
        let handle = serve_pool("127.0.0.1:0", echo_pool(Duration::ZERO), 1).unwrap();
        let addr = handle.addr;

        // connection 1 occupies the whole budget (held open, proven live)
        let s1 = TcpStream::connect(addr).unwrap();
        let mut w1 = s1.try_clone().unwrap();
        w1.write_all(predict_line().as_bytes()).unwrap();
        w1.write_all(b"\n").unwrap();
        let mut r1 = BufReader::new(s1);
        let mut resp = String::new();
        r1.read_line(&mut resp).unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");

        // connection 2 is rejected with one structured line, then EOF
        let s2 = TcpStream::connect(addr).unwrap();
        let mut r2 = BufReader::new(s2);
        let mut line = String::new();
        r2.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{line}");
        assert_eq!(j.req_str("kind").unwrap(), "overloaded");
        line.clear();
        assert_eq!(r2.read_line(&mut line).unwrap(), 0, "rejected conn not closed");
        assert!(
            handle.stats.overloaded.load(std::sync::atomic::Ordering::Relaxed) >= 1
        );

        // closing connection 1 frees the budget for a new connection
        drop(r1);
        drop(w1);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let served = loop {
            let s3 = TcpStream::connect(addr).unwrap();
            let mut w3 = s3.try_clone().unwrap();
            w3.write_all(predict_line().as_bytes()).unwrap();
            w3.write_all(b"\n").unwrap();
            let mut r3 = BufReader::new(s3);
            let mut resp = String::new();
            r3.read_line(&mut resp).unwrap();
            if resp.contains("\"ok\":true") {
                break true;
            }
            if std::time::Instant::now() > deadline {
                break false;
            }
            std::thread::sleep(Duration::from_millis(20));
        };
        assert!(served, "budget slot was never released");
        handle.stop();
    }

    /// The reactor surfaces connection gauges through the `stats` op:
    /// open/idle reflect live connections, reactor_threads the pool size.
    #[test]
    fn stats_op_reports_reactor_health() {
        let opts = ServeOptions {
            max_connections: 8,
            reactor_threads: 2,
            ..ServeOptions::default()
        };
        let handle = serve_pool_opts("127.0.0.1:0", echo_pool(Duration::ZERO), &opts).unwrap();
        let addr = handle.addr;
        let _idle = TcpStream::connect(addr).unwrap();
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        // the idle peer's accept races this request: poll stats until
        // the gauge includes both connections
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let j = loop {
            writer.write_all(b"{\"op\":\"stats\"}\n").unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let j = Json::parse(resp.trim()).unwrap();
            let open = j.get("open_conns").and_then(Json::as_f64).unwrap() as u64;
            if open >= 2 {
                break j;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "idle connection never showed in open_conns: {resp}"
            );
            std::thread::sleep(Duration::from_millis(10));
        };
        let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap() as u64;
        assert_eq!(num("reactor_threads"), 2);
        assert!(num("idle_conns") >= 1);
        assert_eq!(num("active_conns"), 0);
        handle.stop();
    }
}
