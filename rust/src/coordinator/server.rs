//! TCP server: accept loop + one thread per connection, newline-delimited
//! JSON in/out. Connections share the [`EnginePool`] replica handle.
//!
//! Request lines are length-bounded ([`MAX_LINE_BYTES`]): a client that
//! streams an endless unterminated line cannot buffer arbitrary bytes in
//! the server — the oversized line is discarded as it arrives, answered
//! with a structured `line_too_long` error, and the connection keeps
//! serving subsequent well-formed lines.
//!
//! The accept loop enforces a **connection budget**
//! ([`ServeOptions::max_connections`]): past it, a connection is answered
//! with one structured `overloaded` error line and closed instead of
//! spawning an unbounded handler thread per socket.
//!
//! [`ServerHandle::stop`] is a **graceful drain**: it stops accepting,
//! half-closes (read side) every live connection so idle handlers wake
//! with EOF, and then *joins* every in-flight handler thread — a handler
//! mid-request finishes it and flushes the response before exiting, so
//! accepted requests never lose their replies (the seed leaked handler
//! threads on shutdown).
//!
//! With [`ServeOptions::model_dir_watch`] set, a watcher thread polls the
//! model directory on that interval and submits a conditional `reload`
//! job (trainer lane) whenever the directory fingerprint moves — dropping
//! a freshly trained model dir in place hot-swaps the registry epoch with
//! no operator interaction and no restart. The fingerprint ignores the
//! `staging/` subdirectory, so `ingest` traffic never looks like a model
//! change.

use crate::coordinator::dispatch::{EnginePool, EngineStats, Job, PoolOptions};
use crate::coordinator::protocol::Response;
use crate::coordinator::router::respond;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Upper bound on one request line (advisor requests carry four profile
/// objects comfortably under 64 KiB; 1 MiB leaves an order of magnitude
/// of headroom).
pub const MAX_LINE_BYTES: usize = 1024 * 1024;

/// Per-connection write timeout: a peer that stops *reading* its replies
/// (full TCP send buffer) unblocks the handler with an error after this
/// long instead of wedging it forever — which also guarantees the
/// graceful drain's handler joins always terminate. A handler waiting on
/// a long engine job is unaffected: the clock only runs inside `write`.
const WRITE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

/// Server configuration: engine-pool shape + connection budget.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub pool: PoolOptions,
    /// Maximum simultaneously served connections; connection number
    /// `max_connections + 1` gets a structured `overloaded` line and is
    /// closed immediately.
    pub max_connections: usize,
    /// Poll the model directory on this interval and hot-reload it
    /// (publish a new registry epoch) when its contents change. `None`
    /// (the default) disables the watcher; `repro serve
    /// --model-dir-watch <secs>` enables it.
    pub model_dir_watch: Option<std::time::Duration>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            pool: PoolOptions::default(),
            max_connections: 256,
            model_dir_watch: None,
        }
    }
}

/// Live-connection registry: stream clones (for the drain's read-side
/// half-close) and handler join handles, keyed by connection id.
#[derive(Default)]
struct ConnTable {
    streams: Mutex<HashMap<u64, TcpStream>>,
    joins: Mutex<HashMap<u64, std::thread::JoinHandle<()>>>,
    next_id: AtomicU64,
}

impl ConnTable {
    fn active(&self) -> usize {
        self.streams.lock().unwrap().len()
    }

    /// Called by a handler as its last action: a finished connection
    /// detaches its own join handle (dropping a JoinHandle detaches), so
    /// the tables never grow beyond the live-connection count.
    fn deregister(&self, id: u64) {
        self.streams.lock().unwrap().remove(&id);
        self.joins.lock().unwrap().remove(&id);
    }
}

/// Running server handle: local address + shutdown/drain control.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    /// Engine statistics (requests served, artifact batches executed,
    /// cache hits/misses, overload rejections) — shared across replicas.
    pub stats: Arc<EngineStats>,
    shutdown: Arc<AtomicBool>,
    conns: Arc<ConnTable>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Dropping the sender wakes the model-dir watcher (if any)
    /// immediately; the join below then completes without waiting out a
    /// poll interval.
    watch_stop: Option<std::sync::mpsc::Sender<()>>,
    watch_join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Graceful drain: stop accepting, wake idle handlers with EOF, and
    /// join every in-flight connection handler. A handler that is waiting
    /// on the engine finishes its request and flushes the response before
    /// exiting — accepted requests never lose their reply.
    pub fn stop(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // stop the model-dir watcher first (dropping its channel wakes it)
        drop(self.watch_stop.take());
        if let Some(j) = self.watch_join.take() {
            let _ = j.join();
        }
        // poke the accept loop awake so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        // half-close the read side of every live connection: handlers
        // blocked in `read` wake with EOF; a handler mid-request still
        // writes its response (the write side stays open)
        let streams: Vec<TcpStream> = {
            let mut map = self.conns.streams.lock().unwrap();
            map.drain().map(|(_, s)| s).collect()
        };
        for s in &streams {
            let _ = s.shutdown(Shutdown::Read);
        }
        // the socket dups served their purpose (the half-close above);
        // drop them now so the handler-side close is the last reference.
        // Handler joins below always terminate: a handler is either
        // waiting on the engine (every accepted job completes and
        // replies), reading (woken by the half-close), or writing
        // (bounded by WRITE_TIMEOUT) — so an in-flight request flushes
        // its response no matter how long its engine job runs, and a
        // peer that stopped reading cannot wedge the drain.
        drop(streams);
        let joins: Vec<std::thread::JoinHandle<()>> = {
            let mut map = self.conns.joins.lock().unwrap();
            map.drain().map(|(_, j)| j).collect()
        };
        for j in joins {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if self.join.is_some() || self.watch_join.is_some() || self.conns.active() > 0 {
            self.drain();
        }
    }
}

/// Start the service with default options: binds `addr` (use port 0 for
/// ephemeral), spawns the engine pool and the accept loop, returns
/// immediately.
pub fn serve(addr: &str, artifact_dir: PathBuf, model_dir: PathBuf) -> Result<ServerHandle> {
    serve_with(addr, artifact_dir, model_dir, &ServeOptions::default())
}

/// [`serve`] with explicit pool sizing, connection budget, and optional
/// model-dir watching.
pub fn serve_with(
    addr: &str,
    artifact_dir: PathBuf,
    model_dir: PathBuf,
    opts: &ServeOptions,
) -> Result<ServerHandle> {
    let pool = EnginePool::spawn(artifact_dir, model_dir, &opts.pool)?;
    serve_pool_watched(addr, pool, opts.max_connections, opts.model_dir_watch)
}

/// [`serve_pool_watched`] without a watcher (the unit-test seam: mock
/// pools, no PJRT runtime required).
pub(crate) fn serve_pool(
    addr: &str,
    pool: EnginePool,
    max_connections: usize,
) -> Result<ServerHandle> {
    serve_pool_watched(addr, pool, max_connections, None)
}

/// Accept loop over a pre-built pool, plus the optional model-dir watch
/// thread.
pub(crate) fn serve_pool_watched(
    addr: &str,
    pool: EnginePool,
    max_connections: usize,
    watch: Option<std::time::Duration>,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    let pool = Arc::new(pool);
    // the watcher needs its own pool handle before the accept loop
    // captures `pool` by move
    let watch_pool = watch.map(|_| pool.clone());
    let stats = pool.stats.clone();
    let stats2 = stats.clone();
    let shutdown = Arc::new(AtomicBool::new(false));
    let shutdown2 = shutdown.clone();
    let conns = Arc::new(ConnTable::default());
    let conns2 = conns.clone();
    let max_connections = max_connections.max(1);

    let join = std::thread::Builder::new()
        .name("profet-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if shutdown2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                if conns2.active() >= max_connections {
                    stats2.overloaded.fetch_add(1, Ordering::Relaxed);
                    reject_overloaded(stream, max_connections);
                    continue;
                }
                let id = conns2.next_id.fetch_add(1, Ordering::Relaxed);
                // register the stream clone BEFORE spawning, so the
                // budget check and the drain both see this connection
                match stream.try_clone() {
                    Ok(clone) => {
                        conns2.streams.lock().unwrap().insert(id, clone);
                    }
                    Err(_) => continue,
                }
                let pool = pool.clone();
                let conns3 = conns2.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("profet-conn-{id}"))
                    .spawn(move || {
                        let _ = handle_conn(stream, &pool);
                        conns3.deregister(id);
                    });
                match spawned {
                    Ok(handle) => {
                        // the handler may already have finished (instant
                        // EOF) and deregistered `id` BEFORE this insert —
                        // re-check the stream table and detach the handle
                        // if so, or the joins map would leak one finished
                        // entry per short-lived connection until drain.
                        // (Locks taken sequentially, never nested, so
                        // there is no order inversion with deregister.)
                        conns2.joins.lock().unwrap().insert(id, handle);
                        if !conns2.streams.lock().unwrap().contains_key(&id) {
                            conns2.joins.lock().unwrap().remove(&id);
                        }
                    }
                    Err(_) => {
                        conns2.streams.lock().unwrap().remove(&id);
                    }
                }
            }
        })?;

    let (watch_stop, watch_join) = match (watch, watch_pool) {
        (Some(interval), Some(pool)) => {
            let (tx, rx) = std::sync::mpsc::channel::<()>();
            let join = std::thread::Builder::new()
                .name("profet-model-watch".into())
                .spawn(move || model_dir_watch_loop(&pool, interval, rx))?;
            (Some(tx), Some(join))
        }
        _ => (None, None),
    };

    Ok(ServerHandle {
        addr: local,
        stats,
        shutdown,
        conns,
        join: Some(join),
        watch_stop,
        watch_join,
    })
}

/// The model-dir watcher: every `interval`, submit a *conditional* reload
/// to the trainer lane (the registry skips it when the directory
/// fingerprint hasn't moved — including after the registry's own
/// `onboard` saves) and wait for the outcome before sleeping again, so at
/// most one watcher-initiated reload is ever in flight. Exits as soon as
/// the server handle drops its stop channel.
fn model_dir_watch_loop(
    pool: &EnginePool,
    interval: std::time::Duration,
    stop: std::sync::mpsc::Receiver<()>,
) {
    loop {
        match stop.recv_timeout(interval) {
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            // a stop signal or a dropped server handle ends the watch
            Ok(()) | Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
        }
        let (tx, rx) = std::sync::mpsc::channel();
        if pool
            .submit(Job::Reload {
                only_if_changed: true,
                reply: tx,
            })
            .is_err()
        {
            continue; // trainer queue momentarily full — try next tick
        }
        match rx.recv() {
            Ok(Response::Reloaded { .. }) => {}
            Ok(Response::ErrKind { kind, msg }) => {
                eprintln!("model-dir watch: reload refused ({kind}): {msg}");
            }
            Ok(Response::Err(msg)) => eprintln!("model-dir watch: reload failed: {msg}"),
            Ok(_) | Err(_) => {}
        }
    }
}

/// Answer a budget-rejected connection with one structured error line.
/// Written from the accept thread, so the bound is much tighter than
/// WRITE_TIMEOUT — one short line fits any send buffer without blocking,
/// and a pathological peer must not stall the accept loop.
fn reject_overloaded(mut stream: TcpStream, max_connections: usize) {
    stream
        .set_write_timeout(Some(std::time::Duration::from_secs(1)))
        .ok();
    let resp = Response::err_kind(
        "overloaded",
        format!("connection budget of {max_connections} exhausted — retry later"),
    );
    let _ = stream.write_all(resp.to_line().as_bytes());
    let _ = stream.write_all(b"\n");
    let _ = stream.flush();
}

fn handle_conn(stream: TcpStream, pool: &EnginePool) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    // per-connection wire buffers: decode scratch, cache-key scratch, and
    // the encoded-response output buffer — reused line after line, so a
    // steady-state request pays zero wire-layer allocations
    let mut scratch = crate::coordinator::router::ConnScratch::default();
    loop {
        buf.clear();
        match read_line_bounded(&mut reader, &mut buf, MAX_LINE_BYTES)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong => Response::err_kind(
                "line_too_long",
                format!("request line exceeds {MAX_LINE_BYTES} bytes"),
            )
            .encode_line(&mut scratch.out),
            LineRead::Line => match std::str::from_utf8(&buf) {
                Ok(line) if line.trim().is_empty() => continue,
                Ok(line) => respond(pool, line, &mut scratch),
                // lossy replacement would silently mangle profile keys;
                // reject like any other malformed payload
                Err(_) => Response::err_kind("bad_request", "request line is not valid UTF-8")
                    .encode_line(&mut scratch.out),
            },
        }
        // one newline-terminated buffer, one write syscall per response
        writer.write_all(&scratch.out)?;
        writer.flush()?;
    }
}

enum LineRead {
    /// A complete line (newline stripped) is in the buffer.
    Line,
    /// The line exceeded `max`; its bytes were discarded up to and
    /// including the terminating newline (or EOF).
    TooLong,
    /// Clean end of stream with no pending bytes.
    Eof,
}

/// `read_line` with a hard cap: never holds more than `max` line bytes
/// (plus the reader's fixed internal buffer) regardless of what the peer
/// sends. Oversized lines are drained, not buffered.
fn read_line_bounded<R: BufRead>(
    reader: &mut R,
    buf: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    loop {
        let (consume, found_newline, overflow) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                return Ok(if buf.is_empty() {
                    LineRead::Eof
                } else {
                    LineRead::Line // final unterminated line
                });
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if buf.len() + pos > max {
                        (pos + 1, true, true)
                    } else {
                        buf.extend_from_slice(&available[..pos]);
                        (pos + 1, true, false)
                    }
                }
                None => {
                    if buf.len() + available.len() > max {
                        (available.len(), false, true)
                    } else {
                        buf.extend_from_slice(available);
                        (available.len(), false, false)
                    }
                }
            }
        };
        reader.consume(consume);
        if overflow {
            if !found_newline {
                drain_until_newline(reader)?;
            }
            return Ok(LineRead::TooLong);
        }
        if found_newline {
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Ok(LineRead::Line);
        }
    }
}

/// Discard bytes up to and including the next newline (or EOF).
fn drain_until_newline<R: BufRead>(reader: &mut R) -> std::io::Result<()> {
    loop {
        let (consume, done) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                return Ok(());
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => (pos + 1, true),
                None => (available.len(), false),
            }
        };
        reader.consume(consume);
        if done {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::{drain_until_newline, read_line_bounded, serve_pool, serve_pool_watched, LineRead};
    use crate::coordinator::dispatch::{EnginePool, Job};
    use crate::util::Json;
    use std::io::{BufRead as _, BufReader, Write as _};
    use std::net::TcpStream;
    use std::sync::mpsc::Receiver;
    use std::time::Duration;

    fn reader(bytes: &[u8]) -> BufReader<std::io::Cursor<Vec<u8>>> {
        // tiny internal buffer so lines span many fill_buf() rounds
        BufReader::with_capacity(8, std::io::Cursor::new(bytes.to_vec()))
    }

    #[test]
    fn reads_lines_and_strips_terminators() {
        let mut r = reader(b"alpha\nbeta\r\n\ngamma");
        let mut buf = Vec::new();
        for expect in [&b"alpha"[..], b"beta", b"", b"gamma"] {
            buf.clear();
            assert!(matches!(
                read_line_bounded(&mut r, &mut buf, 64).unwrap(),
                LineRead::Line
            ));
            assert_eq!(buf, expect);
        }
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 64).unwrap(),
            LineRead::Eof
        ));
    }

    #[test]
    fn oversized_line_is_rejected_and_stream_recovers() {
        let mut input = vec![b'x'; 1000];
        input.push(b'\n');
        input.extend_from_slice(b"ok\n");
        let mut r = reader(&input);
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::TooLong
        ));
        // the bounded reader never buffered more than the cap
        assert!(buf.len() <= 100, "{}", buf.len());
        // and the next line parses normally
        buf.clear();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::Line
        ));
        assert_eq!(buf, b"ok");
    }

    #[test]
    fn oversized_line_at_exact_boundary() {
        // a line of exactly `max` bytes is allowed
        let mut input = vec![b'y'; 100];
        input.push(b'\n');
        let mut r = reader(&input);
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::Line
        ));
        assert_eq!(buf.len(), 100);
        // one byte more is not
        let mut input = vec![b'y'; 101];
        input.push(b'\n');
        let mut r = reader(&input);
        buf.clear();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::TooLong
        ));
    }

    #[test]
    fn unterminated_oversized_line_hits_eof() {
        let input = vec![b'z'; 500];
        let mut r = reader(&input);
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::TooLong
        ));
        buf.clear(); // the connection loop clears between lines
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 100).unwrap(),
            LineRead::Eof
        ));
    }

    #[test]
    fn final_unterminated_line_is_returned() {
        let mut r = reader(b"tail-no-newline");
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 64).unwrap(),
            LineRead::Line
        ));
        assert_eq!(buf, b"tail-no-newline");
    }

    #[test]
    fn drain_stops_at_newline() {
        let mut r = reader(b"aaaaaaaaaaaaaaaaaaaa\nnext");
        drain_until_newline(&mut r).unwrap();
        let mut buf = Vec::new();
        assert!(matches!(
            read_line_bounded(&mut r, &mut buf, 64).unwrap(),
            LineRead::Line
        ));
        assert_eq!(buf, b"next");
    }

    // ---- pool-backed server behavior (mock lanes, no PJRT needed) ----

    /// Mock lane: answers every job `ok`, optionally after a delay.
    fn slow_echo(delay: Duration) -> impl Fn(usize, Receiver<Job>) + Send + Sync + Clone + 'static {
        move |_idx, rx| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    Job::Predict(_, _, reply) => {
                        std::thread::sleep(delay);
                        let _ = reply.send(crate::coordinator::protocol::Response::Latency {
                            latency_ms: 1.0,
                        });
                    }
                    other => {
                        std::thread::sleep(delay);
                        // reply ok to whatever carries a reply channel
                        match other {
                            Job::BatchSize { reply, .. }
                            | Job::PixelSize { reply, .. }
                            | Job::Recommend { reply, .. }
                            | Job::Plan { reply, .. } => {
                                let _ = reply
                                    .send(crate::coordinator::protocol::Response::Health);
                            }
                            _ => {}
                        }
                    }
                }
            }
        }
    }

    fn predict_line() -> &'static str {
        r#"{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":10.0,"profile":{"Conv2D":1.0}}"#
    }

    #[test]
    fn stop_drains_in_flight_requests_without_dropping_responses() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // mock engine that signals job pickup, then works "slowly"
        let picked = std::sync::Arc::new(AtomicUsize::new(0));
        let picked2 = picked.clone();
        let body = move |_idx: usize, rx: Receiver<Job>| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    Job::Predict(_, _, reply) => {
                        picked2.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(300));
                        let _ = reply.send(crate::coordinator::protocol::Response::Latency {
                            latency_ms: 1.0,
                        });
                    }
                    _ => {}
                }
            }
        };
        let pool = EnginePool::mock(1, 16, 4, body.clone(), move |rx| body(0, rx));
        let handle = serve_pool("127.0.0.1:0", pool, 8).unwrap();
        let addr = handle.addr;

        // a client with a request in flight on a slow engine
        let client = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(predict_line().as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut reader = BufReader::new(stream);
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            resp
        });
        // wait until the engine has provably picked the request up, then
        // drain mid-flight (a fixed sleep would race conn scheduling)
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while picked.load(Ordering::SeqCst) == 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "request never reached the mock engine"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        handle.stop();
        // stop() returned only after the handler flushed the response
        let resp = client.join().unwrap();
        let j = Json::parse(resp.trim()).expect("drained connection lost its response");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{resp}");
    }

    /// The `--model-dir-watch` poller submits *conditional* reload jobs
    /// to the trainer lane on its interval, and the graceful drain stops
    /// it immediately (no waiting out a poll period).
    #[test]
    fn model_dir_watcher_submits_conditional_reloads_and_stops_on_drain() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let reloads = std::sync::Arc::new(AtomicUsize::new(0));
        let r2 = reloads.clone();
        let advisor = move |rx: Receiver<Job>| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    Job::Reload {
                        only_if_changed,
                        reply,
                    } => {
                        assert!(only_if_changed, "watcher reloads must be conditional");
                        r2.fetch_add(1, Ordering::SeqCst);
                        let _ = reply.send(
                            crate::coordinator::protocol::Response::Reloaded { epoch: 1 },
                        );
                    }
                    _ => {}
                }
            }
        };
        let body = slow_echo(Duration::ZERO);
        let pool = EnginePool::mock(1, 16, 8, body, advisor);
        let handle = serve_pool_watched(
            "127.0.0.1:0",
            pool,
            8,
            Some(Duration::from_millis(20)),
        )
        .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while reloads.load(Ordering::SeqCst) < 2 {
            assert!(
                std::time::Instant::now() < deadline,
                "watcher never polled the model dir"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        let t0 = std::time::Instant::now();
        handle.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "drain waited out the watcher interval"
        );
    }

    #[test]
    fn connection_budget_rejects_with_structured_overloaded() {
        let body = slow_echo(Duration::ZERO);
        let pool = EnginePool::mock(1, 16, 4, body.clone(), move |rx| body(0, rx));
        let handle = serve_pool("127.0.0.1:0", pool, 1).unwrap();
        let addr = handle.addr;

        // connection 1 occupies the whole budget (held open, proven live)
        let s1 = TcpStream::connect(addr).unwrap();
        let mut w1 = s1.try_clone().unwrap();
        w1.write_all(predict_line().as_bytes()).unwrap();
        w1.write_all(b"\n").unwrap();
        let mut r1 = BufReader::new(s1);
        let mut resp = String::new();
        r1.read_line(&mut resp).unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");

        // connection 2 is rejected with one structured line, then EOF
        let s2 = TcpStream::connect(addr).unwrap();
        let mut r2 = BufReader::new(s2);
        let mut line = String::new();
        r2.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{line}");
        assert_eq!(j.req_str("kind").unwrap(), "overloaded");
        line.clear();
        assert_eq!(r2.read_line(&mut line).unwrap(), 0, "rejected conn not closed");
        assert!(
            handle.stats.overloaded.load(std::sync::atomic::Ordering::Relaxed) >= 1
        );

        // closing connection 1 frees the budget for a new connection
        drop(r1);
        drop(w1);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let served = loop {
            let s3 = TcpStream::connect(addr).unwrap();
            let mut w3 = s3.try_clone().unwrap();
            w3.write_all(predict_line().as_bytes()).unwrap();
            w3.write_all(b"\n").unwrap();
            let mut r3 = BufReader::new(s3);
            let mut resp = String::new();
            r3.read_line(&mut resp).unwrap();
            if resp.contains("\"ok\":true") {
                break true;
            }
            if std::time::Instant::now() > deadline {
                break false;
            }
            std::thread::sleep(Duration::from_millis(20));
        };
        assert!(served, "budget slot was never released");
        handle.stop();
    }
}
