//! Engine replica pool + lane dispatcher.
//!
//! The seed served every op through ONE `profet-engine` thread, so a
//! single `recommend` sweep (hundreds of grid points) stalled every
//! concurrent `predict` behind it — classic head-of-line blocking. The
//! pool replaces that thread with N+2 engine replicas, each owning its
//! own non-`Send` PJRT [`Runtime`] (nothing non-`Send` ever crosses a
//! thread boundary; trained models are plain data, shared through the
//! epoch-stamped [`ModelRegistry`]):
//!
//! * **predict lanes** (N, default = available parallelism) run the
//!   dynamic-batching loop ([`crate::coordinator::lane::predict_lane`]).
//!   Phase-1 `predict` jobs are routed by (anchor, target) *affinity* —
//!   the same instance pair always lands on the same lane, so concurrent
//!   identical-pair requests still coalesce into one batched artifact
//!   execution. Cheap interpolation ops round-robin across lanes.
//! * **the advisor lane** (1, always present) runs `recommend`/`plan`
//!   sweeps. A sweep can therefore never block predict traffic: the worst
//!   case is sweeps queueing behind each other on their own lane.
//! * **the trainer lane** (1, always present) runs the registry's write
//!   side — `ingest` staging appends, `onboard` retraining, and `reload`
//!   — modeled on the advisor lane so a multi-second training job can
//!   never block predict traffic either. It is also the only writer of
//!   the staging area and the model directory, which is what lets both
//!   go lock-free.
//!
//! Every job carries the [`ModelSnapshot`] it was admitted with: a
//! registry swap mid-queue changes nothing for jobs already submitted
//! (they are answered by the epoch they started on), and the epoch woven
//! into every cache key keeps post-swap lookups from ever matching
//! pre-swap entries.
//!
//! Replicas share the sharded phase-1 [`PredictionCache`], the
//! [`CacheStats`] counters, and the memoized multi-GPU [`ScalingTable`]
//! behind one `Arc` each — repeat traffic hits the cache regardless of
//! which replica answered the first request, and hit/miss counters stay
//! coherent across the pool.
//!
//! Every lane queue is *bounded* (`sync_channel`). When a queue is full,
//! [`EnginePool::submit`] fails fast with [`SubmitError::Overloaded`]
//! instead of buffering unboundedly; the router turns that into a
//! structured `{"ok":false,"kind":"overloaded"}` reply so clients can
//! back off. Dropping the pool sends a shutdown job to every lane and
//! joins it — in-flight jobs are flushed, never leaked.

use crate::advisor::{CacheStats, Objective, PredictionCache, SweepRequest, TrainingJob};
use crate::coordinator::lane::{self, LaneCtx};
use crate::coordinator::protocol::{PredictRequest, Response};
use crate::coordinator::reactor::CompletionQueue;
use crate::coordinator::registry::{IngestRequest, ModelRegistry, ModelSnapshot, OnboardOptions};
use crate::gpu::Instance;
use crate::obs::{Obs, OpClass, Stage, Temp, TraceState};
use crate::runtime::Runtime;
use crate::sim::multigpu::ScalingTable;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Observability metadata riding on every [`Reply`]: the monotonic
/// stage timestamps the latency observatory needs (admission, lane
/// dequeue, completion-queue push), the `(op, temp)` histogram key, and
/// — for sampled requests — the boxed per-request [`TraceState`].
///
/// `Instant`s are stored inline (no boxing); only the trace allocates,
/// and only on the cold submit path, which already allocates to
/// materialize the job.
#[derive(Debug)]
pub struct ReqMeta {
    /// Admission instant (reply construction in the router/reactor).
    pub(crate) submitted: Instant,
    /// Lane dequeue instant (set by the lane's absorb step).
    pub(crate) dequeued: Option<Instant>,
    /// Completion-queue push instant (set by [`Reply::send`]).
    pub(crate) pushed: Option<Instant>,
    pub(crate) op: OpClass,
    pub(crate) temp: Temp,
    pub(crate) trace: Option<Box<TraceState>>,
    /// Absolute expiry instant for the request's queue-time budget
    /// (`--default-deadline-ms`). `None` = no deadline. Jobs past it are
    /// shed at lane dequeue with the `deadline_exceeded` error kind.
    pub(crate) deadline: Option<Instant>,
}

impl ReqMeta {
    fn new() -> ReqMeta {
        ReqMeta {
            submitted: Instant::now(),
            dequeued: None,
            pushed: None,
            op: OpClass::Other,
            temp: Temp::Cold,
            trace: None,
            deadline: None,
        }
    }

    /// Record one stage observation into the histograms AND the
    /// request's trace (when it carries one).
    pub(crate) fn record(&mut self, obs: &Obs, stage: Stage, ns: u64) {
        obs.record_ns(stage, self.op, self.temp, ns);
        if let Some(t) = self.trace.as_deref_mut() {
            t.note(stage, ns);
        }
    }
}

/// Where a lane delivers a job's [`Response`]. Blocking callers (CLI
/// paths, the model-dir watcher, tests) hold the receiving end of a
/// channel; reactor connections instead enqueue the response on their
/// owning reactor thread's [`CompletionQueue`], which wakes the reactor
/// to flush it on writable readiness — no thread ever parks per request.
///
/// The destination is held as an `Option` so the drop guard below can
/// tell a delivered reply (`None`) from one abandoned by a panic
/// unwinding through a lane body — the only way a `Reply` drops while
/// still armed.
pub struct Reply {
    kind: Option<ReplyKind>,
    meta: ReqMeta,
}

enum ReplyKind {
    Channel(Sender<Response>),
    Completion { queue: Arc<CompletionQueue>, conn: u64 },
}

impl Reply {
    /// A blocking reply: the caller waits on the channel's receiver.
    pub fn channel(tx: Sender<Response>) -> Reply {
        Reply {
            kind: Some(ReplyKind::Channel(tx)),
            meta: ReqMeta::new(),
        }
    }

    /// A reactor reply: the response is queued for connection `conn` on
    /// its reactor's completion queue (which wakes the reactor).
    pub(crate) fn completion(queue: Arc<CompletionQueue>, conn: u64) -> Reply {
        Reply {
            kind: Some(ReplyKind::Completion { queue, conn }),
            meta: ReqMeta::new(),
        }
    }

    pub(crate) fn meta_mut(&mut self) -> &mut ReqMeta {
        &mut self.meta
    }

    /// Deliver the response. Consumes the reply — every job answers
    /// exactly once. A disconnected channel receiver (caller gave up) is
    /// ignored, same as the old raw `Sender` behavior.
    pub fn send(mut self, resp: Response) {
        self.deliver(resp);
    }

    /// Shared delivery path for [`Reply::send`] and the drop guard.
    /// Taking the kind disarms the guard; the meta is moved out with a
    /// fresh placeholder so `&mut self` delivery works from `Drop`.
    fn deliver(&mut self, resp: Response) {
        let Some(kind) = self.kind.take() else { return };
        let mut meta = std::mem::replace(&mut self.meta, ReqMeta::new());
        match kind {
            ReplyKind::Channel(tx) => {
                let _ = tx.send(resp);
            }
            ReplyKind::Completion { queue, conn } => {
                meta.pushed = Some(Instant::now());
                queue.push(conn, resp, meta);
            }
        }
    }
}

/// No-lost-replies guarantee: a `Reply` dropped while still armed — a
/// panic unwinding through a lane body is the only path — answers its
/// caller with a structured `internal_error` instead of leaving a
/// channel hung or a reactor connection wedged forever. The lane
/// supervisor ([`supervise`]) then respawns the replica, so the error
/// text can promise a restart.
impl Drop for Reply {
    fn drop(&mut self) {
        if self.kind.is_some() {
            self.deliver(Response::err_kind(
                "internal_error",
                "engine replica panicked mid-request; lane restarted",
            ));
        }
    }
}

/// Work item submitted to an engine lane. Model-consuming jobs carry the
/// [`ModelSnapshot`] captured at admission, pinning them to one registry
/// epoch for their whole life.
pub enum Job {
    Predict(PredictRequest, ModelSnapshot, Reply),
    BatchSize {
        instance: Instance,
        batch: usize,
        t_min: f64,
        t_max: f64,
        snap: ModelSnapshot,
        reply: Reply,
    },
    PixelSize {
        instance: Instance,
        pixels: usize,
        t_min: f64,
        t_max: f64,
        snap: ModelSnapshot,
        reply: Reply,
    },
    Recommend {
        query: SweepRequest,
        top_k: usize,
        snap: ModelSnapshot,
        reply: Reply,
    },
    Plan {
        query: SweepRequest,
        job: TrainingJob,
        objective: Objective,
        snap: ModelSnapshot,
        reply: Reply,
    },
    /// Stage one profiled measurement (trainer lane).
    Ingest {
        req: IngestRequest,
        reply: Reply,
    },
    /// Train staged pairs and publish a new epoch (trainer lane).
    /// `dry_run` validates without publishing (the route tier's phase-1
    /// vote).
    Onboard {
        pair: Option<(Instance, Instance)>,
        dry_run: bool,
        reply: Reply,
    },
    /// Re-load the model dir and publish a new epoch (trainer lane).
    /// `only_if_changed` is the mtime watcher's mode — a directory whose
    /// fingerprint hasn't moved is skipped silently. `dry_run` validates
    /// the on-disk candidate without swapping it in.
    Reload {
        only_if_changed: bool,
        dry_run: bool,
        reply: Reply,
    },
    Shutdown,
}

impl Job {
    /// The reply's observability metadata, for lanes to stamp dequeue
    /// times and record stage histograms. `Shutdown` carries none.
    pub(crate) fn meta_mut(&mut self) -> Option<&mut ReqMeta> {
        match self {
            Job::Predict(_, _, reply) => Some(reply.meta_mut()),
            Job::BatchSize { reply, .. }
            | Job::PixelSize { reply, .. }
            | Job::Recommend { reply, .. }
            | Job::Plan { reply, .. }
            | Job::Ingest { reply, .. }
            | Job::Onboard { reply, .. }
            | Job::Reload { reply, .. } => Some(reply.meta_mut()),
            Job::Shutdown => None,
        }
    }
}

/// Serving statistics, shared by every replica (exposed for
/// tests/monitoring through the `stats` op).
#[derive(Debug, Default)]
pub struct EngineStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of group sizes — *unique* predictions computed per artifact
    /// execution (cache hits and in-batch duplicates don't count).
    pub batched_requests: AtomicU64,
    /// Jobs/connections rejected with the structured `overloaded` error
    /// (full lane queue or exhausted connection budget).
    pub overloaded: AtomicU64,
    /// Lane replicas respawned by the supervisor after a panic (counter;
    /// the `stats` op's `lane_restarts` field). A healthy process stays
    /// at 0 forever.
    pub lane_restarts: AtomicU64,
    /// Phase-1 prediction-cache hit/miss counters (predict + advisor),
    /// shared across all replicas.
    pub cache: CacheStats,
    /// Peer cache hints accepted and inserted by the `hint` op (counter;
    /// an epoch-mismatched hint is acknowledged but not counted).
    pub hints_applied: AtomicU64,
    /// Reactor connection-tier health (the `stats` op's
    /// `open_conns`/`active_conns`/`idle_conns`/`evictions` fields).
    pub conns: ConnStats,
}

/// Connection-tier health, maintained by the acceptor and the reactor
/// threads, read by the router's `stats` op.
#[derive(Debug, Default)]
pub struct ConnStats {
    /// Connections currently open (gauge) — includes idle keep-alives.
    /// The acceptor increments it at admission; the owning reactor
    /// decrements at close, so it doubles as the connection-budget count.
    pub open: AtomicU64,
    /// Connections with an engine job in flight right now (gauge).
    /// `idle_conns` reported by the `stats` op is `open - active`.
    pub active: AtomicU64,
    /// Connections evicted by the reactor idle timeout (counter).
    pub evicted: AtomicU64,
    /// Reactor threads serving connections (set once at serve start).
    pub reactor_threads: AtomicU64,
}

/// Pool sizing/backpressure knobs.
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Number of predict lanes; `0` means `available_parallelism()`.
    /// The advisor and trainer lanes are always two additional replicas.
    pub predict_lanes: usize,
    /// Bound on each predict lane's job queue.
    pub predict_queue_cap: usize,
    /// Bound on the advisor lane's job queue (sweeps are long-running, so
    /// a deep queue would only hide latency — keep it shallow).
    pub advisor_queue_cap: usize,
    /// Bound on the trainer lane's job queue (`ingest` appends are cheap
    /// and bursty; `onboard`/`reload` are rare).
    pub trainer_queue_cap: usize,
    /// Hyper-parameters the trainer lane uses for `onboard` retraining.
    pub onboard: OnboardOptions,
    /// Completed request traces at/above this admission→delivery total
    /// (milliseconds) enter the slow-request ring and are dumped as one
    /// structured JSON line on stderr (`repro serve --trace-slow-ms`).
    pub trace_slow_ms: f64,
    /// Every Nth engine submission carries a trace context; `1` traces
    /// everything, `0` disables tracing (`repro serve --trace-sample`).
    pub trace_sample: u64,
    /// Queue-time budget stamped into every engine submission
    /// (`repro serve --default-deadline-ms`); a job still queued past
    /// `submitted + deadline` is shed at lane dequeue with the
    /// `deadline_exceeded` error kind. `None` disables deadlines.
    pub default_deadline: Option<Duration>,
}

impl Default for PoolOptions {
    fn default() -> PoolOptions {
        PoolOptions {
            predict_lanes: 0,
            predict_queue_cap: 512,
            advisor_queue_cap: 8,
            trainer_queue_cap: 64,
            onboard: OnboardOptions::default(),
            trace_slow_ms: 250.0,
            trace_sample: 1,
            default_deadline: None,
        }
    }
}

impl PoolOptions {
    /// Resolved predict-lane count (the `0 => auto` rule applied).
    pub fn resolved_predict_lanes(&self) -> usize {
        if self.predict_lanes > 0 {
            self.predict_lanes
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2)
        }
    }
}

/// Why a submit was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The target lane's queue is full — shed load, don't buffer.
    Overloaded,
    /// The target lane is gone (engine shut down).
    Gone,
}

struct Lane {
    tx: SyncSender<Job>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Spawn one worker thread with a bounded job queue.
fn spawn_worker<F>(name: &str, cap: usize, body: F) -> Result<Lane>
where
    F: FnOnce(Receiver<Job>) + Send + 'static,
{
    let (tx, rx) = sync_channel::<Job>(cap.max(1));
    let join = std::thread::Builder::new()
        .name(name.to_string())
        .spawn(move || body(rx))?;
    Ok(Lane {
        tx,
        join: Some(join),
    })
}

/// Phase-1 prediction cache shape: shards bound lock scope, the total
/// capacity bounds memory. Each entry carries the canonical quantized
/// profile bytes (collision-proof equality), ~1-2 KB for a realistic
/// aggregated profile, so 32k entries cap the cache around tens of MB.
/// Registry swaps don't flush it: superseded epochs' entries stop
/// matching (the epoch is part of every key) and age out FIFO.
const CACHE_SHARDS: usize = 16;
const CACHE_CAPACITY: usize = 32_768;

/// Which loop a real engine replica runs.
#[derive(Clone, Copy, PartialEq, Eq)]
enum LaneKind {
    Predict,
    Advisor,
    Trainer,
}

/// Handle to the engine replica pool.
pub struct EnginePool {
    predict: Vec<Lane>,
    advisor: Lane,
    trainer: Lane,
    /// Round-robin cursor for non-affine immediate jobs.
    rr: AtomicUsize,
    pub stats: Arc<EngineStats>,
    /// The shared phase-1 prediction cache (the same `Arc` every lane
    /// holds) — the router peeks it to answer warm `predict`s without an
    /// engine round trip.
    cache: Arc<PredictionCache>,
    /// The live model registry — the router snapshots it per request; the
    /// trainer lane swaps it on `onboard`/`reload`.
    registry: Arc<ModelRegistry>,
    /// The latency observatory every tier records into (reactor parse /
    /// warm lookups, lane queue/batch/execute stages, registry swaps)
    /// and the `metrics` op reads from.
    obs: Arc<Obs>,
    /// Queue-time budget the router stamps into every submission
    /// ([`PoolOptions::default_deadline`]).
    default_deadline: Option<Duration>,
}

impl EnginePool {
    /// Spawn the replicas. The trained models load ONCE into the
    /// [`ModelRegistry`] (manifest-checked by [`crate::predictor::Profet::load`])
    /// and are shared read-only across every lane through epoch-stamped
    /// `Arc` snapshots — only the non-`Send` PJRT [`Runtime`] is loaded
    /// inside each lane's own thread (in parallel). The trainer lane runs
    /// the registry's probe-validation gate against the initial model set
    /// before reporting ready, so a pool never comes up serving models
    /// that can't answer the canned probe. Fails if the registry or any
    /// replica's runtime fails to load.
    pub fn spawn(
        artifact_dir: PathBuf,
        model_dir: PathBuf,
        opts: &PoolOptions,
    ) -> Result<EnginePool> {
        let registry = Arc::new(ModelRegistry::open(model_dir)?);
        EnginePool::spawn_with_registry(artifact_dir, registry, opts)
    }

    /// [`EnginePool::spawn`] over a pre-built registry (the path `serve`
    /// takes when the caller already loaded or trained the models).
    pub fn spawn_with_registry(
        artifact_dir: PathBuf,
        registry: Arc<ModelRegistry>,
        opts: &PoolOptions,
    ) -> Result<EnginePool> {
        let stats = Arc::new(EngineStats::default());
        let cache = Arc::new(PredictionCache::new(CACHE_SHARDS, CACHE_CAPACITY));
        let obs = Arc::new(Obs::new(opts.trace_slow_ms, opts.trace_sample));
        registry.set_obs(obs.clone());
        let ctx = LaneCtx {
            cache: cache.clone(),
            scaling: Arc::new(ScalingTable::new()),
            stats: stats.clone(),
            registry: registry.clone(),
            onboard: opts.onboard.clone(),
            obs: obs.clone(),
        };
        let n = opts.resolved_predict_lanes().max(1);
        let mut predict = Vec::with_capacity(n);
        let mut readies = Vec::with_capacity(n + 2);
        for i in 0..n {
            let (lane, ready) = spawn_engine_lane(
                format!("profet-predict-{i}"),
                opts.predict_queue_cap,
                artifact_dir.clone(),
                ctx.clone(),
                LaneKind::Predict,
            )?;
            predict.push(lane);
            readies.push(ready);
        }
        let (advisor, ready) = spawn_engine_lane(
            "profet-advisor".into(),
            opts.advisor_queue_cap,
            artifact_dir.clone(),
            ctx.clone(),
            LaneKind::Advisor,
        )?;
        readies.push(ready);
        let (trainer, ready) = spawn_engine_lane(
            "profet-trainer".into(),
            opts.trainer_queue_cap,
            artifact_dir,
            ctx,
            LaneKind::Trainer,
        )?;
        readies.push(ready);
        let pool = EnginePool {
            predict,
            advisor,
            trainer,
            rr: AtomicUsize::new(0),
            stats,
            cache,
            registry,
            obs,
            default_deadline: opts.default_deadline,
        };
        // wait for every replica to come up; on failure the pool drop
        // below shuts down and joins the lanes that did start
        for ready in readies {
            ready
                .recv()
                .map_err(|_| anyhow::anyhow!("engine replica died during load"))?
                .map_err(|e| anyhow::anyhow!(e))?;
        }
        Ok(pool)
    }

    /// Number of predict lanes (the advisor + trainer lanes are two more
    /// replicas).
    pub fn predict_lanes(&self) -> usize {
        self.predict.len()
    }

    /// The shared phase-1 prediction cache (router fast-path peeks).
    pub fn cache(&self) -> &Arc<PredictionCache> {
        &self.cache
    }

    /// The live model registry (router snapshots + `stats` fields).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// The pool's latency observatory (histograms, traces, uptime).
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// The queue-time budget the router stamps into submissions
    /// (`None` = deadlines disabled).
    pub(crate) fn default_deadline(&self) -> Option<Duration> {
        self.default_deadline
    }

    /// Deterministic (anchor, target) → predict-lane affinity, so
    /// same-pair requests coalesce in one lane's batching window.
    fn lane_of(&self, anchor: Instance, target: Instance) -> usize {
        (crate::util::seed_of(&[anchor.key(), target.key()]) % self.predict.len() as u64) as usize
    }

    /// Route a job to its lane. Fails fast (never blocks, never buffers
    /// past the lane bound) — `Overloaded` is the backpressure signal.
    pub fn submit(&self, job: Job) -> std::result::Result<(), SubmitError> {
        let lane = match &job {
            Job::Predict(req, _, _) => &self.predict[self.lane_of(req.anchor, req.target)],
            Job::Recommend { .. } | Job::Plan { .. } => &self.advisor,
            Job::Ingest { .. } | Job::Onboard { .. } | Job::Reload { .. } => &self.trainer,
            // shutdown is meaningful only from the pool's own Drop (which
            // bypasses submit and signals every lane directly); routing an
            // external one would silently kill a single predict lane
            Job::Shutdown => return Ok(()),
            _ => {
                // ordering: round-robin cursor — any interleaving of the
                // increments is an acceptable lane assignment.
                let i = self.rr.fetch_add(1, Ordering::Relaxed) % self.predict.len();
                &self.predict[i]
            }
        };
        match lane.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => {
                // ordering: stats-only shed counter; orders nothing.
                self.stats.overloaded.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Gone),
        }
    }

    fn lanes_mut(&mut self) -> impl Iterator<Item = &mut Lane> {
        self.predict
            .iter_mut()
            .chain(std::iter::once(&mut self.advisor))
            .chain(std::iter::once(&mut self.trainer))
    }

    /// Test-only pool over caller-provided lane bodies (no PJRT runtime
    /// needed): exercises dispatch/affinity/backpressure in isolation.
    /// The trainer lane reuses the advisor body shape. Bodies run under
    /// the same [`supervise`] loop as real replicas (borrowing the
    /// receiver so a respawn re-enters the body on the same queue), which
    /// lets tests drive the panic-respawn path without a runtime.
    #[cfg(test)]
    pub(crate) fn mock<FP, FA>(
        n_predict: usize,
        predict_cap: usize,
        advisor_cap: usize,
        predict_body: FP,
        advisor_body: FA,
    ) -> EnginePool
    where
        FP: Fn(usize, &Receiver<Job>) + Send + Sync + Clone + 'static,
        FA: Fn(&Receiver<Job>) + Send + Sync + Clone + 'static,
    {
        let stats = Arc::new(EngineStats::default());
        let predict = (0..n_predict.max(1))
            .map(|i| {
                let body = predict_body.clone();
                let stats = stats.clone();
                spawn_worker(&format!("mock-predict-{i}"), predict_cap, move |rx| {
                    supervise(&format!("mock-predict-{i}"), &stats, || body(i, &rx))
                })
                .unwrap()
            })
            .collect();
        let advisor = {
            let body = advisor_body.clone();
            let stats = stats.clone();
            spawn_worker("mock-advisor", advisor_cap, move |rx| {
                supervise("mock-advisor", &stats, || body(&rx))
            })
            .unwrap()
        };
        let trainer = {
            let stats = stats.clone();
            spawn_worker("mock-trainer", advisor_cap, move |rx| {
                supervise("mock-trainer", &stats, || advisor_body(&rx))
            })
            .unwrap()
        };
        EnginePool {
            predict,
            advisor,
            trainer,
            rr: AtomicUsize::new(0),
            stats,
            cache: Arc::new(PredictionCache::new(4, 1024)),
            registry: Arc::new(crate::coordinator::registry::test_registry("mockpool")),
            obs: Arc::new(Obs::new(PoolOptions::default().trace_slow_ms, 1)),
            default_deadline: None,
        }
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        // `send` (not `try_send`): a full queue is being drained by its
        // lane, so the shutdown job queues behind in-flight work and
        // every accepted job is flushed before the lane exits.
        for lane in self
            .predict
            .iter()
            .chain([&self.advisor, &self.trainer])
        {
            let _ = lane.tx.send(Job::Shutdown);
        }
        for lane in self.lanes_mut() {
            if let Some(j) = lane.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Run one lane body under supervision: a panic unwinding out of `body`
/// — a poisoned model, a bug, an injected `lane.execute` failpoint — is
/// caught, counted in `stats.lane_restarts`, and the body re-entered
/// after a capped exponential backoff (10ms doubling to 1s). The job the
/// panic interrupted still answers: its [`Reply`] drop guard sends
/// `internal_error` during the unwind. A clean return is a real shutdown
/// and ends the loop. The body keeps borrowing the same receiver and
/// runtime across restarts, so a respawn costs the backoff sleep, not a
/// runtime reload.
fn supervise<F>(name: &str, stats: &EngineStats, mut body: F)
where
    F: FnMut(),
{
    let mut backoff = Duration::from_millis(10);
    loop {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(&mut body)) {
            Ok(()) => return,
            Err(_) => {
                // ordering: stats-only restart counter; orders nothing.
                stats.lane_restarts.fetch_add(1, Ordering::Relaxed);
                eprintln!("lane {name}: replica panicked; respawning after {backoff:?}");
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(1));
            }
        }
    }
}

/// Spawn one real engine replica; the non-`Send` PJRT runtime loads
/// inside the thread, readiness reported through the returned channel.
/// The trainer replica additionally probe-validates the registry's
/// initial model set before reporting ready. Once ready, the lane loop
/// runs under [`supervise`], so a panic respawns the replica instead of
/// silently killing the lane.
#[allow(clippy::type_complexity)]
fn spawn_engine_lane(
    name: String,
    cap: usize,
    artifact_dir: PathBuf,
    ctx: LaneCtx,
    kind: LaneKind,
) -> Result<(Lane, Receiver<std::result::Result<(), String>>)> {
    let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
    let thread_name = name.clone();
    let lane = spawn_worker(&thread_name, cap, move |rx| {
        let rt = match Runtime::load(&artifact_dir) {
            Ok(rt) => rt,
            Err(e) => {
                let _ = ready_tx.send(Err(format!("runtime: {e:#}")));
                return;
            }
        };
        if kind == LaneKind::Trainer {
            let snap = ctx.registry.snapshot();
            if let Err(e) = ModelRegistry::validate(&rt, &snap.profet) {
                let _ = ready_tx.send(Err(format!("model validation: {e:#}")));
                return;
            }
        }
        let _ = ready_tx.send(Ok(()));
        let stats = ctx.stats.clone();
        supervise(&name, &stats, || match kind {
            LaneKind::Predict => lane::predict_lane(&rt, &rx, &ctx),
            LaneKind::Advisor => lane::advisor_lane(&rt, &rx, &ctx),
            LaneKind::Trainer => lane::trainer_lane(&rt, &rx, &ctx),
        });
    })?;
    Ok((lane, ready_rx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry;
    use std::collections::BTreeMap;
    use std::sync::Mutex;
    use std::time::Duration;

    fn predict_req(anchor: Instance, target: Instance) -> PredictRequest {
        PredictRequest {
            anchor,
            target,
            anchor_latency_ms: 10.0,
            profile: BTreeMap::from([("Conv2D".to_string(), 1.0)]),
        }
    }

    fn snap() -> ModelSnapshot {
        ModelSnapshot {
            epoch: 1,
            profet: Arc::new(registry::empty_profet()),
        }
    }

    /// Lane body that answers every job instantly, echoing its lane index
    /// through the `latency_ms` field of a typed reply.
    fn echo_lane(idx: usize, rx: &Receiver<Job>) {
        for job in rx {
            match job {
                Job::Shutdown => return,
                Job::Predict(_, _, reply) => {
                    reply.send(Response::Latency {
                        latency_ms: idx as f64,
                    });
                }
                Job::BatchSize { reply, .. } | Job::PixelSize { reply, .. } => {
                    reply.send(Response::Health);
                }
                Job::Recommend { reply, .. } | Job::Plan { reply, .. } => {
                    reply.send(Response::Health);
                }
                Job::Ingest { reply, .. }
                | Job::Onboard { reply, .. }
                | Job::Reload { reply, .. } => {
                    reply.send(Response::Latency {
                        latency_ms: idx as f64,
                    });
                }
            }
        }
    }

    #[test]
    fn predict_affinity_is_sticky_per_instance_pair() {
        let pool = EnginePool::mock(4, 64, 4, echo_lane, |rx| echo_lane(99, rx));
        let pairs = [
            (Instance::G4dn, Instance::P3),
            (Instance::G4dn, Instance::P2),
            (Instance::P3, Instance::G4dn),
        ];
        for (anchor, target) in pairs {
            let mut lanes = Vec::new();
            for _ in 0..8 {
                let (tx, rx) = channel();
                pool.submit(Job::Predict(predict_req(anchor, target), snap(), Reply::channel(tx)))
                    .unwrap();
                let resp = rx.recv().unwrap();
                let Response::Latency { latency_ms } = resp else { panic!("err") };
                lanes.push(latency_ms as usize);
            }
            // every request for one pair hit the same lane...
            assert!(lanes.iter().all(|&l| l == lanes[0]), "{lanes:?}");
            // ...and it was a predict lane, never the advisor/trainer
            assert!(lanes[0] < 4, "{lanes:?}");
        }
    }

    #[test]
    fn advisor_jobs_go_to_the_advisor_lane() {
        let hits: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
        let h1 = hits.clone();
        let h2 = hits.clone();
        let pool = EnginePool::mock(
            2,
            64,
            4,
            move |idx, rx| {
                for job in rx {
                    match job {
                        Job::Shutdown => return,
                        _ => {
                            h1.lock().unwrap().push("predict");
                            let _ = idx;
                            reply_ok(job);
                        }
                    }
                }
            },
            move |rx| {
                for job in rx {
                    match job {
                        Job::Shutdown => return,
                        _ => {
                            h2.lock().unwrap().push("advisor");
                            reply_ok(job);
                        }
                    }
                }
            },
        );
        let (tx, rx) = channel();
        pool.submit(Job::Recommend {
            query: sample_query(),
            top_k: 0,
            snap: snap(),
            reply: Reply::channel(tx),
        })
        .unwrap();
        rx.recv().unwrap();
        let (tx, rx) = channel();
        pool.submit(Job::BatchSize {
            instance: Instance::P3,
            batch: 64,
            t_min: 1.0,
            t_max: 2.0,
            snap: snap(),
            reply: Reply::channel(tx),
        })
        .unwrap();
        rx.recv().unwrap();
        assert_eq!(*hits.lock().unwrap(), vec!["advisor", "predict"]);
    }

    /// Registry jobs route to the trainer lane — never to a predict lane
    /// (where they would stall batching) or the advisor lane (where a
    /// sweep backlog would delay a reload).
    #[test]
    fn registry_jobs_go_to_the_trainer_lane() {
        let pool = EnginePool::mock(2, 64, 4, echo_lane, |rx| echo_lane(7, rx));
        // the mock advisor body (idx 7) also backs the trainer lane; an
        // advisor submit and a registry submit must both land on bodies
        // with idx 7, while predicts stay on lanes 0/1
        let (tx, rx) = channel();
        pool.submit(Job::Reload {
            only_if_changed: false,
            dry_run: false,
            reply: Reply::channel(tx),
        })
        .unwrap();
        let Response::Latency { latency_ms } = rx.recv().unwrap() else {
            panic!("unexpected reply")
        };
        assert_eq!(latency_ms as usize, 7);
        let (tx, rx) = channel();
        pool.submit(Job::Onboard {
            pair: Some((Instance::G4dn, Instance::G5)),
            dry_run: false,
            reply: Reply::channel(tx),
        })
        .unwrap();
        let Response::Latency { latency_ms } = rx.recv().unwrap() else {
            panic!("unexpected reply")
        };
        assert_eq!(latency_ms as usize, 7);
        // while the trainer queue backs up, predicts are unaffected
        let (tx, rx) = channel();
        pool.submit(Job::Predict(
            predict_req(Instance::G4dn, Instance::P3),
            snap(),
            Reply::channel(tx),
        ))
        .unwrap();
        let Response::Latency { latency_ms } = rx.recv().unwrap() else {
            panic!("unexpected reply")
        };
        assert!((latency_ms as usize) < 2, "{latency_ms}");
    }

    fn reply_ok(job: Job) {
        match job {
            Job::Predict(_, _, reply)
            | Job::BatchSize { reply, .. }
            | Job::PixelSize { reply, .. }
            | Job::Recommend { reply, .. }
            | Job::Plan { reply, .. }
            | Job::Ingest { reply, .. }
            | Job::Onboard { reply, .. }
            | Job::Reload { reply, .. } => {
                reply.send(Response::Health);
            }
            Job::Shutdown => {}
        }
    }

    fn sample_query() -> SweepRequest {
        use crate::advisor::EndpointProfiles;
        SweepRequest {
            anchor: Instance::G4dn,
            pixels: 64,
            batch: EndpointProfiles {
                profile_min: BTreeMap::from([("Conv2D".to_string(), 1.0)]),
                lat_min: 5.0,
                profile_max: BTreeMap::from([("Conv2D".to_string(), 2.0)]),
                lat_max: 10.0,
            },
            pixel: None,
            targets: Vec::new(),
            batches: Vec::new(),
            pixel_sizes: Vec::new(),
            gpu_counts: Vec::new(),
            include_spot: false,
        }
    }

    #[test]
    fn sweep_on_the_advisor_lane_never_blocks_predicts() {
        // advisor lane stalls on a gate; predicts must still flow
        let (gate_tx, gate_rx) = channel::<()>();
        let gate = Arc::new(Mutex::new(Some(gate_rx)));
        let pool = EnginePool::mock(2, 64, 4, echo_lane, move |rx| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    other => {
                        // simulate a long sweep: wait for the test's gate
                        if let Some(g) = gate.lock().unwrap().take() {
                            let _ = g.recv();
                        }
                        reply_ok(other);
                    }
                }
            }
        });
        let (sweep_tx, sweep_rx) = channel();
        pool.submit(Job::Recommend {
            query: sample_query(),
            top_k: 0,
            snap: snap(),
            reply: Reply::channel(sweep_tx),
        })
        .unwrap();
        // while the "sweep" is stalled, a predict answers promptly
        let (tx, rx) = channel();
        pool.submit(Job::Predict(
            predict_req(Instance::G4dn, Instance::P3),
            snap(),
            Reply::channel(tx),
        ))
        .unwrap();
        let resp = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("predict blocked behind an in-flight sweep");
        assert!(matches!(resp, Response::Latency { .. }));
        // the sweep is still in flight the whole time
        assert!(matches!(
            sweep_rx.try_recv(),
            Err(std::sync::mpsc::TryRecvError::Empty)
        ));
        gate_tx.send(()).unwrap();
        assert!(matches!(
            sweep_rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::Health
        ));
    }

    #[test]
    fn full_lane_queue_is_overloaded_not_buffered() {
        // advisor lane blocks until gated; queue cap 2
        let (gate_tx, gate_rx) = channel::<()>();
        let (busy_tx, busy_rx) = channel::<()>();
        let gate = Arc::new(Mutex::new(Some((busy_tx, gate_rx))));
        let pool = EnginePool::mock(1, 64, 2, echo_lane, move |rx| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    other => {
                        if let Some((busy, g)) = gate.lock().unwrap().take() {
                            let _ = busy.send(()); // first job picked up
                            let _ = g.recv(); // stall
                        }
                        reply_ok(other);
                    }
                }
            }
        });
        let submit_sweep = |pool: &EnginePool| {
            let (tx, rx) = channel();
            let r = pool.submit(Job::Recommend {
                query: sample_query(),
                top_k: 0,
                snap: snap(),
                reply: Reply::channel(tx),
            });
            (r, rx)
        };
        // job 1: consumed by the lane, which then stalls
        let (r1, _rx1) = submit_sweep(&pool);
        r1.unwrap();
        busy_rx.recv().unwrap();
        // jobs 2..=3 fill the bounded queue
        let (r2, _rx2) = submit_sweep(&pool);
        r2.unwrap();
        let (r3, _rx3) = submit_sweep(&pool);
        r3.unwrap();
        // job 4 is shed, not buffered
        let (r4, _rx4) = submit_sweep(&pool);
        assert_eq!(r4, Err(SubmitError::Overloaded));
        assert_eq!(pool.stats.overloaded.load(Ordering::Relaxed), 1);
        // predict lanes are unaffected by the advisor backlog
        let (tx, rx) = channel();
        pool.submit(Job::Predict(
            predict_req(Instance::G4dn, Instance::P3),
            snap(),
            Reply::channel(tx),
        ))
        .unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
        gate_tx.send(()).unwrap();
    }

    /// The tentpole supervision contract, without a runtime: a replica
    /// panic mid-job answers that job with a structured `internal_error`
    /// (the `Reply` drop guard), counts a restart, and the respawned
    /// replica keeps serving the same queue.
    #[test]
    fn panicking_replica_answers_internal_error_and_respawns() {
        use std::sync::atomic::AtomicBool;
        let poisoned = Arc::new(AtomicBool::new(true));
        let p = poisoned.clone();
        let pool = EnginePool::mock(
            1,
            64,
            4,
            move |_idx, rx| {
                for job in rx {
                    match job {
                        Job::Shutdown => return,
                        job => {
                            // ordering: test-only one-shot panic trigger.
                            if p.swap(false, Ordering::Relaxed) {
                                panic!("injected replica panic");
                            }
                            reply_ok(job);
                        }
                    }
                }
            },
            |rx| echo_lane(99, rx),
        );
        let submit_predict = |pool: &EnginePool| {
            let (tx, rx) = channel();
            pool.submit(Job::Predict(
                predict_req(Instance::G4dn, Instance::P3),
                snap(),
                Reply::channel(tx),
            ))
            .unwrap();
            rx
        };
        // job 1 trips the panic; its reply must still arrive, structured
        let rx = submit_predict(&pool);
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Response::ErrKind { kind, .. } => assert_eq!(kind, "internal_error"),
            other => panic!("expected internal_error, got {other:?}"),
        }
        assert!(pool.stats.lane_restarts.load(Ordering::Relaxed) >= 1);
        // the respawned replica answers the next job on the same queue
        let rx = submit_predict(&pool);
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::Health
        ));
    }

    #[test]
    fn drop_flushes_queued_jobs_before_joining() {
        // every accepted job must be answered even when the pool is
        // dropped immediately after submission
        let pool = EnginePool::mock(2, 64, 4, echo_lane, |rx| echo_lane(99, rx));
        let mut rxs = Vec::new();
        for i in 0..16 {
            let (tx, rx) = channel();
            let target = if i % 2 == 0 { Instance::P3 } else { Instance::P2 };
            pool.submit(Job::Predict(predict_req(Instance::G4dn, target), snap(), Reply::channel(tx)))
                .unwrap();
            rxs.push(rx);
        }
        drop(pool); // sends Shutdown behind the queued jobs and joins
        for rx in rxs {
            assert!(
                matches!(rx.recv(), Ok(Response::Latency { .. })),
                "a queued job was dropped during shutdown"
            );
        }
    }
}
