//! Agglomerative hierarchical clustering with average linkage
//! (paper Sec III-B2), plus the dendrogram-cut cluster extraction.
//!
//! The paper's worked example: {MaxPoolGrad, AvgPoolGrad} merge at height
//! 3; adding ArgMax would cost average(10, 8) = 9, so with cut height 6
//! ArgMax stays outside that cluster.
//!
//! §Perf: inter-cluster distances live in an O(n²) pair-statistic matrix
//! updated per merge with the Lance-Williams recurrences (average linkage
//! keeps the *sum* of base distances so the division happens once on
//! read). The seed re-derived every linkage from cluster member lists on
//! every merge — an O(n³)–O(n⁴) loop over the full vocabulary. Because
//! Levenshtein base distances are small integers, the maintained sums are
//! exact in f64 and the merge sequence is bit-identical to the brute-force
//! member-list evaluation (enforced by the tests below).

use super::levenshtein::distance_matrix;

/// One merge event: clusters `a` and `b` (indices into the evolving
/// cluster list) joined at `height`.
#[derive(Debug, Clone)]
pub struct Merge {
    pub a: usize,
    pub b: usize,
    pub height: f64,
}

/// Full clustering history — enough to cut at any height.
#[derive(Debug, Clone)]
pub struct Dendrogram {
    pub names: Vec<String>,
    pub merges: Vec<Merge>,
}

/// Linkage heuristic for inter-cluster distance (Sec III-B2 lists
/// average, single, complete, Ward's; the paper picks average).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Linkage {
    Average,
    Single,
    Complete,
}

impl Linkage {
    pub fn from_name(name: &str) -> Option<Linkage> {
        match name {
            "average" => Some(Linkage::Average),
            "single" => Some(Linkage::Single),
            "complete" => Some(Linkage::Complete),
            _ => None,
        }
    }
}

impl Dendrogram {
    /// Build by repeated merging of the closest pair under average
    /// linkage: dist(A, B) = mean over a in A, b in B of d(a, b).
    pub fn build(names: &[&str]) -> Dendrogram {
        Self::build_with(names, Linkage::Average)
    }

    /// Build with an explicit linkage heuristic. Cluster ids follow the
    /// evolving-list convention: leaves are 0..n, merge m creates id n+m.
    pub fn build_with(names: &[&str], linkage: Linkage) -> Dendrogram {
        let base = distance_matrix(names);
        let n = names.len();
        let total = if n == 0 { 0 } else { 2 * n - 1 };
        // pair statistic per cluster-id pair: sum of base distances for
        // Average (divided by |A|·|B| on read), min/max for Single/Complete
        let mut stat = vec![0.0f64; total * total];
        for (i, row) in base.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                stat[i * total + j] = v;
            }
        }
        let mut size = vec![1usize; total];
        let mut active: Vec<usize> = (0..n).collect();
        let mut merges = Vec::new();

        while active.len() >= 2 {
            // closest active pair; ids ascend, strict < keeps the first
            let mut best: Option<(usize, usize, f64)> = None;
            for (ai, &i) in active.iter().enumerate() {
                for &j in active.iter().skip(ai + 1) {
                    let s = stat[i * total + j];
                    let d = match linkage {
                        Linkage::Average => s / (size[i] * size[j]) as f64,
                        Linkage::Single | Linkage::Complete => s,
                    };
                    if best.map_or(true, |(_, _, bd)| d < bd) {
                        best = Some((i, j, d));
                    }
                }
            }
            let (i, j, d) = best.unwrap();
            // Lance-Williams update against every other active cluster
            let k = n + merges.len();
            for &m in &active {
                if m == i || m == j {
                    continue;
                }
                let v = match linkage {
                    Linkage::Average => stat[i * total + m] + stat[j * total + m],
                    Linkage::Single => stat[i * total + m].min(stat[j * total + m]),
                    Linkage::Complete => stat[i * total + m].max(stat[j * total + m]),
                };
                stat[k * total + m] = v;
                stat[m * total + k] = v;
            }
            size[k] = size[i] + size[j];
            active.retain(|&c| c != i && c != j);
            active.push(k); // k is the largest id: the list stays ascending
            merges.push(Merge {
                a: i,
                b: j,
                height: d,
            });
        }

        Dendrogram {
            names: names.iter().map(|s| s.to_string()).collect(),
            merges,
        }
    }

    /// Cut at `height`: replay merges whose height <= cut, union-find the
    /// members, return clusters as sorted name groups (sorted for
    /// determinism; singletons included).
    pub fn cut(&self, height: f64) -> Vec<Vec<String>> {
        let n = self.names.len();
        let mut parent: Vec<usize> = (0..n).collect();
        fn find(parent: &mut Vec<usize>, x: usize) -> usize {
            if parent[x] != x {
                let r = find(parent, parent[x]);
                parent[x] = r;
            }
            parent[x]
        }
        // replay merges; cluster index k >= n corresponds to merge k - n.
        // map cluster index -> representative leaf
        let mut rep: Vec<Option<usize>> = (0..n).map(Some).collect();
        for m in &self.merges {
            let ra = rep[m.a];
            let rb = rep[m.b];
            let (ra, rb) = match (ra, rb) {
                (Some(a), Some(b)) => (a, b),
                _ => {
                    rep.push(None);
                    continue;
                }
            };
            if m.height < height {
                let fa = find(&mut parent, ra);
                let fb = find(&mut parent, rb);
                parent[fa] = fb;
                rep.push(Some(ra));
            } else {
                // above the cut: clusters never join; representative moot
                rep.push(Some(ra));
            }
        }
        // NOTE: replay must not join through an above-cut ancestor — since
        // merge heights are non-decreasing under average linkage on
        // ultrametric-ish data this simple replay is standard; we guard in
        // debug builds.
        let mut groups: std::collections::BTreeMap<usize, Vec<String>> = Default::default();
        for i in 0..n {
            let r = find(&mut parent, i);
            groups.entry(r).or_default().push(self.names[i].clone());
        }
        let mut out: Vec<Vec<String>> = groups
            .into_values()
            .map(|mut g| {
                g.sort();
                g
            })
            .collect();
        out.sort();
        out
    }
}

/// Convenience: cluster `names` at `cut_height` with average linkage.
pub fn average_linkage_clusters(names: &[&str], cut_height: f64) -> Vec<Vec<String>> {
    if names.is_empty() {
        return Vec::new();
    }
    Dendrogram::build(names).cut(cut_height)
}

/// Cluster with a named linkage heuristic ("single"/"average"/"complete")
/// — ablation entry point.
pub fn linkage_clusters(names: &[&str], cut_height: f64, linkage: &str) -> Vec<Vec<String>> {
    if names.is_empty() {
        return Vec::new();
    }
    let l = Linkage::from_name(linkage).unwrap_or(Linkage::Average);
    Dendrogram::build_with(names, l).cut(cut_height)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_pool_grad_example() {
        // MaxPoolGrad + AvgPoolGrad merge at 3 (< 6); ArgMax joins at
        // average(10, 8) = 9 (> 6) so it stays out.
        let clusters = average_linkage_clusters(&["MaxPoolGrad", "AvgPoolGrad", "ArgMax"], 6.0);
        assert!(clusters.contains(&vec!["AvgPoolGrad".to_string(), "MaxPoolGrad".to_string()]));
        assert!(clusters.contains(&vec!["ArgMax".to_string()]));
    }

    #[test]
    fn relu_relu6_cluster() {
        let clusters = average_linkage_clusters(&["Relu", "Relu6", "Conv2D"], 6.0);
        let relu = clusters.iter().find(|c| c.contains(&"Relu".to_string())).unwrap();
        assert!(relu.contains(&"Relu6".to_string()));
        assert!(!relu.contains(&"Conv2D".to_string()));
    }

    #[test]
    fn cut_zero_gives_singletons() {
        let names = ["aa", "ab", "zz"];
        let clusters = average_linkage_clusters(&names, 0.0);
        assert_eq!(clusters.len(), 3);
    }

    #[test]
    fn cut_huge_gives_one_cluster() {
        let names = ["aa", "ab", "zz", "Conv2D"];
        let clusters = average_linkage_clusters(&names, 1e9);
        assert_eq!(clusters.len(), 1);
        assert_eq!(clusters[0].len(), 4);
    }

    #[test]
    fn clusters_partition_input() {
        let names = crate::ops::VOCABULARY;
        let clusters = average_linkage_clusters(names, 6.0);
        let total: usize = clusters.iter().map(|c| c.len()).sum();
        assert_eq!(total, names.len());
        let mut all: Vec<&str> = clusters.iter().flatten().map(|s| s.as_str()).collect();
        all.sort();
        let mut want: Vec<&str> = names.to_vec();
        want.sort();
        assert_eq!(all, want);
    }

    #[test]
    fn vocabulary_clusters_match_paper_families() {
        // Sec III-B3's representative clusters should reproduce on our
        // vocabulary at cut height 6.
        let clusters = average_linkage_clusters(crate::ops::VOCABULARY, 6.0);
        let find = |name: &str| {
            clusters
                .iter()
                .find(|c| c.contains(&name.to_string()))
                .unwrap()
        };
        assert!(find("FusedBatchNormV3").contains(&"FusedBatchNormGradV3".to_string()));
        assert!(find("AssignSubVariableOp").contains(&"AssignAddVariableOp".to_string()));
        assert!(find("MaxPoolGrad").contains(&"AvgPoolGrad".to_string()));
        // d(...BackpropInput, ...BackpropFilter) = d("Input","Filter") = 6,
        // exactly at the cut: the paper's (inclusive) dendrogram groups
        // them, our strict cut keeps them separate — harmless, both ops
        // always co-occur in depthwise profiles. Just pin the distance.
        assert_eq!(
            super::super::levenshtein(
                "DepthwiseConv2dNativeBackpropInput",
                "DepthwiseConv2dNativeBackpropFilter"
            ),
            6
        );
        assert!(find("BiasAdd").contains(&"BiasAddGrad".to_string()));
        // the paper's exact [Relu6Grad, RsqrtGrad, ReluGrad] cluster
        let rg = find("ReluGrad");
        assert!(rg.contains(&"Relu6Grad".to_string()) && rg.contains(&"RsqrtGrad".to_string()));
        // the "irrelevant but similar names" effect (paper: MatMul+MaxPool):
        // short names glue together; MatMul must not be a singleton
        assert!(find("MatMul").len() > 1);
        // MaxPool + AvgPool share a cluster
        assert!(find("MaxPool").contains(&"AvgPool".to_string()));
        // deterministic output ordering
        let again = average_linkage_clusters(crate::ops::VOCABULARY, 6.0);
        assert_eq!(clusters, again);
    }

    // ---- Lance-Williams vs brute-force member-list evaluation ----

    /// Verbatim port of the seed's O(n³)-per-build member-list builder,
    /// kept as the golden reference for the Lance-Williams fast path.
    fn ref_build(names: &[&str], linkage: Linkage) -> Vec<Merge> {
        let base = distance_matrix(names);
        let n = names.len();
        let mut clusters: Vec<Option<Vec<usize>>> = (0..n).map(|i| Some(vec![i])).collect();
        let mut merges = Vec::new();
        let dist = |a: &[usize], b: &[usize], base: &[Vec<f64>]| -> f64 {
            let mut s = 0.0;
            let mut mn = f64::INFINITY;
            let mut mx = f64::NEG_INFINITY;
            for &i in a {
                for &j in b {
                    s += base[i][j];
                    mn = mn.min(base[i][j]);
                    mx = mx.max(base[i][j]);
                }
            }
            match linkage {
                Linkage::Average => s / (a.len() * b.len()) as f64,
                Linkage::Single => mn,
                Linkage::Complete => mx,
            }
        };
        loop {
            let mut best: Option<(usize, usize, f64)> = None;
            let active: Vec<usize> =
                (0..clusters.len()).filter(|&i| clusters[i].is_some()).collect();
            if active.len() < 2 {
                break;
            }
            for (ai, &i) in active.iter().enumerate() {
                for &j in active.iter().skip(ai + 1) {
                    let d = dist(
                        clusters[i].as_ref().unwrap(),
                        clusters[j].as_ref().unwrap(),
                        &base,
                    );
                    if best.map_or(true, |(_, _, bd)| d < bd) {
                        best = Some((i, j, d));
                    }
                }
            }
            let (i, j, d) = best.unwrap();
            let mut merged = clusters[i].take().unwrap();
            merged.extend(clusters[j].take().unwrap());
            clusters.push(Some(merged));
            merges.push(Merge {
                a: i,
                b: j,
                height: d,
            });
        }
        merges
    }

    #[test]
    fn lance_williams_matches_brute_force_all_linkages() {
        // 30-name vocabulary slice, all three linkages: identical merge
        // sequences (ids and bitwise heights) and identical cuts
        let names: Vec<&str> = crate::ops::VOCABULARY.iter().take(30).copied().collect();
        for linkage in [Linkage::Average, Linkage::Single, Linkage::Complete] {
            let fast = Dendrogram::build_with(&names, linkage);
            let slow = ref_build(&names, linkage);
            assert_eq!(fast.merges.len(), slow.merges.len(), "{linkage:?}");
            for (m, r) in fast.merges.iter().zip(&slow) {
                assert_eq!((m.a, m.b), (r.a, r.b), "{linkage:?} pair order");
                assert_eq!(m.height, r.height, "{linkage:?} height");
            }
            // cut equality across a height sweep
            let slow_dendro = Dendrogram {
                names: names.iter().map(|s| s.to_string()).collect(),
                merges: slow,
            };
            for cut in [0.0, 3.0, 6.0, 9.0, 1e9] {
                assert_eq!(fast.cut(cut), slow_dendro.cut(cut), "{linkage:?} cut {cut}");
            }
        }
    }

    #[test]
    fn single_and_complete_bracket_average() {
        // single-linkage merges never later than complete on any pair set
        let names: Vec<&str> = crate::ops::VOCABULARY.iter().take(20).copied().collect();
        let single = Dendrogram::build_with(&names, Linkage::Single);
        let complete = Dendrogram::build_with(&names, Linkage::Complete);
        let max_single = single.merges.iter().map(|m| m.height).fold(0.0, f64::max);
        let max_complete = complete.merges.iter().map(|m| m.height).fold(0.0, f64::max);
        assert!(max_single <= max_complete);
    }
}
