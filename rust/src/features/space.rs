//! FeatureSpace: maps a raw (op name → ms) profile into the fixed-width
//! clustered feature vector the predictors consume.
//!
//! Built once from the training corpus's op vocabulary. At prediction
//! time, ops unseen during training are attached to their nearest cluster
//! when within the cut distance (the generalization benefit of Sec III-B —
//! e.g. a never-seen `Relu6` lands in the `Relu` cluster); with clustering
//! disabled, unseen ops are *dropped*, which is exactly the accuracy loss
//! Fig 13a measures.

use super::levenshtein::levenshtein;
use super::{average_linkage_clusters, CUT_HEIGHT};
use crate::util::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// A fitted feature space.
#[derive(Debug, Clone)]
pub struct FeatureSpace {
    /// Cluster member lists (sorted); feature i = sum of members' times.
    clusters: Vec<Vec<String>>,
    /// op name → cluster index.
    index: BTreeMap<String, usize>,
    /// Whether name clustering is enabled (ablation switch for Fig 13).
    clustering: bool,
    /// Padded output width (the MLP artifact's D).
    width: usize,
}

impl FeatureSpace {
    /// Fit from the training vocabulary. `width` pads/validates the vector
    /// length (use `ArtifactMeta::d_feat` to match the DNN artifact).
    pub fn fit(vocabulary: &[&str], clustering: bool, width: usize) -> Result<FeatureSpace> {
        let mut names: Vec<&str> = vocabulary.to_vec();
        names.sort();
        names.dedup();
        let clusters = if clustering {
            average_linkage_clusters(&names, CUT_HEIGHT)
        } else {
            names.iter().map(|n| vec![n.to_string()]).collect()
        };
        Self::from_clusters(clusters, clustering, width)
    }

    /// Build from an explicit cluster partition (ablation sweeps over cut
    /// heights / linkage methods reuse this).
    pub fn from_clusters(
        clusters: Vec<Vec<String>>,
        clustering: bool,
        width: usize,
    ) -> Result<FeatureSpace> {
        anyhow::ensure!(
            clusters.len() <= width,
            "feature width {} < {} clusters — regenerate artifacts with a larger D_FEAT",
            width,
            clusters.len()
        );
        let mut index = BTreeMap::new();
        for (ci, members) in clusters.iter().enumerate() {
            for m in members {
                index.insert(m.clone(), ci);
            }
        }
        Ok(FeatureSpace {
            clusters,
            index,
            clustering,
            width,
        })
    }

    /// Number of live (non-padding) features.
    pub fn n_features(&self) -> usize {
        self.clusters.len()
    }

    /// Padded width.
    pub fn width(&self) -> usize {
        self.width
    }

    pub fn clustering_enabled(&self) -> bool {
        self.clustering
    }

    /// Cluster label (joined member names) for feature index `i`.
    pub fn feature_name(&self, i: usize) -> String {
        self.clusters
            .get(i)
            .map(|c| c.join("+"))
            .unwrap_or_else(|| format!("pad{i}"))
    }

    /// Map an op name to its feature slot. Unseen names go to the nearest
    /// cluster by minimum Levenshtein distance when clustering is on and
    /// the distance is within the attachment threshold; otherwise None
    /// (dropped — the accuracy loss Fig 13a measures).
    ///
    /// The attachment threshold is *relative* for long names:
    /// `max(CUT_HEIGHT, 0.45 · |op|)`. Short unseen ops behave exactly as
    /// the paper's worked example (ReLU6 → ReLU at distance 1 < 6), while
    /// long framework-generated names like
    /// `DepthwiseConv2dNativeBackpropFilter` (distance 14 from
    /// `Conv2DBackpropFilter`, but ~45% of the name length) still attach to
    /// their obvious family instead of losing their — often dominant —
    /// profiled time.
    pub fn slot_of(&self, op: &str) -> Option<usize> {
        if let Some(&i) = self.index.get(op) {
            return Some(i);
        }
        if !self.clustering {
            return None;
        }
        let mut best: Option<(usize, f64)> = None;
        for (ci, members) in self.clusters.iter().enumerate() {
            // nearest-member distance: a family is as close as its closest
            // relative (single linkage for attachment).
            let d = members
                .iter()
                .map(|m| levenshtein(op, m) as f64)
                .fold(f64::INFINITY, f64::min);
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((ci, d));
            }
        }
        let threshold = CUT_HEIGHT.max(0.5 * op.chars().count() as f64);
        match best {
            Some((ci, d)) if d < threshold => Some(ci),
            _ => None,
        }
    }

    /// Vectorize an aggregated profile into the padded feature vector
    /// (cluster members summed — the paper's sum aggregation).
    pub fn vectorize(&self, profile: &BTreeMap<String, f64>) -> Vec<f64> {
        let mut v = vec![0.0; self.width];
        for (op, ms) in profile {
            if let Some(slot) = self.slot_of(op) {
                v[slot] += *ms;
            }
        }
        v
    }

    /// JSON persistence (model registry).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "clusters",
            Json::Arr(
                self.clusters
                    .iter()
                    .map(|c| Json::Arr(c.iter().map(|s| Json::Str(s.clone())).collect()))
                    .collect(),
            ),
        );
        o.set("clustering", Json::Bool(self.clustering));
        o.set("width", Json::Num(self.width as f64));
        o
    }

    pub fn from_json(j: &Json) -> Result<FeatureSpace> {
        let clusters: Vec<Vec<String>> = j
            .req_arr("clusters")?
            .iter()
            .map(|c| {
                c.as_arr()
                    .ok_or_else(|| anyhow!("cluster not an array"))
                    .map(|ms| ms.iter().filter_map(|m| m.as_str().map(String::from)).collect())
            })
            .collect::<Result<_>>()?;
        let mut index = BTreeMap::new();
        for (ci, members) in clusters.iter().enumerate() {
            for m in members {
                index.insert(m.clone(), ci);
            }
        }
        Ok(FeatureSpace {
            index,
            clustering: j.get("clustering").and_then(Json::as_bool).unwrap_or(true),
            width: j.req_usize("width")?,
            clusters,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn vectorize_sums_cluster_members() {
        let fs = FeatureSpace::fit(&["Relu", "Relu6", "Conv2D"], true, 8).unwrap();
        let v = fs.vectorize(&profile(&[("Relu", 10.0), ("Relu6", 5.0), ("Conv2D", 100.0)]));
        assert_eq!(v.len(), 8);
        let nonzero: Vec<f64> = v.iter().copied().filter(|x| *x > 0.0).collect();
        assert_eq!(nonzero.len(), 2);
        assert!(nonzero.contains(&15.0), "Relu+Relu6 summed");
        assert!(nonzero.contains(&100.0));
    }

    #[test]
    fn unseen_op_maps_to_near_cluster_when_clustering() {
        // train WITHOUT Relu6 in the vocabulary
        let fs = FeatureSpace::fit(&["Relu", "Conv2D", "MaxPool"], true, 8).unwrap();
        let slot = fs.slot_of("Relu6").expect("Relu6 should land near Relu");
        assert_eq!(slot, fs.slot_of("Relu").unwrap());
        // a genuinely alien name is dropped
        assert!(fs.slot_of("CompletelyDifferentOperationName").is_none());
    }

    #[test]
    fn unseen_op_dropped_without_clustering() {
        let fs = FeatureSpace::fit(&["Relu", "Conv2D"], false, 8).unwrap();
        assert!(fs.slot_of("Relu6").is_none());
        let v = fs.vectorize(&profile(&[("Relu6", 5.0)]));
        assert!(v.iter().all(|x| *x == 0.0), "unseen time lost");
    }

    #[test]
    fn width_too_small_rejected() {
        assert!(FeatureSpace::fit(&["a", "bbbbbbbbbbbb", "cccccc!!!", "Conv2D"], false, 2).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let fs = FeatureSpace::fit(crate::ops::VOCABULARY, true, 48).unwrap();
        let j = fs.to_json();
        let fs2 = FeatureSpace::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(fs.n_features(), fs2.n_features());
        let p = profile(&[("Conv2D", 50.0), ("Relu", 2.0)]);
        assert_eq!(fs.vectorize(&p), fs2.vectorize(&p));
    }

    #[test]
    fn full_vocabulary_fits_artifact_width() {
        // The D_FEAT=48 the artifacts were lowered with must accommodate
        // the clustered vocabulary.
        let fs = FeatureSpace::fit(crate::ops::VOCABULARY, true, 48).unwrap();
        assert!(fs.n_features() <= 48, "{} clusters", fs.n_features());
        // and without clustering (raw ops) it must also fit
        let raw = FeatureSpace::fit(crate::ops::VOCABULARY, false, 48).unwrap();
        assert!(raw.n_features() <= 48, "{} raw ops", raw.n_features());
    }
}
