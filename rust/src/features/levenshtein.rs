//! Levenshtein (edit) distance over operation names (paper Sec III-B1).
//!
//! A CPU implementation lives here for the training pipeline and tests;
//! the serving path can also use the Pallas/HLO batched kernel through
//! [`crate::runtime::Runtime::levenshtein_strs`] (both are verified to
//! agree in the integration tests).
//!
//! §Perf: op names are almost always ASCII, so the hot path runs directly
//! over byte slices (no per-call `Vec<char>` materialization), and
//! [`distance_matrix`] reuses one DP row allocation across all D² pairs
//! (the seed allocated two vectors per pair).

/// Classic two-row Wagner-Fischer, O(|a|·|b|) time, O(|b|) space.
pub fn levenshtein(a: &str, b: &str) -> usize {
    let mut row = Vec::new();
    levenshtein_with(a, b, &mut row)
}

/// Wagner-Fischer with a caller-owned, reusable DP row buffer.
fn levenshtein_with(a: &str, b: &str, row: &mut Vec<usize>) -> usize {
    if a.is_ascii() && b.is_ascii() {
        lev_core(a.as_bytes(), b.as_bytes(), row)
    } else {
        let ac: Vec<char> = a.chars().collect();
        let bc: Vec<char> = b.chars().collect();
        lev_core(&ac, &bc, row)
    }
}

/// Element-generic DP core shared by the ASCII byte fast path and the
/// Unicode char fallback.
fn lev_core<T: PartialEq>(a: &[T], b: &[T], row: &mut Vec<usize>) -> usize {
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    row.clear();
    row.extend(0..=b.len());
    for (i, ca) in a.iter().enumerate() {
        let mut prev = row[0]; // row[i-1][0]
        row[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev + usize::from(ca != cb);
            prev = row[j + 1];
            row[j + 1] = sub.min(prev + 1).min(row[j] + 1);
        }
    }
    row[b.len()]
}

/// Symmetric D x D distance matrix over `names` (paper: "Calculating the
/// Levenshtein distance among all pairs of D features results in a D x D
/// distance matrix"). One DP row buffer serves every pair.
pub fn distance_matrix(names: &[&str]) -> Vec<Vec<f64>> {
    let d = names.len();
    let mut m = vec![vec![0.0; d]; d];
    let mut row = Vec::new();
    for i in 0..d {
        for j in (i + 1)..d {
            let dist = levenshtein_with(names[i], names[j], &mut row) as f64;
            m[i][j] = dist;
            m[j][i] = dist;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worked_examples() {
        // Sec III-B1: d(ReLU, ReLU6) = 1; d(ReLU, Conv2D) = 6.
        assert_eq!(levenshtein("ReLU", "ReLU6"), 1);
        assert_eq!(levenshtein("ReLU", "Conv2D"), 6);
        // Sec III-B2: d(MaxPoolGrad, AvgPoolGrad) = 3.
        assert_eq!(levenshtein("MaxPoolGrad", "AvgPoolGrad"), 3);
    }

    #[test]
    fn edge_cases() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("same", "same"), 0);
    }

    #[test]
    fn unicode_falls_back_to_char_path() {
        // non-ASCII names count scalar values, not bytes
        assert_eq!(levenshtein("naïve", "naive"), 1);
        assert_eq!(levenshtein("λReLU", "ReLU"), 1);
        assert_eq!(levenshtein("é", ""), 1);
        // mixed ASCII/Unicode pair also takes the char path
        assert_eq!(levenshtein("Conv2D", "Cönv2D"), 1);
    }

    #[test]
    fn ascii_fast_path_matches_char_reference() {
        // the byte fast path must agree with a char-by-char reference
        let mut rng = crate::util::Rng64::new(123);
        let alphabet: Vec<char> = "abcdXY26GradPool".chars().collect();
        let mut rand_name = |rng: &mut crate::util::Rng64| {
            let n = rng.below(14);
            (0..n).map(|_| alphabet[rng.below(alphabet.len())]).collect::<String>()
        };
        let mut row = Vec::new();
        for _ in 0..300 {
            let x = rand_name(&mut rng);
            let y = rand_name(&mut rng);
            let xc: Vec<char> = x.chars().collect();
            let yc: Vec<char> = y.chars().collect();
            let via_chars = lev_core(&xc, &yc, &mut row);
            assert_eq!(levenshtein(&x, &y), via_chars, "{x} vs {y}");
        }
    }

    #[test]
    fn symmetry_and_triangle_property() {
        // hand-rolled property test over pseudo-random op-like strings
        let mut rng = crate::util::Rng64::new(99);
        let alphabet: Vec<char> = "abcdXY26GradPool".chars().collect();
        let mut rand_name = |rng: &mut crate::util::Rng64| {
            let n = rng.below(12);
            (0..n).map(|_| alphabet[rng.below(alphabet.len())]).collect::<String>()
        };
        for _ in 0..200 {
            let x = rand_name(&mut rng);
            let y = rand_name(&mut rng);
            let z = rand_name(&mut rng);
            let dxy = levenshtein(&x, &y);
            let dyx = levenshtein(&y, &x);
            assert_eq!(dxy, dyx, "symmetry {x} {y}");
            let dyz = levenshtein(&y, &z);
            let dxz = levenshtein(&x, &z);
            assert!(dxz <= dxy + dyz, "triangle {x} {y} {z}");
            // identity of indiscernibles
            assert_eq!(levenshtein(&x, &x), 0);
            // length lower bound
            assert!(dxy >= x.chars().count().abs_diff(y.chars().count()));
        }
    }

    #[test]
    fn matrix_symmetric_zero_diagonal() {
        let names = ["Relu", "Relu6", "Conv2D", "MatMul"];
        let m = distance_matrix(&names);
        for i in 0..4 {
            assert_eq!(m[i][i], 0.0);
            for j in 0..4 {
                assert_eq!(m[i][j], m[j][i]);
            }
        }
        assert_eq!(m[0][1], 1.0);
    }
}
