//! Feature engineering: operation-name clustering (paper Sec III-B) and
//! profile → fixed-width feature-vector alignment.
//!
//! Pipeline (Fig 5): Levenshtein distance matrix over the op-name
//! vocabulary → agglomerative clustering with *average* linkage → cut the
//! dendrogram at height [`CUT_HEIGHT`] (= 6, the paper's empirically best
//! value) → aggregate each cluster's profiled times by *sum*.

mod cluster;
mod levenshtein;
mod space;

pub use cluster::{average_linkage_clusters, linkage_clusters, Dendrogram, Linkage};
pub use levenshtein::{distance_matrix, levenshtein};
pub use space::FeatureSpace;

/// The paper's dendrogram cut height (Sec III-B3).
pub const CUT_HEIGHT: f64 = 6.0;
