//! # repro — PROFET reproduction
//!
//! Production-quality reproduction of *PROFET: Profiling-based CNN Training
//! Latency Prophet for GPU Cloud Instances* (Lee et al., cs.DC 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the full PROFET system: GPU training simulator
//!   substrate, TF-profiler emulation, operation-name clustering, classical
//!   ML (OLS / random forest), the median ensemble, batch/pixel polynomial
//!   models, baselines (Paleo, MLPredict, Habitat), the evaluation harness
//!   for every table/figure in the paper, and a TCP/JSON prediction
//!   service ([`coordinator`]) with a readiness-polled connection reactor,
//!   an engine replica pool, a zero-allocation wire path, a live,
//!   hot-swappable model registry ([`coordinator::registry`]) for online
//!   GPU onboarding, an open-loop load generator ([`loadgen`]) for
//!   tail-latency benchmarking, and a per-stage latency observatory
//!   ([`obs`]) behind the `metrics` wire op.
//! * **L2/L1 (python/, build time only)** — the DNN ensemble member
//!   (128·64·32·16·1 MLP) and the batched Levenshtein kernel, written in
//!   JAX/Pallas and AOT-lowered to HLO text artifacts executed here via the
//!   PJRT CPU client ([`runtime`]). Python is never on the request path.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index,
//! `docs/ARCHITECTURE.md` for the serving dataflow narrative, and
//! `docs/PROTOCOL.md` for the wire reference.

pub mod advisor;
pub mod analysis;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod dnn;
pub mod evalx;
pub mod features;
pub mod gpu;
pub mod loadgen;
pub mod ml;
pub mod models;
pub mod obs;
pub mod ops;
pub mod predictor;
pub mod profiler;
pub mod runtime;
pub mod sim;
pub mod util;

pub use anyhow::Result;
