//! GPU cloud instance specifications (paper Table I + the new-GPU study).
//!
//! These are the *inputs to the simulator substrate*, not features of the
//! PROFET predictor — PROFET is deliberately hardware-spec-free (Sec III-C3).
//! Specs follow the paper's Table I where given and public datasheets for
//! the fields the paper omits (memory bandwidth, VRAM, tensor cores).

use std::fmt;

/// Cloud instance families used in the paper.
///
/// `G3s..P3` are the four training/anchor instances; `G5` (A10) and `Ac1`
/// (P100, IBM) appear only as *new* target devices in Table VI.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Instance {
    /// AWS g3s.xlarge — NVIDIA Tesla M60 (Maxwell).
    G3s,
    /// AWS g4dn.xlarge — NVIDIA T4 (Turing, tensor cores).
    G4dn,
    /// AWS p2.xlarge — NVIDIA K80 (Kepler).
    P2,
    /// AWS p3.2xlarge — NVIDIA V100 (Volta, tensor cores).
    P3,
    /// AWS g5.xlarge — NVIDIA A10G (Ampere, tensor cores). Table VI only.
    G5,
    /// IBM AC1 — NVIDIA P100 (Pascal). Table VI only.
    Ac1,
}

impl Instance {
    /// The paper's four anchor/training instances (Sec III).
    pub const CORE: [Instance; 4] = [Instance::G3s, Instance::G4dn, Instance::P2, Instance::P3];

    /// The Table VI "new GPU" targets.
    pub const NEW: [Instance; 2] = [Instance::G5, Instance::Ac1];

    /// All six instances.
    pub const ALL: [Instance; 6] = [
        Instance::G3s,
        Instance::G4dn,
        Instance::P2,
        Instance::P3,
        Instance::G5,
        Instance::Ac1,
    ];

    pub fn key(self) -> &'static str {
        match self {
            Instance::G3s => "g3s",
            Instance::G4dn => "g4dn",
            Instance::P2 => "p2",
            Instance::P3 => "p3",
            Instance::G5 => "g5",
            Instance::Ac1 => "ac1",
        }
    }

    pub fn from_key(key: &str) -> Option<Instance> {
        Instance::ALL.into_iter().find(|i| i.key() == key)
    }

    pub fn spec(self) -> &'static GpuSpec {
        spec_of(self)
    }
}

impl fmt::Display for Instance {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.key())
    }
}

/// Hardware description of one GPU cloud instance.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub instance: Instance,
    /// e.g. "M60".
    pub gpu_model: &'static str,
    /// CUDA core count (Table I).
    pub cores: u32,
    /// Boost clock, MHz (Table I).
    pub clock_mhz: u32,
    /// Peak FP32 throughput, TFLOPS (Table I).
    pub tflops_fp32: f64,
    /// Device memory, GiB (per visible GPU).
    pub vram_gib: f64,
    /// Device memory bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Host<->device (PCIe) bandwidth, GB/s.
    pub pcie_gbs: f64,
    /// Has tensor cores usable by cuDNN fp32/TF32-style paths.
    pub tensor_cores: bool,
    /// On-demand price, $/hr (Table I; G5/AC1 from public pricing).
    pub price_hr: f64,
    /// Per-kernel launch + driver overhead, microseconds. Older
    /// architectures and older host CPUs pay more (this is the term that
    /// makes tiny models fastest on g4dn rather than p3 — Fig 2a).
    pub launch_overhead_us: f64,
    /// Host-side framework overhead per op, microseconds (python/TF
    /// dispatch on the instance's vCPU).
    pub framework_overhead_us: f64,
    /// Saturation constant: number of concurrently resident work elements
    /// needed to reach ~50% utilization. Scales with core count, so wide
    /// devices (V100) need large batches to saturate — the Fig 2c effect.
    pub saturation_elems: f64,
    /// Hardware release year (Table I).
    pub released: u32,
}

static G3S: GpuSpec = GpuSpec {
    instance: Instance::G3s,
    gpu_model: "M60",
    cores: 2048,
    clock_mhz: 1178,
    tflops_fp32: 4.825,
    vram_gib: 8.0,
    mem_bw_gbs: 160.0,
    pcie_gbs: 10.0,
    tensor_cores: false,
    price_hr: 0.75,
    launch_overhead_us: 8.0,
    framework_overhead_us: 55.0,
    saturation_elems: 2048.0 * 192.0,
    released: 2017,
};

static G4DN: GpuSpec = GpuSpec {
    instance: Instance::G4dn,
    gpu_model: "T4",
    cores: 2560,
    clock_mhz: 1590,
    tflops_fp32: 8.141,
    vram_gib: 16.0,
    mem_bw_gbs: 320.0,
    pcie_gbs: 12.0,
    tensor_cores: true,
    price_hr: 0.526,
    launch_overhead_us: 5.0,
    framework_overhead_us: 38.0,
    saturation_elems: 2560.0 * 192.0,
    released: 2019,
};

static P2: GpuSpec = GpuSpec {
    instance: Instance::P2,
    gpu_model: "K80",
    cores: 2496,
    clock_mhz: 875,
    tflops_fp32: 4.113,
    vram_gib: 12.0,
    mem_bw_gbs: 240.0,
    pcie_gbs: 8.0,
    tensor_cores: false,
    price_hr: 0.9,
    launch_overhead_us: 12.0,
    framework_overhead_us: 85.0,
    saturation_elems: 2496.0 * 160.0,
    released: 2016,
};

static P3: GpuSpec = GpuSpec {
    instance: Instance::P3,
    gpu_model: "V100",
    cores: 5120,
    clock_mhz: 1380,
    tflops_fp32: 14.13,
    vram_gib: 16.0,
    mem_bw_gbs: 900.0,
    pcie_gbs: 12.0,
    tensor_cores: true,
    price_hr: 3.06,
    launch_overhead_us: 5.0,
    framework_overhead_us: 40.0,
    saturation_elems: 5120.0 * 256.0,
    released: 2017,
};

static G5: GpuSpec = GpuSpec {
    instance: Instance::G5,
    gpu_model: "A10",
    cores: 9216,
    clock_mhz: 1695,
    tflops_fp32: 31.2,
    vram_gib: 24.0,
    mem_bw_gbs: 600.0,
    pcie_gbs: 16.0,
    tensor_cores: true,
    price_hr: 1.006,
    launch_overhead_us: 4.0,
    framework_overhead_us: 33.0,
    saturation_elems: 9216.0 * 256.0,
    released: 2021,
};

static AC1: GpuSpec = GpuSpec {
    instance: Instance::Ac1,
    gpu_model: "P100",
    cores: 3584,
    clock_mhz: 1303,
    tflops_fp32: 9.3,
    vram_gib: 16.0,
    mem_bw_gbs: 732.0,
    pcie_gbs: 10.0,
    tensor_cores: false,
    price_hr: 2.0,
    launch_overhead_us: 7.0,
    framework_overhead_us: 50.0,
    saturation_elems: 3584.0 * 192.0,
    released: 2016,
};

/// Static spec lookup.
pub fn spec_of(instance: Instance) -> &'static GpuSpec {
    match instance {
        Instance::G3s => &G3S,
        Instance::G4dn => &G4DN,
        Instance::P2 => &P2,
        Instance::P3 => &P3,
        Instance::G5 => &G5,
        Instance::Ac1 => &AC1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        // Exactly the paper's Table I numbers.
        assert_eq!(Instance::G3s.spec().tflops_fp32, 4.825);
        assert_eq!(Instance::G4dn.spec().tflops_fp32, 8.141);
        assert_eq!(Instance::P2.spec().tflops_fp32, 4.113);
        assert_eq!(Instance::P3.spec().tflops_fp32, 14.13);
        assert_eq!(Instance::P3.spec().cores, 5120);
        assert_eq!(Instance::P2.spec().price_hr, 0.9);
    }

    #[test]
    fn keys_roundtrip() {
        for i in Instance::ALL {
            assert_eq!(Instance::from_key(i.key()), Some(i));
        }
        assert_eq!(Instance::from_key("nope"), None);
    }

    #[test]
    fn spec_sanity() {
        for i in Instance::ALL {
            let s = i.spec();
            assert!(s.tflops_fp32 > 1.0 && s.tflops_fp32 < 50.0);
            assert!(s.mem_bw_gbs > 100.0);
            assert!(s.vram_gib >= 8.0);
            assert!(s.price_hr > 0.0);
            assert!(s.saturation_elems > 0.0);
        }
    }

    #[test]
    fn tensor_core_devices() {
        assert!(!Instance::G3s.spec().tensor_cores);
        assert!(Instance::G4dn.spec().tensor_cores);
        assert!(!Instance::P2.spec().tensor_cores);
        assert!(Instance::P3.spec().tensor_cores);
        assert!(Instance::G5.spec().tensor_cores);
        assert!(!Instance::Ac1.spec().tensor_cores);
    }
}
