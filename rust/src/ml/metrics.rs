//! Regression metrics (paper Sec V): MAPE, RMSE, R².

/// Mean Absolute Percentage Error, in percent (paper reports e.g. 11.4159).
pub fn mape(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    if truth.is_empty() {
        return 0.0;
    }
    let s: f64 = truth
        .iter()
        .zip(pred)
        .map(|(t, p)| ((p - t) / t.max(1e-9)).abs())
        .sum();
    100.0 * s / truth.len() as f64
}

/// Root Mean Squared Error.
pub fn rmse(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    if truth.is_empty() {
        return 0.0;
    }
    let s: f64 = truth.iter().zip(pred).map(|(t, p)| (p - t) * (p - t)).sum();
    (s / truth.len() as f64).sqrt()
}

/// Coefficient of determination. Can be negative for terrible models
/// (Table II's joint DNN scores -0.0765).
pub fn r2(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    if truth.len() < 2 {
        return 0.0;
    }
    let mean = truth.iter().sum::<f64>() / truth.len() as f64;
    let ss_res: f64 = truth.iter().zip(pred).map(|(t, p)| (t - p) * (t - p)).sum();
    let ss_tot: f64 = truth.iter().map(|t| (t - mean) * (t - mean)).sum();
    if ss_tot == 0.0 {
        return 0.0;
    }
    1.0 - ss_res / ss_tot
}

/// All three at once.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scores {
    pub mape: f64,
    pub rmse: f64,
    pub r2: f64,
}

pub fn scores(truth: &[f64], pred: &[f64]) -> Scores {
    Scores {
        mape: mape(truth, pred),
        rmse: rmse(truth, pred),
        r2: r2(truth, pred),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction() {
        let t = [1.0, 2.0, 3.0];
        assert_eq!(mape(&t, &t), 0.0);
        assert_eq!(rmse(&t, &t), 0.0);
        assert_eq!(r2(&t, &t), 1.0);
    }

    #[test]
    fn known_values() {
        let t = [100.0, 200.0];
        let p = [110.0, 180.0];
        assert!((mape(&t, &p) - 10.0).abs() < 1e-9); // (10% + 10%) / 2
        assert!((rmse(&t, &p) - (250.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn r2_negative_for_bad_model() {
        let t = [1.0, 2.0, 3.0, 4.0];
        let p = [4.0, 3.0, 2.0, 1.0];
        assert!(r2(&t, &p) < 0.0);
    }

    #[test]
    fn constant_truth_r2_zero() {
        assert_eq!(r2(&[2.0, 2.0], &[1.0, 3.0]), 0.0);
    }
}
