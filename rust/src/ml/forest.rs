//! CART regression trees + bootstrap-aggregated random forest, from
//! scratch (the paper uses sklearn's RandomForestRegressor with default
//! hyper-parameters: 100 trees, unlimited depth, min_samples_split=2,
//! bootstrap sampling, all features considered per split).
//!
//! §Perf: the grower is *presorted* — every feature column is sorted once
//! per tree at the root, and the per-feature sorted index lanes are
//! maintained through node partitions by a stable sweep. The seed
//! implementation re-sorted every candidate feature at every node
//! (O(d · m log m) per node); the presorted sweep is O(d · m), which
//! dominates the ≥3x fit speedup on the `hot_paths` bench. Trees are laid
//! out struct-of-arrays (parallel `feature`/`value`/`left`/`right` lanes
//! with a leaf sentinel) instead of an enum graph, so batched prediction
//! keeps one tree's arrays cache-hot across all rows.
//!
//! The grower consumes the RNG stream (bootstrap draws, per-node
//! Fisher-Yates feature order) and evaluates the split criterion in
//! exactly the seed implementation's order, so fixed-seed forests are
//! bit-identical to the old per-node-sorting grower (enforced by the
//! `presorted_grower_matches_seed_reference*` tests below). Exact scope of
//! that claim: the only ordering difference vs the seed is *inside* groups
//! of equal feature values (the seed's per-node unstable sort ordered ties
//! arbitrarily; the presorted lanes order them by bootstrap position), so
//! prefix sums over a tie group may differ in the last ulp when tied rows
//! carry different non-integer targets. Equality is exact when ties come
//! only from bootstrap duplication (continuous features) or when targets
//! sum exactly in f64 (integer-ish data) — both tested; for other data the
//! split criterion is identical and any divergence needs a split score
//! race decided inside one ulp.

use crate::ml::FeatureMatrix;
use crate::util::{Json, Rng64};
use anyhow::{anyhow, Result};

/// Leaf sentinel in the SoA `feature` lane.
const LEAF: u32 = u32::MAX;

/// Flat struct-of-arrays binary regression tree. Node `i` is a split iff
/// `feature[i] != LEAF`; `value[i]` holds the split threshold for splits
/// and the prediction for leaves; `left`/`right` are child indices
/// (unused, 0, for leaves).
#[derive(Debug, Clone)]
pub struct DecisionTree {
    feature: Vec<u32>,
    value: Vec<f64>,
    left: Vec<u32>,
    right: Vec<u32>,
}

/// Tree-growing hyper-parameters (sklearn defaults).
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Features tried per split as a fraction of D (1.0 = all, sklearn's
    /// regression default).
    pub max_features_frac: f64,
}

impl Default for TreeParams {
    fn default() -> Self {
        Self {
            max_depth: 32,
            min_samples_split: 2,
            max_features_frac: 1.0,
        }
    }
}

/// Per-thread reusable growing workspace: per-feature sorted lanes, the
/// bootstrap-order lane, partition scratch, and the SoA output under
/// construction. One `Grower` serves every tree a worker fits.
struct Grower<'a> {
    x: &'a FeatureMatrix,
    y: &'a [f64],
    params: TreeParams,
    n: usize,
    d: usize,
    /// bootstrap draw: position p in the sample -> row id in `x`
    boot_row: Vec<u32>,
    /// target per position: y[boot_row[p]]
    y_boot: Vec<f64>,
    /// d lanes of n positions each, lane f sorted by feature-f value
    ord: Vec<u32>,
    /// feature values aligned with `ord`
    val: Vec<f64>,
    /// positions in the seed grower's bootstrap order (Hoare-partitioned
    /// at each split so node statistics accumulate in the seed's order)
    node_pos: Vec<u32>,
    goes_left: Vec<bool>,
    tmp_ord: Vec<u32>,
    tmp_val: Vec<f64>,
    pairs: Vec<(f64, u32)>,
    feats: Vec<usize>,
    out_feature: Vec<u32>,
    out_value: Vec<f64>,
    out_left: Vec<u32>,
    out_right: Vec<u32>,
}

impl<'a> Grower<'a> {
    fn new(x: &'a FeatureMatrix, y: &'a [f64], params: TreeParams) -> Grower<'a> {
        let n = x.n_rows();
        let d = x.n_cols();
        Grower {
            x,
            y,
            params,
            n,
            d,
            boot_row: vec![0; n],
            y_boot: vec![0.0; n],
            ord: vec![0; d * n],
            val: vec![0.0; d * n],
            node_pos: Vec::with_capacity(n),
            goes_left: vec![false; n],
            tmp_ord: vec![0; n],
            tmp_val: vec![0.0; n],
            pairs: Vec::with_capacity(n),
            feats: Vec::with_capacity(d),
            out_feature: Vec::new(),
            out_value: Vec::new(),
            out_left: Vec::new(),
            out_right: Vec::new(),
        }
    }

    /// Draw the bootstrap sample exactly as the seed implementation did
    /// (same RNG consumption order -> identical forests for a fixed seed).
    fn bootstrap(&mut self, rng: &mut Rng64) {
        let n = self.n;
        for p in 0..n {
            self.boot_row[p] = rng.below(n) as u32;
        }
    }

    /// Use every row once, unsampled (single-tree / test path).
    fn identity_sample(&mut self) {
        for p in 0..self.n {
            self.boot_row[p] = p as u32;
        }
    }

    /// Presort every feature lane once, then grow recursively.
    fn fit_tree(&mut self, rng: &mut Rng64) -> DecisionTree {
        let n = self.n;
        for p in 0..n {
            self.y_boot[p] = self.y[self.boot_row[p] as usize];
        }
        for f in 0..self.d {
            let xcol = self.x.col(f);
            {
                let pairs = &mut self.pairs;
                let boot = &self.boot_row;
                pairs.clear();
                pairs.extend((0..n).map(|p| (xcol[boot[p] as usize], p as u32)));
                // ties break by bootstrap position so the stable partition
                // below keeps a well-defined order
                pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            }
            let lane = f * n;
            for (k, &(v, p)) in self.pairs.iter().enumerate() {
                self.val[lane + k] = v;
                self.ord[lane + k] = p;
            }
        }
        self.node_pos.clear();
        self.node_pos.extend(0..n as u32);
        self.out_feature.clear();
        self.out_value.clear();
        self.out_left.clear();
        self.out_right.clear();
        self.grow(0, n, 0, rng);
        DecisionTree {
            feature: std::mem::take(&mut self.out_feature),
            value: std::mem::take(&mut self.out_value),
            left: std::mem::take(&mut self.out_left),
            right: std::mem::take(&mut self.out_right),
        }
    }

    fn push_leaf(&mut self, v: f64) -> usize {
        self.out_feature.push(LEAF);
        self.out_value.push(v);
        self.out_left.push(0);
        self.out_right.push(0);
        self.out_feature.len() - 1
    }

    /// Grow the subtree over position range [lo, hi); returns its node id.
    fn grow(&mut self, lo: usize, hi: usize, depth: usize, rng: &mut Rng64) -> usize {
        let len = hi - lo;
        let mut sum = 0.0;
        for k in lo..hi {
            sum += self.y_boot[self.node_pos[k] as usize];
        }
        let mean = sum / len as f64;
        let mut sse = 0.0;
        for k in lo..hi {
            let dv = self.y_boot[self.node_pos[k] as usize] - mean;
            sse += dv * dv;
        }
        if depth >= self.params.max_depth || len < self.params.min_samples_split || sse < 1e-12 {
            return self.push_leaf(mean);
        }

        let d = self.d;
        let n_try = ((d as f64 * self.params.max_features_frac).ceil() as usize).clamp(1, d);
        // sample features without replacement (Fisher-Yates prefix)
        self.feats.clear();
        self.feats.extend(0..d);
        for i in 0..n_try {
            let j = i + rng.below(d - i);
            self.feats.swap(i, j);
        }

        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, score)
        for fi in 0..n_try {
            let f = self.feats[fi];
            let lane = f * self.n;
            // totals accumulate in sorted order, matching the seed's
            // post-sort summation
            let mut total_sum = 0.0;
            let mut total_sq = 0.0;
            for k in lo..hi {
                let yv = self.y_boot[self.ord[lane + k] as usize];
                total_sum += yv;
                total_sq += yv * yv;
            }
            // prefix sums for the O(m) best-split scan over the presorted lane
            let mut lsum = 0.0;
            let mut lsq = 0.0;
            for k in 0..len - 1 {
                let yv = self.y_boot[self.ord[lane + lo + k] as usize];
                lsum += yv;
                lsq += yv * yv;
                let v0 = self.val[lane + lo + k];
                let v1 = self.val[lane + lo + k + 1];
                if v0 == v1 {
                    continue; // can't split between equal values
                }
                let nl = (k + 1) as f64;
                let nr = (len - k - 1) as f64;
                let rsum = total_sum - lsum;
                let rsq = total_sq - lsq;
                // total child SSE
                let score = (lsq - lsum * lsum / nl) + (rsq - rsum * rsum / nr);
                if best.map_or(true, |(_, _, bs)| score < bs) {
                    best = Some((f, 0.5 * (v0 + v1), score));
                }
            }
        }

        let Some((feature, threshold, score)) = best else {
            return self.push_leaf(mean);
        };
        if score >= sse {
            return self.push_leaf(mean);
        }

        // partition the bootstrap-order lane with the seed's Hoare sweep
        // (keeps per-node statistic accumulation order identical)
        let x = self.x;
        let xcol = x.col(feature);
        let mid = {
            let mut i = lo;
            let mut h = hi;
            while i < h {
                let p = self.node_pos[i] as usize;
                if xcol[self.boot_row[p] as usize] <= threshold {
                    i += 1;
                } else {
                    h -= 1;
                    self.node_pos.swap(i, h);
                }
            }
            i - lo
        };
        if mid == 0 || mid == len {
            return self.push_leaf(mean);
        }

        // membership mask from the split feature's sorted lane
        {
            let lane = feature * self.n;
            for k in lo..hi {
                let p = self.ord[lane + k] as usize;
                self.goes_left[p] = self.val[lane + k] <= threshold;
            }
        }
        // stable partition of every lane: each side stays sorted, no re-sort
        for f in 0..d {
            let lane = f * self.n;
            let mut w = lo;
            let mut t = 0;
            for k in lo..hi {
                let p = self.ord[lane + k];
                let v = self.val[lane + k];
                if self.goes_left[p as usize] {
                    self.ord[lane + w] = p;
                    self.val[lane + w] = v;
                    w += 1;
                } else {
                    self.tmp_ord[t] = p;
                    self.tmp_val[t] = v;
                    t += 1;
                }
            }
            self.ord[lane + w..lane + hi].copy_from_slice(&self.tmp_ord[..t]);
            self.val[lane + w..lane + hi].copy_from_slice(&self.tmp_val[..t]);
            debug_assert_eq!(w - lo, mid);
        }

        let slot = self.push_leaf(0.0); // placeholder, patched below
        let l = self.grow(lo, lo + mid, depth + 1, rng);
        let r = self.grow(lo + mid, hi, depth + 1, rng);
        self.out_feature[slot] = feature as u32;
        self.out_value[slot] = threshold;
        self.out_left[slot] = l as u32;
        self.out_right[slot] = r as u32;
        slot
    }
}

impl DecisionTree {
    /// Fit a single tree on every row of `x` (no bootstrap resampling).
    pub fn fit_full(
        x: &FeatureMatrix,
        y: &[f64],
        params: &TreeParams,
        rng: &mut Rng64,
    ) -> DecisionTree {
        assert!(!x.is_empty() && x.n_rows() == y.len(), "bad shapes");
        let mut g = Grower::new(x, y, *params);
        g.identity_sample();
        g.fit_tree(rng)
    }

    pub fn predict_one(&self, x: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            let f = self.feature[i];
            if f == LEAF {
                return self.value[i];
            }
            i = if x[f as usize] <= self.value[i] {
                self.left[i] as usize
            } else {
                self.right[i] as usize
            };
        }
    }

    /// Predict every row of the columnar matrix into `out`, keeping this
    /// tree's SoA lanes hot across the whole batch.
    pub fn predict_into(&self, x: &FeatureMatrix, out: &mut [f64]) {
        debug_assert_eq!(out.len(), x.n_rows());
        for (r, slot) in out.iter_mut().enumerate() {
            let mut i = 0usize;
            loop {
                let f = self.feature[i];
                if f == LEAF {
                    *slot = self.value[i];
                    break;
                }
                i = if x.get(r, f as usize) <= self.value[i] {
                    self.left[i] as usize
                } else {
                    self.right[i] as usize
                };
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }

    fn to_json(&self) -> Json {
        Json::Arr(
            (0..self.feature.len())
                .map(|i| {
                    if self.feature[i] == LEAF {
                        Json::from_f64s(&[self.value[i]])
                    } else {
                        Json::from_f64s(&[
                            self.feature[i] as f64,
                            self.value[i],
                            self.left[i] as f64,
                            self.right[i] as f64,
                        ])
                    }
                })
                .collect(),
        )
    }

    fn from_json(j: &Json) -> Result<DecisionTree> {
        let arr = j.as_arr().ok_or_else(|| anyhow!("tree not array"))?;
        anyhow::ensure!(!arr.is_empty(), "empty tree");
        let mut feature = Vec::with_capacity(arr.len());
        let mut value = Vec::with_capacity(arr.len());
        let mut left = Vec::with_capacity(arr.len());
        let mut right = Vec::with_capacity(arr.len());
        for node in arr {
            let v = node.to_f64s()?;
            match v.len() {
                1 => {
                    feature.push(LEAF);
                    value.push(v[0]);
                    left.push(0);
                    right.push(0);
                }
                4 => {
                    feature.push(v[0] as u32);
                    value.push(v[1]);
                    left.push(v[2] as u32);
                    right.push(v[3] as u32);
                }
                _ => anyhow::bail!("bad node arity"),
            }
        }
        let nn = feature.len() as u32;
        for i in 0..feature.len() {
            if feature[i] != LEAF {
                anyhow::ensure!(left[i] < nn && right[i] < nn, "child index out of range");
            }
        }
        Ok(DecisionTree {
            feature,
            value,
            left,
            right,
        })
    }
}

/// Bagged forest of CART trees.
#[derive(Debug, Clone)]
pub struct RandomForest {
    pub trees: Vec<DecisionTree>,
}

impl RandomForest {
    /// Fit `n_trees` trees on bootstrap samples. Deterministic via `seed`
    /// (each tree's RNG depends only on `seed` and its index, so the
    /// thread-parallel fit below produces bit-identical forests to a
    /// sequential one). A panicking worker surfaces as an `Err` instead of
    /// poisoning the caller.
    pub fn fit(x: &FeatureMatrix, y: &[f64], n_trees: usize, seed: u64) -> Result<RandomForest> {
        anyhow::ensure!(!x.is_empty() && x.n_rows() == y.len(), "bad shapes");
        anyhow::ensure!(x.n_cols() > 0, "no feature columns");
        anyhow::ensure!(n_trees > 0, "n_trees must be positive");
        let params = TreeParams::default();
        let tree_seed = |t: usize| -> u64 {
            (seed.wrapping_add(1)).wrapping_mul(0x9e3779b97f4a7c15)
                ^ (t as u64 + 1).wrapping_mul(0xd1342543de82ef95)
        };
        // §Perf: trees are independent, so fan out across cores via scoped
        // threads with a striped work split; each worker reuses one Grower
        // (sorted lanes + partition scratch) across all its trees.
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(n_trees)
            .max(1);
        let trees: Vec<DecisionTree> = if workers <= 1 || n_trees < 8 {
            let mut g = Grower::new(x, y, params);
            (0..n_trees)
                .map(|t| {
                    let mut rng = Rng64::new(tree_seed(t));
                    g.bootstrap(&mut rng);
                    g.fit_tree(&mut rng)
                })
                .collect()
        } else {
            let mut slots: Vec<Option<DecisionTree>> = (0..n_trees).map(|_| None).collect();
            let mut worker_err: Option<anyhow::Error> = None;
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(workers);
                for w in 0..workers {
                    let tree_seed = &tree_seed;
                    handles.push(scope.spawn(move || {
                        let mut g = Grower::new(x, y, params);
                        (w..n_trees)
                            .step_by(workers)
                            .map(|t| {
                                let mut rng = Rng64::new(tree_seed(t));
                                g.bootstrap(&mut rng);
                                (t, g.fit_tree(&mut rng))
                            })
                            .collect::<Vec<_>>()
                    }));
                }
                for h in handles {
                    match h.join() {
                        Ok(list) => {
                            for (t, tree) in list {
                                slots[t] = Some(tree);
                            }
                        }
                        Err(payload) => {
                            let msg = payload
                                .downcast_ref::<&str>()
                                .map(|s| (*s).to_string())
                                .or_else(|| payload.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "unknown panic payload".into());
                            worker_err = Some(anyhow!("forest worker panicked: {msg}"));
                        }
                    }
                }
            });
            if let Some(e) = worker_err {
                return Err(e);
            }
            slots
                .into_iter()
                .map(|t| t.ok_or_else(|| anyhow!("forest worker produced no tree")))
                .collect::<Result<_>>()?
        };
        Ok(RandomForest { trees })
    }

    pub fn predict_one(&self, x: &[f64]) -> f64 {
        self.trees.iter().map(|t| t.predict_one(x)).sum::<f64>() / self.trees.len() as f64
    }

    /// Batched prediction over a columnar matrix. Each tree walks all rows
    /// while its SoA lanes stay cache-hot; trees fan out over scoped
    /// threads. Per-tree results reduce in tree order, so every output is
    /// bit-identical to `predict_one` on that row.
    pub fn predict_batch(&self, x: &FeatureMatrix) -> Vec<f64> {
        let n = x.n_rows();
        let nt = self.trees.len();
        if n == 0 {
            return Vec::new();
        }
        if nt == 0 {
            return vec![f64::NAN; n];
        }
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(nt)
            .max(1);
        let mut per_tree = vec![0.0f64; nt * n];
        if workers <= 1 || nt * n < 4096 {
            for (ti, chunk) in per_tree.chunks_mut(n).enumerate() {
                self.trees[ti].predict_into(x, chunk);
            }
        } else {
            std::thread::scope(|scope| {
                let mut buckets: Vec<Vec<(usize, &mut [f64])>> =
                    (0..workers).map(|_| Vec::new()).collect();
                for (ti, chunk) in per_tree.chunks_mut(n).enumerate() {
                    buckets[ti % workers].push((ti, chunk));
                }
                for bucket in buckets {
                    let trees = &self.trees;
                    scope.spawn(move || {
                        for (ti, out) in bucket {
                            trees[ti].predict_into(x, out);
                        }
                    });
                }
            });
        }
        let mut out = vec![0.0f64; n];
        for chunk in per_tree.chunks(n) {
            for (o, v) in out.iter_mut().zip(chunk) {
                *o += *v;
            }
        }
        for o in out.iter_mut() {
            *o /= nt as f64;
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "trees",
            Json::Arr(self.trees.iter().map(|t| t.to_json()).collect()),
        );
        o
    }

    pub fn from_json(j: &Json) -> Result<RandomForest> {
        Ok(RandomForest {
            trees: j
                .req_arr("trees")?
                .iter()
                .map(DecisionTree::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics;

    fn step_rows(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        // piecewise target trees should nail: y = 10 if x0>0.5 else 2, +x1
        let mut rng = Rng64::new(seed);
        let x: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.next_f64(), rng.next_f64()])
            .collect();
        let y: Vec<f64> = x
            .iter()
            .map(|r| if r[0] > 0.5 { 10.0 } else { 2.0 } + r[1])
            .collect();
        (x, y)
    }

    fn step_data(n: usize, seed: u64) -> (FeatureMatrix, Vec<f64>) {
        let (rows, y) = step_rows(n, seed);
        (FeatureMatrix::from_rows(&rows).unwrap(), y)
    }

    #[test]
    fn tree_learns_step_function() {
        let (rows, y) = step_rows(400, 1);
        let x = FeatureMatrix::from_rows(&rows).unwrap();
        let tree = DecisionTree::fit_full(&x, &y, &TreeParams::default(), &mut Rng64::new(2));
        let pred: Vec<f64> = rows.iter().map(|r| tree.predict_one(r)).collect();
        assert!(metrics::r2(&y, &pred) > 0.99);
    }

    #[test]
    fn forest_generalizes_better_than_guess() {
        let (x, y) = step_data(500, 3);
        let forest = RandomForest::fit(&x, &y, 30, 7).unwrap();
        let (xt, yt) = step_data(200, 4);
        let pred = forest.predict_batch(&xt);
        assert!(metrics::r2(&yt, &pred) > 0.95, "r2 {}", metrics::r2(&yt, &pred));
    }

    #[test]
    fn forest_deterministic_for_seed() {
        let (x, y) = step_data(200, 5);
        let a = RandomForest::fit(&x, &y, 10, 42).unwrap();
        let b = RandomForest::fit(&x, &y, 10, 42).unwrap();
        let p = vec![0.3, 0.7];
        assert_eq!(a.predict_one(&p), b.predict_one(&p));
        let c = RandomForest::fit(&x, &y, 10, 43).unwrap();
        assert_ne!(a.predict_one(&p), c.predict_one(&p));
    }

    #[test]
    fn constant_target_constant_prediction() {
        let rows: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let x = FeatureMatrix::from_rows(&rows).unwrap();
        let y = vec![5.0; 50];
        let f = RandomForest::fit(&x, &y, 5, 1).unwrap();
        assert!((f.predict_one(&[25.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let (x, y) = step_data(150, 9);
        let f = RandomForest::fit(&x, &y, 8, 2).unwrap();
        let j = Json::parse(&f.to_json().to_string()).unwrap();
        let f2 = RandomForest::from_json(&j).unwrap();
        for i in 0..20 {
            let r = x.row_vec(i);
            assert!((f.predict_one(&r) - f2.predict_one(&r)).abs() < 1e-12);
        }
    }

    #[test]
    fn monotone_extrapolation_is_clamped() {
        // trees clamp outside the training range — a known RF property the
        // median ensemble exploits (linear handles extrapolation instead)
        let rows: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 100.0]).collect();
        let x = FeatureMatrix::from_rows(&rows).unwrap();
        let y: Vec<f64> = rows.iter().map(|r| 3.0 * r[0]).collect();
        let f = RandomForest::fit(&x, &y, 20, 3).unwrap();
        let far = f.predict_one(&[10.0]);
        assert!(far <= 3.0 + 1e-9, "clamped at max leaf: {far}");
    }

    #[test]
    fn predict_batch_matches_predict_one_bitwise() {
        let (rows, y) = step_rows(250, 9);
        let x = FeatureMatrix::from_rows(&rows).unwrap();
        let f = RandomForest::fit(&x, &y, 40, 5).unwrap();
        let batch = f.predict_batch(&x);
        assert_eq!(batch.len(), rows.len());
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(batch[i], f.predict_one(r), "row {i} diverged");
        }
    }

    // ---- seed-reference equivalence (the old->new golden contract) ----

    /// Verbatim port of the seed's per-node-sorting grower (enum nodes,
    /// per-node `sort_unstable_by`), kept as the golden reference the
    /// presorted grower must reproduce bit-for-bit.
    enum RefNode {
        Leaf {
            value: f64,
        },
        Split {
            feature: usize,
            threshold: f64,
            left: usize,
            right: usize,
        },
    }

    fn ref_grow(
        x: &[Vec<f64>],
        y: &[f64],
        idx: &mut [usize],
        params: &TreeParams,
        rng: &mut Rng64,
        nodes: &mut Vec<RefNode>,
        depth: usize,
    ) -> usize {
        let mean = idx.iter().map(|&i| y[i]).sum::<f64>() / idx.len() as f64;
        let sse: f64 = idx.iter().map(|&i| (y[i] - mean) * (y[i] - mean)).sum();
        if depth >= params.max_depth || idx.len() < params.min_samples_split || sse < 1e-12 {
            nodes.push(RefNode::Leaf { value: mean });
            return nodes.len() - 1;
        }
        let d = x[0].len();
        let n_try = ((d as f64 * params.max_features_frac).ceil() as usize).clamp(1, d);
        let mut feats: Vec<usize> = (0..d).collect();
        for i in 0..n_try {
            let j = i + rng.below(d - i);
            feats.swap(i, j);
        }
        let mut best: Option<(usize, f64, f64)> = None;
        let mut vals: Vec<(f64, f64)> = Vec::with_capacity(idx.len());
        for &f in feats.iter().take(n_try) {
            vals.clear();
            vals.extend(idx.iter().map(|&i| (x[i][f], y[i])));
            vals.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
            let total_sum: f64 = vals.iter().map(|v| v.1).sum();
            let total_sq: f64 = vals.iter().map(|v| v.1 * v.1).sum();
            let mut lsum = 0.0;
            let mut lsq = 0.0;
            for k in 0..vals.len() - 1 {
                lsum += vals[k].1;
                lsq += vals[k].1 * vals[k].1;
                if vals[k].0 == vals[k + 1].0 {
                    continue;
                }
                let nl = (k + 1) as f64;
                let nr = (vals.len() - k - 1) as f64;
                let rsum = total_sum - lsum;
                let rsq = total_sq - lsq;
                let score = (lsq - lsum * lsum / nl) + (rsq - rsum * rsum / nr);
                if best.map_or(true, |(_, _, bs)| score < bs) {
                    best = Some((f, 0.5 * (vals[k].0 + vals[k + 1].0), score));
                }
            }
        }
        let Some((feature, threshold, score)) = best else {
            nodes.push(RefNode::Leaf { value: mean });
            return nodes.len() - 1;
        };
        if score >= sse {
            nodes.push(RefNode::Leaf { value: mean });
            return nodes.len() - 1;
        }
        let mid = {
            let mut lo = 0;
            let mut hi = idx.len();
            while lo < hi {
                if x[idx[lo]][feature] <= threshold {
                    lo += 1;
                } else {
                    hi -= 1;
                    idx.swap(lo, hi);
                }
            }
            lo
        };
        if mid == 0 || mid == idx.len() {
            nodes.push(RefNode::Leaf { value: mean });
            return nodes.len() - 1;
        }
        let slot = nodes.len();
        nodes.push(RefNode::Leaf { value: 0.0 });
        let (li, ri) = idx.split_at_mut(mid);
        let left = ref_grow(x, y, li, params, rng, nodes, depth + 1);
        let right = ref_grow(x, y, ri, params, rng, nodes, depth + 1);
        nodes[slot] = RefNode::Split {
            feature,
            threshold,
            left,
            right,
        };
        slot
    }

    fn ref_tree_json(nodes: &[RefNode]) -> Json {
        Json::Arr(
            nodes
                .iter()
                .map(|n| match n {
                    RefNode::Leaf { value } => Json::from_f64s(&[*value]),
                    RefNode::Split {
                        feature,
                        threshold,
                        left,
                        right,
                    } => Json::from_f64s(&[
                        *feature as f64,
                        *threshold,
                        *left as f64,
                        *right as f64,
                    ]),
                })
                .collect(),
        )
    }

    fn assert_matches_reference(rows: &[Vec<f64>], y: &[f64], n_trees: usize, seed: u64) {
        let x = FeatureMatrix::from_rows(rows).unwrap();
        let forest = RandomForest::fit(&x, y, n_trees, seed).unwrap();
        for t in 0..n_trees {
            let mut rng = Rng64::new(
                (seed.wrapping_add(1)).wrapping_mul(0x9e3779b97f4a7c15)
                    ^ (t as u64 + 1).wrapping_mul(0xd1342543de82ef95),
            );
            let mut idx: Vec<usize> = (0..rows.len()).map(|_| rng.below(rows.len())).collect();
            let mut nodes = Vec::new();
            ref_grow(
                rows,
                y,
                &mut idx,
                &TreeParams::default(),
                &mut rng,
                &mut nodes,
                0,
            );
            assert_eq!(
                forest.trees[t].to_json().to_string(),
                ref_tree_json(&nodes).to_string(),
                "tree {t} diverged from the seed reference grower"
            );
        }
    }

    #[test]
    fn presorted_grower_matches_seed_reference() {
        // continuous features: the only value ties come from bootstrap row
        // duplication (identical y), so equality is exact
        let (rows, y) = step_rows(300, 21);
        assert_matches_reference(&rows, &y, 6, 1234);
        // wider feature space, nonlinear target
        let mut rng = Rng64::new(77);
        let rows: Vec<Vec<f64>> = (0..180)
            .map(|_| (0..6).map(|_| rng.range(0.0, 50.0)).collect())
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| r[0] * r[1] / 10.0 + r[2].sqrt() + r[5])
            .collect();
        assert_matches_reference(&rows, &y, 5, 99);
    }

    #[test]
    fn presorted_grower_matches_seed_reference_with_ties() {
        // quantized integer features + integer targets: heavy cross-row
        // value ties, but all split-scan sums stay exact in f64, so the
        // presorted grower still reproduces the reference bit-for-bit
        let mut rng = Rng64::new(33);
        let rows: Vec<Vec<f64>> = (0..200)
            .map(|_| (0..4).map(|_| rng.below(8) as f64).collect())
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| 3.0 * r[0] + r[1] * r[2] - r[3])
            .collect();
        assert_matches_reference(&rows, &y, 4, 2024);
    }
}
