//! CART regression trees + bootstrap-aggregated random forest, from
//! scratch (the paper uses sklearn's RandomForestRegressor with default
//! hyper-parameters: 100 trees, unlimited depth, min_samples_split=2,
//! bootstrap sampling, all features considered per split).

use crate::util::{Json, Rng64};
use anyhow::{anyhow, Result};

/// Flat-array binary regression tree.
#[derive(Debug, Clone)]
pub struct DecisionTree {
    nodes: Vec<Node>,
}

#[derive(Debug, Clone)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        /// child indices into `nodes`
        left: usize,
        right: usize,
    },
}

/// Tree-growing hyper-parameters (sklearn defaults).
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Features tried per split as a fraction of D (1.0 = all, sklearn's
    /// regression default).
    pub max_features_frac: f64,
}

impl Default for TreeParams {
    fn default() -> Self {
        Self {
            max_depth: 32,
            min_samples_split: 2,
            max_features_frac: 1.0,
        }
    }
}

impl DecisionTree {
    /// Fit on the rows of `x` indexed by `idx`.
    fn fit_indices(
        x: &[Vec<f64>],
        y: &[f64],
        idx: &mut [usize],
        params: &TreeParams,
        rng: &mut Rng64,
    ) -> DecisionTree {
        let mut nodes = Vec::new();
        Self::grow(x, y, idx, params, rng, &mut nodes, 0);
        DecisionTree { nodes }
    }

    /// Grow a subtree over `idx`; returns its node index.
    fn grow(
        x: &[Vec<f64>],
        y: &[f64],
        idx: &mut [usize],
        params: &TreeParams,
        rng: &mut Rng64,
        nodes: &mut Vec<Node>,
        depth: usize,
    ) -> usize {
        let mean = idx.iter().map(|&i| y[i]).sum::<f64>() / idx.len() as f64;
        let sse: f64 = idx.iter().map(|&i| (y[i] - mean) * (y[i] - mean)).sum();
        if depth >= params.max_depth || idx.len() < params.min_samples_split || sse < 1e-12 {
            nodes.push(Node::Leaf { value: mean });
            return nodes.len() - 1;
        }

        let d = x[0].len();
        let n_try = ((d as f64 * params.max_features_frac).ceil() as usize).clamp(1, d);
        // sample features without replacement (Fisher-Yates prefix)
        let mut feats: Vec<usize> = (0..d).collect();
        for i in 0..n_try {
            let j = i + rng.below(d - i);
            feats.swap(i, j);
        }

        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, score)
        let mut vals: Vec<(f64, f64)> = Vec::with_capacity(idx.len());
        for &f in feats.iter().take(n_try) {
            vals.clear();
            vals.extend(idx.iter().map(|&i| (x[i][f], y[i])));
            // §Perf: sort_unstable + total_cmp measured ~15% faster than
            // the stable partial_cmp sort on the split hot loop.
            vals.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
            // prefix sums for O(n) best-split scan
            let total_sum: f64 = vals.iter().map(|v| v.1).sum();
            let total_sq: f64 = vals.iter().map(|v| v.1 * v.1).sum();
            let mut lsum = 0.0;
            let mut lsq = 0.0;
            for k in 0..vals.len() - 1 {
                lsum += vals[k].1;
                lsq += vals[k].1 * vals[k].1;
                if vals[k].0 == vals[k + 1].0 {
                    continue; // can't split between equal values
                }
                let nl = (k + 1) as f64;
                let nr = (vals.len() - k - 1) as f64;
                let rsum = total_sum - lsum;
                let rsq = total_sq - lsq;
                // total child SSE
                let score = (lsq - lsum * lsum / nl) + (rsq - rsum * rsum / nr);
                if best.map_or(true, |(_, _, bs)| score < bs) {
                    best = Some((f, 0.5 * (vals[k].0 + vals[k + 1].0), score));
                }
            }
        }

        let Some((feature, threshold, score)) = best else {
            nodes.push(Node::Leaf { value: mean });
            return nodes.len() - 1;
        };
        if score >= sse {
            nodes.push(Node::Leaf { value: mean });
            return nodes.len() - 1;
        }

        // partition idx in place
        let mid = {
            let mut lo = 0;
            let mut hi = idx.len();
            while lo < hi {
                if x[idx[lo]][feature] <= threshold {
                    lo += 1;
                } else {
                    hi -= 1;
                    idx.swap(lo, hi);
                }
            }
            lo
        };
        if mid == 0 || mid == idx.len() {
            nodes.push(Node::Leaf { value: mean });
            return nodes.len() - 1;
        }
        let slot = nodes.len();
        nodes.push(Node::Leaf { value: 0.0 }); // placeholder
        let (li, ri) = idx.split_at_mut(mid);
        let left = Self::grow(x, y, li, params, rng, nodes, depth + 1);
        let right = Self::grow(x, y, ri, params, rng, nodes, depth + 1);
        nodes[slot] = Node::Split {
            feature,
            threshold,
            left,
            right,
        };
        slot
    }

    pub fn predict_one(&self, x: &[f64]) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    fn to_json(&self) -> Json {
        Json::Arr(
            self.nodes
                .iter()
                .map(|n| match n {
                    Node::Leaf { value } => Json::from_f64s(&[*value]),
                    Node::Split {
                        feature,
                        threshold,
                        left,
                        right,
                    } => Json::from_f64s(&[
                        *feature as f64,
                        *threshold,
                        *left as f64,
                        *right as f64,
                    ]),
                })
                .collect(),
        )
    }

    fn from_json(j: &Json) -> Result<DecisionTree> {
        let arr = j.as_arr().ok_or_else(|| anyhow!("tree not array"))?;
        let nodes = arr
            .iter()
            .map(|n| {
                let v = n.to_f64s()?;
                Ok(match v.len() {
                    1 => Node::Leaf { value: v[0] },
                    4 => Node::Split {
                        feature: v[0] as usize,
                        threshold: v[1],
                        left: v[2] as usize,
                        right: v[3] as usize,
                    },
                    _ => anyhow::bail!("bad node arity"),
                })
            })
            .collect::<Result<_>>()?;
        Ok(DecisionTree { nodes })
    }
}

/// Bagged forest of CART trees.
#[derive(Debug, Clone)]
pub struct RandomForest {
    pub trees: Vec<DecisionTree>,
}

impl RandomForest {
    /// Fit `n_trees` trees on bootstrap samples. Deterministic via `seed`
    /// (each tree's RNG depends only on `seed` and its index, so the
    /// thread-parallel fit below produces bit-identical forests to a
    /// sequential one).
    pub fn fit(x: &[Vec<f64>], y: &[f64], n_trees: usize, seed: u64) -> Result<RandomForest> {
        anyhow::ensure!(!x.is_empty() && x.len() == y.len(), "bad shapes");
        let params = TreeParams::default();
        let n = x.len();
        let fit_one = |t: usize| -> DecisionTree {
            let mut rng = Rng64::new(
                (seed.wrapping_add(1)).wrapping_mul(0x9e3779b97f4a7c15)
                    ^ (t as u64 + 1).wrapping_mul(0xd1342543de82ef95),
            );
            let mut idx: Vec<usize> = (0..n).map(|_| rng.below(n)).collect();
            DecisionTree::fit_indices(x, y, &mut idx, &params, &mut rng)
        };
        // §Perf: tree growing dominated training (1.4 s per 100-tree
        // forest); trees are independent, so fan out across cores via
        // scoped threads with a striped work split.
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(n_trees)
            .max(1);
        let trees: Vec<DecisionTree> = if workers <= 1 || n_trees < 8 {
            (0..n_trees).map(fit_one).collect()
        } else {
            let mut slots: Vec<Option<DecisionTree>> = (0..n_trees).map(|_| None).collect();
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(workers);
                for w in 0..workers {
                    let fit_one = &fit_one;
                    handles.push(scope.spawn(move || {
                        (w..n_trees)
                            .step_by(workers)
                            .map(|t| (t, fit_one(t)))
                            .collect::<Vec<_>>()
                    }));
                }
                for h in handles {
                    for (t, tree) in h.join().expect("forest worker panicked") {
                        slots[t] = Some(tree);
                    }
                }
            });
            slots.into_iter().map(|t| t.unwrap()).collect()
        };
        Ok(RandomForest { trees })
    }

    pub fn predict_one(&self, x: &[f64]) -> f64 {
        self.trees.iter().map(|t| t.predict_one(x)).sum::<f64>() / self.trees.len() as f64
    }

    pub fn predict(&self, x: &[Vec<f64>]) -> Vec<f64> {
        x.iter().map(|r| self.predict_one(r)).collect()
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("trees", Json::Arr(self.trees.iter().map(|t| t.to_json()).collect()));
        o
    }

    pub fn from_json(j: &Json) -> Result<RandomForest> {
        Ok(RandomForest {
            trees: j
                .req_arr("trees")?
                .iter()
                .map(DecisionTree::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics;

    fn step_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        // piecewise target trees should nail: y = 10 if x0>0.5 else 2, +x1
        let mut rng = Rng64::new(seed);
        let x: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.next_f64(), rng.next_f64()])
            .collect();
        let y: Vec<f64> = x
            .iter()
            .map(|r| if r[0] > 0.5 { 10.0 } else { 2.0 } + r[1])
            .collect();
        (x, y)
    }

    #[test]
    fn tree_learns_step_function() {
        let (x, y) = step_data(400, 1);
        let mut idx: Vec<usize> = (0..x.len()).collect();
        let tree = DecisionTree::fit_indices(&x, &y, &mut idx, &TreeParams::default(), &mut Rng64::new(2));
        let pred: Vec<f64> = x.iter().map(|r| tree.predict_one(r)).collect();
        assert!(metrics::r2(&y, &pred) > 0.99);
    }

    #[test]
    fn forest_generalizes_better_than_guess() {
        let (x, y) = step_data(500, 3);
        let forest = RandomForest::fit(&x, &y, 30, 7).unwrap();
        let (xt, yt) = step_data(200, 4);
        let pred = forest.predict(&xt);
        assert!(metrics::r2(&yt, &pred) > 0.95, "r2 {}", metrics::r2(&yt, &pred));
    }

    #[test]
    fn forest_deterministic_for_seed() {
        let (x, y) = step_data(200, 5);
        let a = RandomForest::fit(&x, &y, 10, 42).unwrap();
        let b = RandomForest::fit(&x, &y, 10, 42).unwrap();
        let p = vec![0.3, 0.7];
        assert_eq!(a.predict_one(&p), b.predict_one(&p));
        let c = RandomForest::fit(&x, &y, 10, 43).unwrap();
        assert_ne!(a.predict_one(&p), c.predict_one(&p));
    }

    #[test]
    fn constant_target_constant_prediction() {
        let x: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let y = vec![5.0; 50];
        let f = RandomForest::fit(&x, &y, 5, 1).unwrap();
        assert!((f.predict_one(&[25.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let (x, y) = step_data(150, 9);
        let f = RandomForest::fit(&x, &y, 8, 2).unwrap();
        let j = Json::parse(&f.to_json().to_string()).unwrap();
        let f2 = RandomForest::from_json(&j).unwrap();
        for r in x.iter().take(20) {
            assert!((f.predict_one(r) - f2.predict_one(r)).abs() < 1e-12);
        }
    }

    #[test]
    fn monotone_extrapolation_is_clamped() {
        // trees clamp outside the training range — a known RF property the
        // median ensemble exploits (linear handles extrapolation instead)
        let x: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 100.0]).collect();
        let y: Vec<f64> = x.iter().map(|r| 3.0 * r[0]).collect();
        let f = RandomForest::fit(&x, &y, 20, 3).unwrap();
        let far = f.predict_one(&[10.0]);
        assert!(far <= 3.0 + 1e-9, "clamped at max leaf: {far}");
    }
}
