//! Classical ML, from scratch (the offline environment has no sklearn
//! equivalent): OLS linear regression, CART random forest, polynomial
//! regression, min-max scaling, and the regression metrics the paper
//! reports (MAPE / RMSE / R²).
//!
//! All fit/predict paths run over the columnar [`FeatureMatrix`]
//! (contiguous column-major storage) so per-feature scans are sequential
//! memory reads.

mod forest;
mod linear;
mod matrix;
pub mod metrics;
mod polynomial;
mod scaler;

pub use forest::{DecisionTree, RandomForest, TreeParams};
pub use linear::LinearRegression;
pub use matrix::FeatureMatrix;
pub use polynomial::PolyRegression;
pub use scaler::MinMaxScaler;
