//! Classical ML, from scratch (the offline environment has no sklearn
//! equivalent): OLS linear regression, CART random forest, polynomial
//! regression, min-max scaling, and the regression metrics the paper
//! reports (MAPE / RMSE / R²).

mod forest;
mod linear;
pub mod metrics;
mod polynomial;
mod scaler;

pub use forest::{DecisionTree, RandomForest};
pub use linear::LinearRegression;
pub use polynomial::PolyRegression;
pub use scaler::MinMaxScaler;
