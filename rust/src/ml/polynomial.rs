//! Polynomial regression (paper Sec III-C2): T_N(b) = α₂b² + α₁b + α₀.
//!
//! The order is a parameter so the Fig 12 ablation (order-1 vs order-2)
//! uses the same code path.

use super::linear::solve;
use crate::ml::FeatureMatrix;
use crate::util::Json;
use anyhow::{anyhow, Result};

/// Least-squares polynomial of a given order on scalar inputs.
#[derive(Debug, Clone)]
pub struct PolyRegression {
    /// Coefficients low→high: c[0] + c[1] x + c[2] x² + ...
    pub coeffs: Vec<f64>,
}

impl PolyRegression {
    pub fn fit(x: &[f64], y: &[f64], order: usize) -> Result<PolyRegression> {
        anyhow::ensure!(x.len() == y.len() && x.len() > order, "need > order points");
        let n = order + 1;
        // Vandermonde normal equations
        let mut a = vec![vec![0.0; n]; n];
        let mut b = vec![0.0; n];
        for (&xi, &yi) in x.iter().zip(y) {
            let mut pow = vec![1.0; 2 * n - 1];
            for k in 1..2 * n - 1 {
                pow[k] = pow[k - 1] * xi;
            }
            for i in 0..n {
                b[i] += pow[i] * yi;
                for j in 0..n {
                    a[i][j] += pow[i + j];
                }
            }
        }
        for (i, row) in a.iter_mut().enumerate() {
            row[i] += 1e-10;
        }
        let coeffs = solve(a, b).ok_or_else(|| anyhow!("singular Vandermonde"))?;
        Ok(PolyRegression { coeffs })
    }

    /// Fit on one column of a columnar matrix (the batch/pixel models'
    /// scalar regressor lives in a wider design matrix during sweeps).
    pub fn fit_col(x: &FeatureMatrix, col: usize, y: &[f64], order: usize) -> Result<PolyRegression> {
        Self::fit(x.col(col), y, order)
    }

    pub fn predict(&self, x: f64) -> f64 {
        let mut acc = 0.0;
        for &c in self.coeffs.iter().rev() {
            acc = acc * x + c;
        }
        acc
    }

    pub fn order(&self) -> usize {
        self.coeffs.len().saturating_sub(1)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("coeffs", Json::from_f64s(&self.coeffs));
        o
    }

    pub fn from_json(j: &Json) -> Result<PolyRegression> {
        Ok(PolyRegression {
            coeffs: j.get("coeffs").ok_or_else(|| anyhow!("coeffs"))?.to_f64s()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_quadratic() {
        let x: Vec<f64> = (0..30).map(|i| i as f64 / 10.0).collect();
        let y: Vec<f64> = x.iter().map(|&v| 2.0 * v * v - 3.0 * v + 1.0).collect();
        let p = PolyRegression::fit(&x, &y, 2).unwrap();
        assert!((p.coeffs[2] - 2.0).abs() < 1e-6);
        assert!((p.coeffs[1] + 3.0).abs() < 1e-6);
        assert!((p.coeffs[0] - 1.0).abs() < 1e-6);
        assert!((p.predict(5.0) - (2.0 * 25.0 - 15.0 + 1.0)).abs() < 1e-4);
    }

    #[test]
    fn order1_is_line() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [3.0, 5.0, 7.0, 9.0];
        let p = PolyRegression::fit(&x, &y, 1).unwrap();
        assert_eq!(p.order(), 1);
        assert!((p.predict(10.0) - 21.0).abs() < 1e-6);
    }

    #[test]
    fn order2_fits_curvature_better_than_order1() {
        // convex latency-vs-batch shape
        let x: Vec<f64> = vec![0.0, 0.066, 0.2, 0.46, 1.0];
        let y: Vec<f64> = x.iter().map(|&v| 0.1 + 0.3 * v + 0.6 * v * v).collect();
        let p1 = PolyRegression::fit(&x, &y, 1).unwrap();
        let p2 = PolyRegression::fit(&x, &y, 2).unwrap();
        let err = |p: &PolyRegression| -> f64 {
            x.iter()
                .zip(&y)
                .map(|(&xi, &yi)| (p.predict(xi) - yi).abs())
                .sum()
        };
        assert!(err(&p2) < err(&p1) / 5.0);
    }

    #[test]
    fn too_few_points_error() {
        assert!(PolyRegression::fit(&[1.0, 2.0], &[1.0, 2.0], 2).is_err());
    }

    #[test]
    fn fit_col_matches_fit() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&v| 0.5 * v * v - v + 2.0).collect();
        let rows: Vec<Vec<f64>> = xs.iter().map(|&v| vec![99.0, v]).collect();
        let m = FeatureMatrix::from_rows(&rows).unwrap();
        let a = PolyRegression::fit(&xs, &ys, 2).unwrap();
        let b = PolyRegression::fit_col(&m, 1, &ys, 2).unwrap();
        assert_eq!(a.coeffs, b.coeffs);
    }

    #[test]
    fn json_roundtrip() {
        let p = PolyRegression {
            coeffs: vec![1.0, -0.5, 2.25],
        };
        let j = Json::parse(&p.to_json().to_string()).unwrap();
        let p2 = PolyRegression::from_json(&j).unwrap();
        assert_eq!(p.coeffs, p2.coeffs);
    }
}
