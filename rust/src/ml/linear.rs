//! Ordinary least squares linear regression via ridge-stabilized normal
//! equations (Gaussian elimination with partial pivoting).
//!
//! §Perf: X^T X accumulates as column-pair dot products over the columnar
//! [`FeatureMatrix`] — each inner loop is two contiguous slice scans
//! instead of one strided read per row allocation.

use crate::ml::FeatureMatrix;
use crate::util::Json;
use anyhow::{anyhow, Result};

/// y ≈ w·x + b. The paper's Linear cross-instance model uses a single
/// feature (anchor batch latency): y = αx + β (Sec V-A).
#[derive(Debug, Clone, Default)]
pub struct LinearRegression {
    pub weights: Vec<f64>,
    pub bias: f64,
}

/// Solve A x = b in place; A is n x n row-major. Ridge-jittered upstream.
pub(crate) fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // partial pivot
        let piv = (col..n).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in (col + 1)..n {
            let f = a[row][col] / a[col][col];
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut s = b[col];
        for k in (col + 1)..n {
            s -= a[col][k] * x[k];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

impl LinearRegression {
    /// Fit on the columnar matrix `x` against targets `y`.
    pub fn fit(x: &FeatureMatrix, y: &[f64]) -> Result<LinearRegression> {
        anyhow::ensure!(!x.is_empty() && x.n_rows() == y.len(), "bad shapes");
        let n = x.n_rows();
        let d = x.n_cols();
        let da = d + 1; // + bias column
        // normal equations: (X^T X + λI) w = X^T y, built column-by-column
        let mut xtx = vec![vec![0.0; da]; da];
        let mut xty = vec![0.0; da];
        for i in 0..d {
            let ci = x.col(i);
            xty[i] = ci.iter().zip(y).map(|(a, b)| a * b).sum();
            for j in i..d {
                let cj = x.col(j);
                xtx[i][j] = ci.iter().zip(cj).map(|(a, b)| a * b).sum();
            }
            xtx[i][d] = ci.iter().sum(); // dot with the implicit 1s column
        }
        xty[d] = y.iter().sum();
        xtx[d][d] = n as f64;
        for i in 0..da {
            for j in 0..i {
                xtx[i][j] = xtx[j][i];
            }
            xtx[i][i] += 1e-8 * (1.0 + xtx[i][i].abs()); // ridge jitter
        }
        let w = solve(xtx, xty).ok_or_else(|| anyhow!("singular system"))?;
        Ok(LinearRegression {
            bias: w[d],
            weights: w[..d].to_vec(),
        })
    }

    pub fn predict_one(&self, x: &[f64]) -> f64 {
        self.bias
            + self
                .weights
                .iter()
                .zip(x)
                .map(|(w, v)| w * v)
                .sum::<f64>()
    }

    /// Columnar batched prediction: one axpy pass per weight column.
    pub fn predict(&self, x: &FeatureMatrix) -> Vec<f64> {
        let mut out = vec![self.bias; x.n_rows()];
        for (j, w) in self.weights.iter().enumerate() {
            for (o, v) in out.iter_mut().zip(x.col(j)) {
                *o += w * v;
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("weights", Json::from_f64s(&self.weights));
        o.set("bias", Json::Num(self.bias));
        o
    }

    pub fn from_json(j: &Json) -> Result<LinearRegression> {
        Ok(LinearRegression {
            weights: j
                .get("weights")
                .ok_or_else(|| anyhow!("weights"))?
                .to_f64s()?,
            bias: j.req_f64("bias")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix(rows: &[Vec<f64>]) -> FeatureMatrix {
        FeatureMatrix::from_rows(rows).unwrap()
    }

    #[test]
    fn recovers_exact_line() {
        // y = 3x + 2
        let rows: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = rows.iter().map(|r| 3.0 * r[0] + 2.0).collect();
        let m = LinearRegression::fit(&matrix(&rows), &y).unwrap();
        assert!((m.weights[0] - 3.0).abs() < 1e-6);
        assert!((m.bias - 2.0).abs() < 1e-5);
    }

    #[test]
    fn recovers_multivariate_plane() {
        let mut rng = crate::util::Rng64::new(5);
        let rows: Vec<Vec<f64>> = (0..200)
            .map(|_| (0..4).map(|_| rng.range(-2.0, 2.0)).collect())
            .collect();
        let w = [1.5, -2.0, 0.5, 4.0];
        let y: Vec<f64> = rows
            .iter()
            .map(|r| r.iter().zip(&w).map(|(a, b)| a * b).sum::<f64>() + 7.0)
            .collect();
        let m = LinearRegression::fit(&matrix(&rows), &y).unwrap();
        for (got, want) in m.weights.iter().zip(&w) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
        assert!((m.bias - 7.0).abs() < 1e-4);
    }

    #[test]
    fn noisy_fit_reasonable() {
        let mut rng = crate::util::Rng64::new(6);
        let rows: Vec<Vec<f64>> = (0..500).map(|_| vec![rng.range(0.0, 10.0)]).collect();
        let y: Vec<f64> = rows.iter().map(|r| 2.0 * r[0] + 1.0 + rng.normal() * 0.1).collect();
        let m = LinearRegression::fit(&matrix(&rows), &y).unwrap();
        assert!((m.weights[0] - 2.0).abs() < 0.05);
    }

    #[test]
    fn batched_predict_matches_per_row() {
        let mut rng = crate::util::Rng64::new(8);
        let rows: Vec<Vec<f64>> = (0..40)
            .map(|_| (0..3).map(|_| rng.range(-5.0, 5.0)).collect())
            .collect();
        let m = LinearRegression {
            weights: vec![0.5, -1.5, 2.0],
            bias: 0.75,
        };
        let x = matrix(&rows);
        let batch = m.predict(&x);
        for (i, r) in rows.iter().enumerate() {
            assert!((batch[i] - m.predict_one(r)).abs() < 1e-12);
        }
    }

    #[test]
    fn json_roundtrip() {
        let m = LinearRegression {
            weights: vec![1.0, -2.5],
            bias: 0.25,
        };
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        let m2 = LinearRegression::from_json(&j).unwrap();
        assert_eq!(m.weights, m2.weights);
        assert_eq!(m.bias, m2.bias);
    }

    #[test]
    fn shape_errors() {
        assert!(LinearRegression::fit(&FeatureMatrix::from_rows(&[]).unwrap(), &[]).is_err());
        let one = matrix(&[vec![1.0]]);
        assert!(LinearRegression::fit(&one, &[1.0, 2.0]).is_err());
    }
}
