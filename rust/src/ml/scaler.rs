//! Min-max scaler (paper Sec III-C2 / Eq. 1).

use crate::ml::FeatureMatrix;
use crate::util::Json;
use anyhow::Result;

/// Maps [lo, hi] ↔ [0, 1]. Degenerate ranges map to 0 on transform.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MinMaxScaler {
    pub lo: f64,
    pub hi: f64,
}

impl MinMaxScaler {
    pub fn fit(values: &[f64]) -> MinMaxScaler {
        let lo = values.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = values.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        MinMaxScaler { lo, hi }
    }

    pub fn from_bounds(lo: f64, hi: f64) -> MinMaxScaler {
        MinMaxScaler { lo, hi }
    }

    /// One scaler per column of a columnar matrix — each fit is a single
    /// contiguous slice scan.
    pub fn fit_columns(x: &FeatureMatrix) -> Vec<MinMaxScaler> {
        (0..x.n_cols()).map(|j| MinMaxScaler::fit(x.col(j))).collect()
    }

    /// T_N = (T_O - min) / (max - min).
    pub fn transform(&self, v: f64) -> f64 {
        if self.hi <= self.lo {
            0.0
        } else {
            (v - self.lo) / (self.hi - self.lo)
        }
    }

    /// Eq. 1: T_O = T_N · (max - min) + min.
    pub fn inverse(&self, n: f64) -> f64 {
        n * (self.hi - self.lo) + self.lo
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("lo", Json::Num(self.lo));
        o.set("hi", Json::Num(self.hi));
        o
    }

    pub fn from_json(j: &Json) -> Result<MinMaxScaler> {
        Ok(MinMaxScaler {
            lo: j.req_f64("lo")?,
            hi: j.req_f64("hi")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = MinMaxScaler::fit(&[10.0, 20.0, 30.0]);
        assert_eq!(s.transform(10.0), 0.0);
        assert_eq!(s.transform(30.0), 1.0);
        assert_eq!(s.transform(20.0), 0.5);
        for v in [12.0, 17.5, 29.0, 35.0] {
            assert!((s.inverse(s.transform(v)) - v).abs() < 1e-12);
        }
    }

    #[test]
    fn degenerate_range() {
        let s = MinMaxScaler::fit(&[5.0, 5.0]);
        assert_eq!(s.transform(5.0), 0.0);
        assert_eq!(s.inverse(0.0), 5.0);
    }

    #[test]
    fn per_column_fit() {
        let m = FeatureMatrix::from_rows(&[vec![1.0, 100.0], vec![3.0, 50.0]]).unwrap();
        let scalers = MinMaxScaler::fit_columns(&m);
        assert_eq!(scalers.len(), 2);
        assert_eq!((scalers[0].lo, scalers[0].hi), (1.0, 3.0));
        assert_eq!((scalers[1].lo, scalers[1].hi), (50.0, 100.0));
    }
}
