//! Columnar design matrix: contiguous column-major f64 storage shared by
//! every classical-ML fit/predict path (forest, linear, scaler, DNN
//! preprocessing) and the serving batcher.
//!
//! The previous substrate passed `&[Vec<f64>]` row lists everywhere; every
//! per-feature scan (CART split search, normal-equation accumulation,
//! min-max fitting) then strided across one heap allocation per row. Here
//! each feature column is one contiguous slice, so the hot loops are
//! sequential reads the prefetcher can follow.

use anyhow::Result;

/// Dense column-major matrix: `data[j * n_rows + i]` holds row `i`,
/// column `j`. Invariant: `data.len() == n_rows * n_cols`.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureMatrix {
    data: Vec<f64>,
    n_rows: usize,
    n_cols: usize,
}

impl FeatureMatrix {
    /// Build from row slices (the shape produced by
    /// `FeatureSpace::vectorize`). Rejects ragged input.
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<FeatureMatrix> {
        let n_rows = rows.len();
        let n_cols = rows.first().map_or(0, |r| r.len());
        let mut data = vec![0.0; n_rows * n_cols];
        for (i, row) in rows.iter().enumerate() {
            anyhow::ensure!(
                row.len() == n_cols,
                "ragged row {i}: {} cols, expected {n_cols}",
                row.len()
            );
            for (j, &v) in row.iter().enumerate() {
                data[j * n_rows + i] = v;
            }
        }
        Ok(FeatureMatrix {
            data,
            n_rows,
            n_cols,
        })
    }

    /// Single-column matrix over `values` (e.g. the linear member's
    /// anchor-latency regressor).
    pub fn from_col(values: &[f64]) -> FeatureMatrix {
        FeatureMatrix {
            data: values.to_vec(),
            n_rows: values.len(),
            n_cols: 1,
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    /// Column `j` as one contiguous slice — the whole point of the layout.
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.n_rows..(j + 1) * self.n_rows]
    }

    /// Single cell (row-major callers; strided access).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n_rows + i]
    }

    /// Copy row `i` into a caller-owned vector (for row-oriented consumers
    /// like `predict_one`).
    pub fn row_vec(&self, i: usize) -> Vec<f64> {
        (0..self.n_cols).map(|j| self.get(i, j)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_rows_columnar() {
        let rows = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let m = FeatureMatrix::from_rows(&rows).unwrap();
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.n_cols(), 3);
        assert_eq!(m.col(0), &[1.0, 4.0]);
        assert_eq!(m.col(2), &[3.0, 6.0]);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.row_vec(0), rows[0]);
        assert_eq!(m.row_vec(1), rows[1]);
    }

    #[test]
    fn ragged_rejected() {
        let rows = vec![vec![1.0, 2.0], vec![3.0]];
        assert!(FeatureMatrix::from_rows(&rows).is_err());
    }

    #[test]
    fn empty_and_single_col() {
        let m = FeatureMatrix::from_rows(&[]).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.n_cols(), 0);
        let c = FeatureMatrix::from_col(&[7.0, 8.0]);
        assert_eq!(c.n_rows(), 2);
        assert_eq!(c.n_cols(), 1);
        assert_eq!(c.col(0), &[7.0, 8.0]);
    }

}
