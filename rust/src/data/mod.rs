//! Dataset container: the offline experiment corpus (Sec III / Fig 6 upper
//! half) — every executable workload run on every instance, with the
//! anchor-side profile and the target-side clean latency.

use crate::gpu::Instance;
use crate::sim::{self, Workload};
use crate::util::{Json, Rng64};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One workload executed on one instance.
#[derive(Debug, Clone)]
pub struct RunData {
    /// Aggregated (op name → ms) profile, profiling enabled.
    pub profile: BTreeMap<String, f64>,
    /// Clean batch latency (profiling off), ms — the ground truth y.
    pub latency_ms: f64,
}

/// One workload with its per-instance observations.
#[derive(Debug, Clone)]
pub struct Entry {
    pub workload: Workload,
    pub runs: BTreeMap<Instance, RunData>,
}

/// The full corpus.
#[derive(Debug, Clone, Default)]
pub struct Corpus {
    pub entries: Vec<Entry>,
}

impl Corpus {
    /// Generate by running the simulator over every executable workload on
    /// `instances` (deterministic).
    ///
    /// §Perf: builds each workload's op graph ONCE and executes it per
    /// instance (the enumerate + run_workload path rebuilt the graph per
    /// instance — ~40% of corpus-generation time on the big graphs).
    pub fn generate(instances: &[Instance]) -> Corpus {
        let mut entries = Vec::new();
        for model in crate::models::ModelId::ALL {
            for batch in sim::workload::BATCHES {
                for pixels in sim::workload::PIXELS {
                    let w = sim::Workload::new(model, batch, pixels);
                    let Ok(graph) = w.graph() else { continue };
                    let mut runs = BTreeMap::new();
                    for &inst in instances {
                        if !sim::fits_in_memory(&graph, inst.spec()) {
                            continue;
                        }
                        let r = sim::execute(&graph, inst.spec());
                        runs.insert(
                            inst,
                            RunData {
                                profile: r.profile.aggregated(),
                                latency_ms: r.batch_latency_ms,
                            },
                        );
                    }
                    if !runs.is_empty() {
                        entries.push(Entry { workload: w, runs });
                    }
                }
            }
        }
        Corpus { entries }
    }

    /// Total (workload, instance) observation count.
    pub fn n_observations(&self) -> usize {
        self.entries.iter().map(|e| e.runs.len()).sum()
    }

    /// Distinct op names across all profiles (the feature vocabulary).
    pub fn vocabulary(&self) -> Vec<String> {
        let mut set = std::collections::BTreeSet::new();
        for e in &self.entries {
            for run in e.runs.values() {
                for op in run.profile.keys() {
                    set.insert(op.clone());
                }
            }
        }
        set.into_iter().collect()
    }

    /// Vocabulary excluding the given models' entries (leave-out studies).
    pub fn vocabulary_excluding(&self, exclude: &[crate::models::ModelId]) -> Vec<String> {
        let mut set = std::collections::BTreeSet::new();
        for e in &self.entries {
            if exclude.contains(&e.workload.model) {
                continue;
            }
            for run in e.runs.values() {
                for op in run.profile.keys() {
                    set.insert(op.clone());
                }
            }
        }
        set.into_iter().collect()
    }

    /// Random train/test split over entries (by workload, so a workload's
    /// observations never straddle the split). Returns index vectors.
    pub fn split_random(&self, test_frac: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
        let mut idx: Vec<usize> = (0..self.entries.len()).collect();
        let mut rng = Rng64::new(seed);
        rng.shuffle(&mut idx);
        let n_test = ((self.entries.len() as f64) * test_frac).round() as usize;
        let test = idx[..n_test].to_vec();
        let train = idx[n_test..].to_vec();
        (train, test)
    }

    /// Leave-one-model-out split: test = all entries of `model`.
    pub fn split_by_model(&self, model: crate::models::ModelId) -> (Vec<usize>, Vec<usize>) {
        let mut train = Vec::new();
        let mut test = Vec::new();
        for (i, e) in self.entries.iter().enumerate() {
            if e.workload.model == model {
                test.push(i);
            } else {
                train.push(i);
            }
        }
        (train, test)
    }

    /// JSON persistence.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut o = Json::obj();
                o.set("model", Json::Str(e.workload.model.name().to_string()));
                o.set("batch", Json::Num(e.workload.batch as f64));
                o.set("pixels", Json::Num(e.workload.pixels as f64));
                let mut runs = Json::obj();
                for (inst, run) in &e.runs {
                    let mut r = Json::obj();
                    r.set("latency_ms", Json::Num(run.latency_ms));
                    let mut prof = Json::obj();
                    for (k, v) in &run.profile {
                        prof.set(k, Json::Num(*v));
                    }
                    r.set("profile", prof);
                    runs.set(inst.key(), r);
                }
                o.set("runs", runs);
                o
            })
            .collect();
        std::fs::write(path.as_ref(), Json::Arr(entries).to_string())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Corpus> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let j = Json::parse(&text)?;
        let arr = j.as_arr().ok_or_else(|| anyhow!("corpus not an array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            let model = crate::models::ModelId::from_name(e.req_str("model")?)
                .ok_or_else(|| anyhow!("unknown model"))?;
            let workload = Workload::new(model, e.req_usize("batch")?, e.req_usize("pixels")?);
            let mut runs = BTreeMap::new();
            if let Some(Json::Obj(rmap)) = e.get("runs") {
                for (k, r) in rmap {
                    let inst = Instance::from_key(k).ok_or_else(|| anyhow!("instance {k}"))?;
                    let mut profile = BTreeMap::new();
                    if let Some(Json::Obj(pmap)) = r.get("profile") {
                        for (op, v) in pmap {
                            profile.insert(op.clone(), v.as_f64().unwrap_or(0.0));
                        }
                    }
                    runs.insert(
                        inst,
                        RunData {
                            profile,
                            latency_ms: r.req_f64("latency_ms")?,
                        },
                    );
                }
            }
            entries.push(Entry { workload, runs });
        }
        Ok(Corpus { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;

    fn tiny_corpus() -> Corpus {
        // only classic models at small sizes to keep tests fast
        let mut entries = Vec::new();
        for model in [ModelId::LeNet5, ModelId::MnistCnn] {
            for batch in [16usize, 32] {
                let w = Workload::new(model, batch, 32);
                let mut runs = BTreeMap::new();
                for inst in [Instance::G3s, Instance::P3] {
                    let run = sim::run_workload(&w, inst).unwrap();
                    runs.insert(
                        inst,
                        RunData {
                            profile: run.profile.aggregated(),
                            latency_ms: run.latency_ms,
                        },
                    );
                }
                entries.push(Entry { workload: w, runs });
            }
        }
        Corpus { entries }
    }

    #[test]
    fn vocabulary_nonempty_and_sorted() {
        let c = tiny_corpus();
        let v = c.vocabulary();
        assert!(v.len() > 10);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(v, sorted);
        assert!(v.contains(&"Conv2D".to_string()));
    }

    #[test]
    fn split_random_partitions() {
        let c = tiny_corpus();
        let (train, test) = c.split_random(0.25, 1);
        assert_eq!(train.len() + test.len(), c.entries.len());
        assert_eq!(test.len(), 1);
        // deterministic
        let (t2, s2) = c.split_random(0.25, 1);
        assert_eq!(train, t2);
        assert_eq!(test, s2);
    }

    #[test]
    fn split_by_model_isolates() {
        let c = tiny_corpus();
        let (train, test) = c.split_by_model(ModelId::LeNet5);
        assert!(test.iter().all(|&i| c.entries[i].workload.model == ModelId::LeNet5));
        assert!(train.iter().all(|&i| c.entries[i].workload.model != ModelId::LeNet5));
    }

    #[test]
    fn save_load_roundtrip() {
        let c = tiny_corpus();
        let path = std::env::temp_dir().join("repro_corpus_test.json");
        c.save(&path).unwrap();
        let c2 = Corpus::load(&path).unwrap();
        assert_eq!(c.entries.len(), c2.entries.len());
        assert_eq!(c.n_observations(), c2.n_observations());
        let a = &c.entries[0].runs[&Instance::G3s];
        let b = &c2.entries[0].runs[&Instance::G3s];
        assert!((a.latency_ms - b.latency_ms).abs() < 1e-9);
        assert_eq!(a.profile.len(), b.profile.len());
        std::fs::remove_file(&path).ok();
    }
}
