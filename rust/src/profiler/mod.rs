//! TF-Profiler emulation: per-op records and the (operation → aggregated
//! time) view PROFET consumes.
//!
//! Fig 4 of the paper: the profiler reports `Operation`, `Operation
//! details` (layer name, output tensor, memory) and per-layer latencies;
//! PROFET deliberately uses only the *aggregated* (Operation, Time) pairs
//! so the internal architecture is never revealed. [`Profile::aggregated`]
//! is exactly that view — it is the `profile` object a client uploads on
//! the wire (`predict`, `recommend`, and the onboarding `ingest` op all
//! carry it), the feature payload [`crate::features::FeatureSpace`]
//! vectorizes, and the black-box contract that lets one anchor profile
//! price a workload on hardware the client has never touched.

use std::collections::BTreeMap;

/// One profiler line (Fig 4): the full detail view. Everything except
/// `op_name` and the time is "operation details" PROFET refuses to use.
#[derive(Debug, Clone)]
pub struct OpRecord {
    pub op_name: String,
    pub layer_name: String,
    pub output_shape: Vec<usize>,
    pub mem_kb: f64,
    pub time_ms: f64,
}

/// Profiling output for one workload execution on one instance.
#[derive(Debug, Clone, Default)]
pub struct Profile {
    /// Detailed per-layer records (profiler's full table).
    pub records: Vec<OpRecord>,
    /// Mini-batch latency measured *with profiling enabled*, ms.
    pub batch_latency_profiled_ms: f64,
}

impl Profile {
    /// The abstracted (operation name → total ms) feature view — the only
    /// thing a PROFET client uploads (black-box contract).
    pub fn aggregated(&self) -> BTreeMap<String, f64> {
        let mut agg: BTreeMap<String, f64> = BTreeMap::new();
        for r in &self.records {
            *agg.entry(r.op_name.clone()).or_insert(0.0) += r.time_ms;
        }
        agg
    }

    /// Number of distinct operation names.
    pub fn distinct_ops(&self) -> usize {
        self.aggregated().len()
    }

    /// Sum of all per-op times (ms) — close to, but below, the profiled
    /// batch latency (which also contains host gaps).
    pub fn total_op_time_ms(&self) -> f64 {
        self.records.iter().map(|r| r.time_ms).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(op: &str, layer: &str, ms: f64) -> OpRecord {
        OpRecord {
            op_name: op.into(),
            layer_name: layer.into(),
            output_shape: vec![1],
            mem_kb: 1.0,
            time_ms: ms,
        }
    }

    #[test]
    fn aggregation_sums_by_op_name() {
        let p = Profile {
            records: vec![
                rec("Conv2D", "conv2d_0", 50.0),
                rec("Conv2D", "conv2d_1", 45.0),
                rec("Relu", "activation_0", 11.0),
            ],
            batch_latency_profiled_ms: 120.0,
        };
        let agg = p.aggregated();
        assert_eq!(agg["Conv2D"], 95.0);
        assert_eq!(agg["Relu"], 11.0);
        assert_eq!(p.distinct_ops(), 2);
        assert!((p.total_op_time_ms() - 106.0).abs() < 1e-9);
    }
}
