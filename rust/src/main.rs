//! `repro` — the PROFET reproduction CLI (leader entrypoint).
//!
//! Subcommands:
//!   dataset   generate the offline experiment corpus (simulator runs)
//!   train     fit the full PROFET system and save the model directory
//!   predict   one-shot prediction for a (model, batch, pixels) workload
//!   simulate  run the GPU simulator for one workload
//!   eval      regenerate the paper's tables/figures (DESIGN.md index)
//!   serve     start the TCP/JSON prediction service
//!   route     start the sharding route tier over N serve backends
//!   loadgen   open-loop load generator against a live server (BENCH_serve.json)
//!   lint      in-repo invariant linter (docs/ANALYSIS.md rule catalogue)

use anyhow::{anyhow, Context, Result};
use repro::data::Corpus;
use repro::gpu::Instance;
use repro::models::ModelId;
use repro::predictor::Profet;
use repro::sim::{self, Workload};
use repro::{evalx, runtime};
use std::collections::BTreeMap;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut it = rest.iter();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got `{k}`"))?;
            let val = it.next().cloned().unwrap_or_else(|| "true".into());
            flags.insert(key.to_string(), val);
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key}")),
            None => Ok(default),
        }
    }

    fn instance(&self, key: &str, default: Instance) -> Result<Instance> {
        match self.get(key) {
            Some(v) => Instance::from_key(v).ok_or_else(|| anyhow!("unknown instance `{v}`")),
            None => Ok(default),
        }
    }
}

const USAGE: &str = "usage: repro <dataset|train|predict|simulate|eval|serve|route|loadgen> [--flags]
  repro dataset  [--out data/corpus.json] [--instances core|all]
  repro train    [--corpus data/corpus.json] [--out models] [--fast true]
  repro predict  --model VGG16 --batch 32 --pixels 128 \\
                 [--anchor g4dn] [--target p3] [--models models]
  repro simulate --model VGG16 --batch 32 --pixels 128 [--instance p3]
  repro eval     [--exp all|fig9|table4|...] [--out results.txt]
  repro serve    [--addr 127.0.0.1:7878] [--models models] [--pool N]
                 [--queue-cap 512] [--advisor-queue-cap 8] [--max-conns 256]
                 [--reactor-threads N] [--idle-timeout SECS]
                 [--model-dir-watch SECS] [--trace-slow-ms MS]
                 [--trace-sample N] [--default-deadline-ms MS]
                 [--failpoints 'name=action;...']
  repro route    --backends a:7878,b:7878 [--addr 127.0.0.1:7979]
                 [--probe-interval-ms 500] [--fail-threshold 2]
                 [--call-timeout-ms 5000] [--failpoints 'name=action;...']
  repro loadgen  [--addr 127.0.0.1:7878] [--targets a,b,c] [--rate 200]
                 [--duration 10] [--conns 16] [--predict-pct 90]
                 [--anchor g4dn] [--target p3] [--connect-retries 5]
                 [--out BENCH_serve.json] [--strict]
  repro lint     [--root PATH] [--json] [--audit]";

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "dataset" => cmd_dataset(&args),
        "train" => cmd_train(&args),
        "predict" => cmd_predict(&args),
        "simulate" => cmd_simulate(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "loadgen" => cmd_loadgen(&args),
        "lint" => cmd_lint(&args),
        other => {
            println!("{USAGE}");
            Err(anyhow!("unknown command `{other}`"))
        }
    }
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let out = args.get_or("out", "data/corpus.json");
    let instances: &[Instance] = match args.get_or("instances", "all").as_str() {
        "core" => &Instance::CORE,
        _ => &Instance::ALL,
    };
    eprintln!("generating corpus over {instances:?} ...");
    let corpus = Corpus::generate(instances);
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    corpus.save(&out)?;
    println!(
        "wrote {out}: {} workloads, {} observations, {} distinct ops",
        corpus.entries.len(),
        corpus.n_observations(),
        corpus.vocabulary().len()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = runtime::load_default()?;
    let corpus_path = args.get_or("corpus", "data/corpus.json");
    let corpus = if std::path::Path::new(&corpus_path).exists() {
        Corpus::load(&corpus_path)?
    } else {
        eprintln!("{corpus_path} not found — generating in-memory corpus");
        Corpus::generate(&Instance::ALL)
    };
    let (train_idx, _) = corpus.split_random(0.2, evalx::SPLIT_SEED);
    let mut opts = repro::predictor::TrainOptions {
        anchors: Instance::CORE.to_vec(),
        targets: Instance::ALL.to_vec(),
        ..Default::default()
    };
    if args.get("fast").is_some() {
        opts.n_trees = 25;
        opts.dnn_epochs = 15;
    }
    eprintln!(
        "training PROFET: {} anchors x {} targets ...",
        opts.anchors.len(),
        opts.targets.len()
    );
    let t0 = std::time::Instant::now();
    let profet = Profet::train(&rt, &corpus, &train_idx, &opts)?;
    let out = args.get_or("out", "models");
    profet.save(&out)?;
    println!(
        "trained {} cross-instance ensembles + {} batch/pixel models in {:.1}s -> {out}/",
        profet.cross.len(),
        profet.scale.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let rt = runtime::load_default()?;
    let model = ModelId::from_name(&args.get_or("model", "VGG16"))
        .ok_or_else(|| anyhow!("unknown model (try VGG16, ResNet50, ...)"))?;
    let batch = args.usize_or("batch", 32)?;
    let pixels = args.usize_or("pixels", 128)?;
    let anchor = args.instance("anchor", Instance::G4dn)?;
    let target = args.instance("target", Instance::P3)?;
    let model_dir = args.get_or("models", "models");
    let profet = Profet::load(&model_dir)
        .with_context(|| format!("loading {model_dir}/ — run `repro train` first"))?;

    // simulate the client-side anchor profiling run
    let w = Workload::new(model, batch, pixels);
    let run = sim::run_workload(&w, anchor)
        .ok_or_else(|| anyhow!("workload not executable on {anchor}"))?;
    let (pred, member) = profet.predict_cross(
        &rt,
        anchor,
        target,
        &run.profile.aggregated(),
        run.latency_ms,
    )?;
    println!("workload       : {} b={batch} px={pixels}", model.name());
    println!("anchor         : {anchor} ({:.2} ms measured)", run.latency_ms);
    println!("prediction     : {pred:.2} ms on {target} (median member: {})", member.name());
    if let Some(truth) = sim::run_workload(&w, target) {
        let err = 100.0 * (pred - truth.latency_ms).abs() / truth.latency_ms;
        println!("simulator truth: {:.2} ms  (APE {err:.1}%)", truth.latency_ms);
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = ModelId::from_name(&args.get_or("model", "VGG16"))
        .ok_or_else(|| anyhow!("unknown model"))?;
    let batch = args.usize_or("batch", 32)?;
    let pixels = args.usize_or("pixels", 128)?;
    let w = Workload::new(model, batch, pixels);
    let instances: Vec<Instance> = match args.get("instance") {
        Some(v) => vec![Instance::from_key(v).ok_or_else(|| anyhow!("unknown instance"))?],
        None => Instance::ALL.to_vec(),
    };
    println!("{} b={batch} px={pixels}:", model.name());
    for g in instances {
        match sim::run_workload(&w, g) {
            Some(r) => {
                let agg = r.profile.aggregated();
                let top: Vec<String> = {
                    let mut v: Vec<(&String, &f64)> = agg.iter().collect();
                    v.sort_by(|a, b| b.1.partial_cmp(a.1).unwrap());
                    v.iter()
                        .take(3)
                        .map(|(k, t)| format!("{k}={t:.1}ms"))
                        .collect()
                };
                println!(
                    "  {:5} {:9.2} ms  (profiled {:.2} ms; {} ops; top: {})",
                    g.key(),
                    r.latency_ms,
                    r.profile.batch_latency_profiled_ms,
                    agg.len(),
                    top.join(", ")
                );
            }
            None => println!("  {:5} not executable (OOM or model constraint)", g.key()),
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let exp = args.get_or("exp", "all");
    let mut ctx = evalx::Ctx::build()?;
    let t0 = std::time::Instant::now();
    let report = evalx::run(&exp, &mut ctx)?;
    println!("{report}");
    eprintln!("eval `{exp}` finished in {:.1}s", t0.elapsed().as_secs_f64());
    if let Some(out) = args.get("out") {
        std::fs::write(out, &report)?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let model_dir = args.get_or("models", "models");
    let defaults = repro::coordinator::ServeOptions::default();
    // chaos injection (docs/RESILIENCE.md): `REPRO_FAILPOINTS` first, then
    // `--failpoints` on top (the flag wins on a name collision)
    repro::util::failpoint::init_from_env().map_err(|e| anyhow!("REPRO_FAILPOINTS: {e}"))?;
    if let Some(spec) = args.get("failpoints") {
        repro::util::failpoint::configure_from_str(spec)
            .map_err(|e| anyhow!("--failpoints: {e}"))?;
    }
    // `--default-deadline-ms 250` sheds any engine job still queued 250 ms
    // after admission with a structured `deadline_exceeded`; omitted =
    // no deadline (jobs wait out the queue)
    let default_deadline = match args.get("default-deadline-ms") {
        None => defaults.pool.default_deadline,
        Some(v) => {
            let ms: u64 = v.parse().with_context(|| "--default-deadline-ms")?;
            anyhow::ensure!(ms >= 1, "--default-deadline-ms must be at least 1");
            Some(std::time::Duration::from_millis(ms))
        }
    };
    // `--model-dir-watch 5` polls every 5 s; a bare `--model-dir-watch`
    // (no value) uses the 5 s default; 0 is rejected (it would busy-loop
    // the watcher and the trainer lane)
    let model_dir_watch = match args.get("model-dir-watch") {
        None => None,
        Some("true") => Some(std::time::Duration::from_secs(5)),
        Some(v) => {
            let secs: u64 = v.parse().with_context(|| "--model-dir-watch")?;
            anyhow::ensure!(secs >= 1, "--model-dir-watch must be at least 1 second");
            Some(std::time::Duration::from_secs(secs))
        }
    };
    // `--idle-timeout 300` evicts keep-alive connections idle for 5 min;
    // omitted = never evict (idle connections only cost a file descriptor)
    let idle_timeout = match args.get("idle-timeout") {
        None => None,
        Some(v) => {
            let secs: u64 = v.parse().with_context(|| "--idle-timeout")?;
            anyhow::ensure!(secs >= 1, "--idle-timeout must be at least 1 second");
            Some(std::time::Duration::from_secs(secs))
        }
    };
    let opts = repro::coordinator::ServeOptions {
        pool: repro::coordinator::PoolOptions {
            // 0 = auto (available parallelism)
            predict_lanes: args.usize_or("pool", defaults.pool.predict_lanes)?,
            predict_queue_cap: args.usize_or("queue-cap", defaults.pool.predict_queue_cap)?,
            advisor_queue_cap: args
                .usize_or("advisor-queue-cap", defaults.pool.advisor_queue_cap)?,
            trainer_queue_cap: args
                .usize_or("trainer-queue-cap", defaults.pool.trainer_queue_cap)?,
            onboard: defaults.pool.onboard.clone(),
            // slow-request dumps to stderr past this threshold; tracing
            // samples every Nth engine request (0 disables)
            trace_slow_ms: match args.get("trace-slow-ms") {
                None => defaults.pool.trace_slow_ms,
                Some(v) => v.parse().with_context(|| "--trace-slow-ms")?,
            },
            trace_sample: match args.get("trace-sample") {
                None => defaults.pool.trace_sample,
                Some(v) => v.parse().with_context(|| "--trace-sample")?,
            },
            default_deadline,
        },
        max_connections: args.usize_or("max-conns", defaults.max_connections)?,
        // 0 = auto (scales with available parallelism)
        reactor_threads: args.usize_or("reactor-threads", defaults.reactor_threads)?,
        idle_timeout,
        write_stall_timeout: defaults.write_stall_timeout,
        model_dir_watch,
    };
    let handle = repro::coordinator::serve_with(
        &addr,
        runtime::default_artifact_dir(),
        model_dir.into(),
        &opts,
    )?;
    println!(
        "PROFET service listening on {} ({} predict lanes + 1 advisor + 1 trainer lane, \
         {} reactor threads, {} max connections{})",
        handle.addr,
        opts.pool.resolved_predict_lanes(),
        opts.resolved_reactor_threads(),
        opts.max_connections,
        match opts.model_dir_watch {
            Some(d) => format!(", model dir watched every {}s", d.as_secs()),
            None => String::new(),
        }
    );
    println!("protocol: newline-delimited JSON; try:");
    println!(r#"  {{"op":"health"}}"#);
    println!(r#"  {{"op":"predict","anchor":"g4dn","target":"p3","anchor_latency_ms":120.0,"profile":{{"Conv2D":40.0}}}}"#);
    println!(r#"  {{"op":"recommend","anchor":"g4dn","pixels":64,"profile_bmin":{{"Conv2D":8.0}},"anchor_lat_bmin":20.0,"profile_bmax":{{"Conv2D":90.0}},"anchor_lat_bmax":200.0,"include_spot":true}}"#);
    println!(r#"  {{"op":"stats"}}  (registry_epoch / last_reload track hot reloads)"#);
    println!(r#"  {{"op":"metrics"}}  (per-stage latency histograms + slow-request traces)"#);
    println!("(full op reference in docs/PROTOCOL.md)");
    // park forever
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_route(args: &Args) -> Result<()> {
    // same chaos surface as `serve` — the route tier has its own
    // failpoints (cluster.peer.send[.<addr>], docs/RESILIENCE.md)
    repro::util::failpoint::init_from_env().map_err(|e| anyhow!("REPRO_FAILPOINTS: {e}"))?;
    if let Some(spec) = args.get("failpoints") {
        repro::util::failpoint::configure_from_str(spec)
            .map_err(|e| anyhow!("--failpoints: {e}"))?;
    }
    let backends: Vec<String> = args
        .get("backends")
        .ok_or_else(|| anyhow!("repro route needs --backends a:port,b:port"))?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!backends.is_empty(), "--backends must list at least one address");
    let probe_ms = args.usize_or("probe-interval-ms", 500)? as u64;
    anyhow::ensure!(probe_ms >= 1, "--probe-interval-ms must be at least 1");
    let call_timeout_ms = args.usize_or("call-timeout-ms", 5000)? as u64;
    anyhow::ensure!(call_timeout_ms >= 1, "--call-timeout-ms must be at least 1");
    let opts = repro::coordinator::RouteOptions {
        addr: args.get_or("addr", "127.0.0.1:7979"),
        backends,
        probe_interval: std::time::Duration::from_millis(probe_ms),
        fail_threshold: args.usize_or("fail-threshold", 2)? as u32,
        call_timeout: std::time::Duration::from_millis(call_timeout_ms),
    };
    let n = opts.backends.len();
    let handle = repro::coordinator::serve_cluster(opts)?;
    println!(
        "PROFET route tier listening on {} ({n} backends, rendezvous-sharded by (anchor, target))",
        handle.addr()
    );
    println!(r#"protocol: same newline-delimited JSON as serve, plus {{"op":"cluster_stats"}}"#);
    println!("(full op reference in docs/PROTOCOL.md)");
    // park forever (the handle's accept/prober threads do the work)
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_lint(args: &Args) -> Result<()> {
    // --root overrides; otherwise walk up from cwd to the directory
    // holding both rust/src and docs (works from the repo root or from
    // inside rust/, e.g. under `cargo run`)
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => {
            let mut dir = std::env::current_dir()?;
            loop {
                if dir.join("rust/src").is_dir() && dir.join("docs").is_dir() {
                    break dir;
                }
                if !dir.pop() {
                    return Err(anyhow!(
                        "cannot find repo root (rust/src + docs) above cwd — pass --root"
                    ));
                }
            }
        }
    };
    let report = repro::analysis::run(&root)
        .with_context(|| format!("linting {}", root.display()))?;
    if args.get("audit").is_some() {
        print!("{}", report.render_audit());
        return Ok(());
    }
    if args.get("json").is_some() {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    if report.hard_count() > 0 {
        anyhow::bail!("lint failed with {} hard finding(s)", report.hard_count());
    }
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let rate: f64 = args
        .get_or("rate", "200")
        .parse()
        .with_context(|| "--rate")?;
    let duration_s: f64 = args
        .get_or("duration", "10")
        .parse()
        .with_context(|| "--duration")?;
    anyhow::ensure!(duration_s > 0.0, "--duration must be positive");
    let predict_pct = args.usize_or("predict-pct", 90)?;
    anyhow::ensure!(predict_pct <= 100, "--predict-pct must be 0..=100");
    let opts = repro::loadgen::LoadgenOptions {
        addr: args.get_or("addr", "127.0.0.1:7878"),
        rate,
        duration: std::time::Duration::from_secs_f64(duration_s),
        conns: args.usize_or("conns", 16)?,
        predict_pct: predict_pct as u32,
        anchor: args.get_or("anchor", "g4dn"),
        target: args.get_or("target", "p3"),
        connect_retries: args.usize_or("connect-retries", 5)?,
        targets: args
            .get("targets")
            .map(|t| {
                t.split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(String::from)
                    .collect()
            })
            .unwrap_or_default(),
    };
    eprintln!(
        "loadgen: open-loop {} rps for {:.1}s over {} conns ({}% predict) -> {}",
        opts.rate, duration_s, opts.conns, opts.predict_pct, opts.addr
    );
    if !opts.targets.is_empty() {
        eprintln!(
            "loadgen: cluster mode — probing {} backend(s) for per-shard deltas",
            opts.targets.len()
        );
    }
    let report = repro::loadgen::run(&opts)?;
    let out = args.get_or("out", "BENCH_serve.json");
    let mut text = report.to_json().to_string();
    text.push('\n');
    std::fs::write(&out, &text).with_context(|| format!("writing {out}"))?;
    println!(
        "sent {} / completed {} (ok {}, errors {}, overloaded {}, dropped {}, unsent {})",
        report.sent, report.completed, report.ok, report.errors, report.overloaded,
        report.dropped, report.unsent
    );
    println!(
        "throughput {:.1} rps; latency ms p50 {:.2} p95 {:.2} p99 {:.2} p999 {:.2} max {:.2}",
        report.throughput_rps,
        report.latency.p50,
        report.latency.p95,
        report.latency.p99,
        report.latency.p999,
        report.latency.max
    );
    println!("wrote {out}");
    if args.get("strict").is_some() {
        // CI gate: re-parse what we just wrote, then fail on violations
        let parsed = repro::util::Json::parse(text.trim())
            .with_context(|| format!("{out} is not valid JSON"))?;
        anyhow::ensure!(
            parsed.req_str("schema").ok() == Some("profet.loadgen.v2"),
            "{out} missing schema marker"
        );
        let violations = report.strict_violations();
        if !violations.is_empty() {
            for v in &violations {
                eprintln!("strict violation: {v}");
            }
            anyhow::bail!("loadgen --strict failed with {} violation(s)", violations.len());
        }
    }
    Ok(())
}
