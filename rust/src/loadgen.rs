//! Open-loop load generator for the serving tier (`repro loadgen`).
//!
//! **Open-loop, not closed-loop**: requests are scheduled on a fixed
//! arrival clock (request *k* fires at `k / rate` seconds after start,
//! round-robin across the connection fleet) and the generator never
//! waits for a response before sending the next request. A slow server
//! therefore accumulates genuine queueing delay instead of silently
//! throttling the offered load — and every latency sample is measured
//! from the request's **scheduled** send instant, so coordinated
//! omission cannot hide a stall: if the generator (or the server) falls
//! behind, the backlog shows up in the tail percentiles where it
//! belongs.
//!
//! Each connection runs a writer thread (sends at the schedule) and a
//! reader thread (pairs response lines FIFO with in-flight requests —
//! the protocol answers requests on one connection in order, so FIFO
//! pairing is exact). The mix is deterministic: request `k` is a
//! `predict` iff `k % 100 < predict_pct`, with `anchor_latency_ms`
//! cycling over a small set of distinct values so the run exercises both
//! the cold engine path and the warm zero-allocation cache path.
//!
//! [`LoadgenReport`] aggregates p50/p95/p99/p999/mean/max latency,
//! throughput, and error/overload/drop counts, and serializes to the
//! documented `BENCH_serve.json` schema (`profet.loadgen.v2` — see
//! README §Loadgen):
//!
//! ```json
//! {
//!   "schema": "profet.loadgen.v2",
//!   "config": {"addr": "...", "rate": 500.0, "duration_s": 10.0,
//!              "conns": 16, "predict_pct": 90},
//!   "totals": {"sent": 5000, "completed": 5000, "ok": 4990,
//!              "errors": 10, "overloaded": 0, "dropped": 0, "unsent": 0},
//!   "elapsed_s": 10.02,
//!   "throughput_rps": 499.0,
//!   "latency_ms": {"p50": 0.4, "p95": 1.1, "p99": 2.3, "p999": 7.9,
//!                  "mean": 0.6, "max": 12.0},
//!   "per_op": {"predict": {"count": 4500, "ok": 4500, "p50": 0.3, "p99": 1.9},
//!              "recommend": {"count": 500, "ok": 490, "p50": 2.0, "p99": 6.5}},
//!   "server": {"requests": 5000, "cache_hits": 4484, "cache_misses": 16,
//!              "cache_hit_ratio": 0.996, "evictions": 0, "overloaded": 0,
//!              "queue_wait_ms": {"count": 516, "p50": 0.3, "p99": 2.1, "max": 4.0},
//!              "execute_ms": {"count": 516, "p50": 0.8, "p99": 3.0, "max": 6.2}}
//! }
//! ```
//!
//! The `server` section is the **server-side delta** of this run: the
//! generator captures a `stats` + `metrics` snapshot (see
//! `docs/OBSERVABILITY.md`) over a dedicated connection before the first
//! arrival and again after the last completion, and reports the
//! difference — queue-wait and execute stage histograms (all ops, warm +
//! cold, merged), cache hit ratio, evictions, and shed load as the
//! *server* saw them, alongside the client-observed round-trip
//! percentiles above. Against a server that cannot answer `metrics` the
//! section is omitted (the rest of the report is unaffected).
//!
//! A `dropped` request is one the server accepted bytes for but never
//! answered (its connection died first) — the graceful-drain contract
//! says this must be zero, and `--strict` turns any violation into a
//! nonzero exit for CI.
//!
//! **Multi-endpoint mode** (`--targets a,b,c`): when driving a
//! `repro route` tier, pass the backend addresses and the report gains
//! a `cluster` section with each backend's `stats` request delta:
//!
//! ```json
//! {
//!   "cluster": {"backends": [{"addr": "a", "requests": 1700,
//!                             "throughput_rps": 170.0, "share": 0.34}],
//!              "shard_skew": 1.02}
//! }
//! ```
//!
//! `share` is the backend's fraction of the fleet's request delta and
//! `shard_skew` is the hottest backend's share times the backend count
//! (1.0 = perfectly even, N = everything on one backend). A backend
//! that answers no `stats` probe contributes 0 — visible as share 0.

use crate::obs::HistSnapshot;
use crate::util::{quantile, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// Request kinds the generator mixes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Predict,
    Recommend,
}

impl OpKind {
    pub fn key(self) -> &'static str {
        match self {
            OpKind::Predict => "predict",
            OpKind::Recommend => "recommend",
        }
    }
}

/// Generator configuration (`repro loadgen` flags).
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Server address (`host:port`).
    pub addr: String,
    /// Offered arrival rate, requests/second (open-loop clock).
    pub rate: f64,
    /// Run length; `floor(rate * duration)` requests are scheduled.
    pub duration: Duration,
    /// Connection fleet size; arrivals round-robin across it.
    pub conns: usize,
    /// Percentage of requests that are `predict` (0..=100); the rest
    /// are `recommend` sweeps.
    pub predict_pct: u32,
    /// Anchor instance key for generated requests.
    pub anchor: String,
    /// Target instance key for generated `predict` requests.
    pub target: String,
    /// Bounded attempts for each connection's *initial* connect
    /// (`--connect-retries`): attempt `i` backs off `10ms * 2^i` (capped
    /// at 2 s) plus a deterministic per-connection jitter, so a fleet
    /// racing a server still binding its listener spreads its
    /// reconnects. `0` is treated as 1 (a single attempt, no retry).
    pub connect_retries: usize,
    /// Multi-endpoint mode (`--targets a,b,c`): backend addresses probed
    /// with `stats` before/after the run. Load still goes to `addr` (the
    /// route tier); the per-backend request deltas become the report's
    /// `cluster` section (per-backend throughput + shard skew). Empty =
    /// single-endpoint mode, no `cluster` section.
    pub targets: Vec<String>,
}

impl Default for LoadgenOptions {
    fn default() -> LoadgenOptions {
        LoadgenOptions {
            addr: "127.0.0.1:7878".into(),
            rate: 200.0,
            duration: Duration::from_secs(10),
            conns: 16,
            predict_pct: 90,
            anchor: "g4dn".into(),
            target: "p3".into(),
            connect_retries: 5,
            targets: Vec::new(),
        }
    }
}

/// Latency percentile summary, milliseconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub mean: f64,
    pub max: f64,
}

/// Per-op-kind slice of the run.
#[derive(Debug, Clone, Default)]
pub struct OpSummary {
    pub count: u64,
    pub ok: u64,
    pub p50: f64,
    pub p99: f64,
}

/// Everything a run measured; serializes to `BENCH_serve.json`.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub opts: LoadgenOptions,
    /// Requests written to a socket (each is owed a response).
    pub sent: u64,
    /// Responses received (ok + errors + overloaded).
    pub completed: u64,
    pub ok: u64,
    /// Structured/engine errors (`"ok":false`, not overload).
    pub errors: u64,
    /// `kind:"overloaded"` responses (connection budget or full lanes).
    pub overloaded: u64,
    /// Sent but never answered — the connection died first. The drain
    /// contract says this must be zero.
    pub dropped: u64,
    /// Never written (connect/write failure before the request left).
    pub unsent: u64,
    /// Wall time from the schedule origin to the last completion.
    pub elapsed_s: f64,
    pub throughput_rps: f64,
    pub latency: LatencySummary,
    /// Per-kind breakdown, keyed by [`OpKind::key`].
    pub per_op: Vec<(OpKind, OpSummary)>,
    /// Server-side delta over the run (`stats` + `metrics` snapshots
    /// before/after); `None` when the target could not answer them.
    pub server: Option<ServerSnapshot>,
    /// Per-backend request deltas in `--targets` multi-endpoint mode
    /// (empty outside it). A backend that answered no `stats` probe
    /// contributes 0.
    pub cluster: Vec<ClusterSample>,
}

/// One backend's contribution to a `--targets` run: its `stats`
/// `requests` delta between the pre- and post-run probes.
#[derive(Debug, Clone, Default)]
pub struct ClusterSample {
    pub addr: String,
    pub requests: u64,
}

/// Server-side counters and stage histograms from one `stats` +
/// `metrics` capture — or, via [`ServerSnapshot::delta_from`], the
/// difference between two captures (what one run contributed).
#[derive(Debug, Clone, Default)]
pub struct ServerSnapshot {
    pub requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Idle-timeout connection evictions.
    pub evictions: u64,
    /// Requests shed with `kind:"overloaded"`.
    pub overloaded: u64,
    /// `queue_wait` stage histogram, every op × warm/cold cell merged.
    pub queue_wait: HistSnapshot,
    /// `execute` stage histogram, every op × warm/cold cell merged.
    pub execute: HistSnapshot,
}

impl ServerSnapshot {
    /// Capture over a dedicated blocking connection. `None` on any
    /// connect/protocol failure — an older server without the `metrics`
    /// op degrades the report, never the run.
    pub fn fetch(addr: &str) -> Option<ServerSnapshot> {
        let mut stream = TcpStream::connect(addr).ok()?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone().ok()?);
        let stats = fetch_op(&mut stream, &mut reader, "{\"op\":\"stats\"}\n")?;
        let metrics = fetch_op(&mut stream, &mut reader, "{\"op\":\"metrics\"}\n")?;
        let n = |k: &str| stats.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
        Some(ServerSnapshot {
            requests: n("requests"),
            cache_hits: n("cache_hits"),
            cache_misses: n("cache_misses"),
            evictions: n("evictions"),
            overloaded: n("overloaded"),
            queue_wait: stage_hist(&metrics, "queue_wait"),
            execute: stage_hist(&metrics, "execute"),
        })
    }

    /// What happened between `before` and `self`: counter deltas and
    /// histogram windows ([`HistSnapshot::diff_from`]).
    pub fn delta_from(&self, before: &ServerSnapshot) -> ServerSnapshot {
        ServerSnapshot {
            requests: self.requests.saturating_sub(before.requests),
            cache_hits: self.cache_hits.saturating_sub(before.cache_hits),
            cache_misses: self.cache_misses.saturating_sub(before.cache_misses),
            evictions: self.evictions.saturating_sub(before.evictions),
            overloaded: self.overloaded.saturating_sub(before.overloaded),
            queue_wait: self.queue_wait.diff_from(&before.queue_wait),
            execute: self.execute.diff_from(&before.execute),
        }
    }

    /// Cache hit ratio over the captured window (0 when no predict
    /// touched the cache).
    pub fn cache_hit_ratio(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// One request/response exchange on the snapshot connection.
fn fetch_op(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Option<Json> {
    stream.write_all(line.as_bytes()).ok()?;
    let mut resp = String::new();
    match reader.read_line(&mut resp) {
        Ok(nread) if nread > 0 => Json::parse(resp.trim()).ok(),
        _ => None,
    }
}

/// Merge every cell of the named stage in a `metrics` reply into one
/// histogram. Cells are sparse `[bucket_index, count]` pairs over the
/// shared log-linear bucket table, so merging loses nothing.
fn stage_hist(metrics: &Json, stage: &str) -> HistSnapshot {
    let mut merged = HistSnapshot::empty();
    let Some(Json::Arr(stages)) = metrics.get("stages") else {
        return merged;
    };
    for s in stages {
        if s.get("stage").and_then(Json::as_str) != Some(stage) {
            continue;
        }
        let Some(Json::Arr(cells)) = s.get("cells") else {
            continue;
        };
        for cell in cells {
            merged.merge(&cell_hist(cell));
        }
    }
    merged
}

/// Reconstruct one cell's [`HistSnapshot`] from its wire form.
fn cell_hist(cell: &Json) -> HistSnapshot {
    let mut buckets: Vec<(u32, u64)> = Vec::new();
    let mut count = 0u64;
    if let Some(Json::Arr(bs)) = cell.get("buckets") {
        for b in bs {
            let Json::Arr(pair) = b else { continue };
            let idx = pair.first().and_then(Json::as_f64);
            let n = pair.get(1).and_then(Json::as_f64);
            if let (Some(idx), Some(n)) = (idx, n) {
                buckets.push((idx as u32, n as u64));
                count += n as u64;
            }
        }
    }
    buckets.sort_unstable_by_key(|&(i, _)| i);
    let sum_ms = cell.get("sum_ms").and_then(Json::as_f64).unwrap_or(0.0);
    HistSnapshot {
        buckets,
        count,
        sum_ns: (sum_ms.max(0.0) * 1e6).round() as u64,
    }
}

/// Deterministic open-loop mix: request `k` is a predict iff
/// `k % 100 < predict_pct`.
pub fn op_for(k: usize, predict_pct: u32) -> OpKind {
    if (k % 100) < predict_pct as usize {
        OpKind::Predict
    } else {
        OpKind::Recommend
    }
}

/// The wire line for request `k` (newline-terminated). Predicts cycle
/// `anchor_latency_ms` over 16 distinct values: the first pass misses
/// into the engine, repeats hit the warm zero-allocation cache path —
/// both sides of the serving tier are on the clock.
pub fn request_line(kind: OpKind, k: usize, anchor: &str, target: &str) -> String {
    match kind {
        OpKind::Predict => format!(
            "{{\"op\":\"predict\",\"anchor\":\"{anchor}\",\"target\":\"{target}\",\
             \"anchor_latency_ms\":{lat:.1},\
             \"profile\":{{\"Conv2D\":286.0,\"Relu\":26.0}}}}\n",
            lat = 50.0 + (k % 16) as f64,
        ),
        OpKind::Recommend => format!(
            "{{\"op\":\"recommend\",\"anchor\":\"{anchor}\",\"pixels\":64,\
             \"profile_bmin\":{{\"Conv2D\":80.0}},\"anchor_lat_bmin\":95.0,\
             \"profile_bmax\":{{\"Conv2D\":900.0}},\"anchor_lat_bmax\":1020.0,\
             \"top_k\":4}}\n",
        ),
    }
}

/// How one completed request ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Ok,
    Overloaded,
    Error,
}

fn classify(line: &str) -> Outcome {
    if line.contains("\"ok\":true") {
        Outcome::Ok
    } else if line.contains("\"kind\":\"overloaded\"") {
        Outcome::Overloaded
    } else {
        Outcome::Error
    }
}

/// One answered request: kind, scheduled offset, measured latency.
struct Sample {
    kind: OpKind,
    latency_ms: f64,
    outcome: Outcome,
    /// Offset of the completion from the schedule origin (throughput).
    done_at_s: f64,
}

/// What one connection's writer/reader pair produced.
#[derive(Default)]
struct ConnResult {
    samples: Vec<Sample>,
    dropped: u64,
    unsent: u64,
}

/// Run the generator against a live server. Blocks for roughly
/// `duration` plus response drain time.
pub fn run(opts: &LoadgenOptions) -> Result<LoadgenReport> {
    anyhow::ensure!(opts.rate > 0.0, "--rate must be positive");
    anyhow::ensure!(opts.predict_pct <= 100, "--predict-pct must be 0..=100");
    let total = ((opts.rate * opts.duration.as_secs_f64()).floor() as usize).max(1);
    let conns = opts.conns.max(1).min(total);

    // server-side baseline, captured before the first arrival so the
    // post-run delta isolates exactly this run's contribution
    let server_before = ServerSnapshot::fetch(&opts.addr);
    let targets_before: Vec<Option<ServerSnapshot>> =
        opts.targets.iter().map(|t| ServerSnapshot::fetch(t)).collect();

    // schedule origin slightly in the future so every fleet thread is
    // up before the first arrival is due
    let start = Instant::now() + Duration::from_millis(50);
    let mut handles = Vec::with_capacity(conns);
    for c in 0..conns {
        let addr = opts.addr.clone();
        let anchor = opts.anchor.clone();
        let target = opts.target.clone();
        let rate = opts.rate;
        let predict_pct = opts.predict_pct;
        let retries = opts.connect_retries;
        let handle = std::thread::Builder::new()
            .name(format!("loadgen-conn-{c}"))
            .spawn(move || {
                conn_worker(
                    &addr, start, c, conns, total, rate, predict_pct, &anchor, &target, retries,
                )
            })
            .context("spawning loadgen connection worker")?;
        handles.push(handle);
    }

    let mut samples: Vec<Sample> = Vec::with_capacity(total);
    let mut dropped = 0u64;
    let mut unsent = 0u64;
    for h in handles {
        let r = h.join().unwrap_or_default();
        samples.extend(r.samples);
        dropped += r.dropped;
        unsent += r.unsent;
    }
    let server = match (server_before, ServerSnapshot::fetch(&opts.addr)) {
        (Some(before), Some(after)) => Some(after.delta_from(&before)),
        _ => None,
    };
    let cluster: Vec<ClusterSample> = opts
        .targets
        .iter()
        .zip(targets_before)
        .map(|(t, before)| ClusterSample {
            addr: t.clone(),
            requests: match (before, ServerSnapshot::fetch(t)) {
                (Some(b), Some(a)) => a.delta_from(&b).requests,
                _ => 0,
            },
        })
        .collect();
    let mut report = aggregate(opts, total as u64, samples, dropped, unsent);
    report.server = server;
    report.cluster = cluster;
    Ok(report)
}

/// One connection of the fleet: writer sends its round-robin share of
/// the schedule, reader pairs response lines FIFO and timestamps them.
#[allow(clippy::too_many_arguments)]
fn conn_worker(
    addr: &str,
    start: Instant,
    conn_idx: usize,
    conns: usize,
    total: usize,
    rate: f64,
    predict_pct: u32,
    anchor: &str,
    target: &str,
    connect_retries: usize,
) -> ConnResult {
    let my_count = (conn_idx..total).step_by(conns).count() as u64;
    let stream = match connect_with_retries(addr, connect_retries, conn_idx) {
        Some(s) => s,
        None => {
            return ConnResult {
                unsent: my_count,
                ..ConnResult::default()
            }
        }
    };
    stream.set_nodelay(true).ok();
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            return ConnResult {
                unsent: my_count,
                ..ConnResult::default()
            }
        }
    };

    // scheduled-offset + kind of every request in flight, FIFO
    let (meta_tx, meta_rx): (Sender<(Duration, OpKind)>, Receiver<(Duration, OpKind)>) = channel();
    let reader = std::thread::spawn(move || read_responses(reader_stream, start, meta_rx));

    let mut stream = stream;
    let mut unsent = 0u64;
    for k in (conn_idx..total).step_by(conns) {
        let offset = Duration::from_secs_f64(k as f64 / rate);
        let kind = op_for(k, predict_pct);
        let line = request_line(kind, k, anchor, target);
        // open-loop clock: sleep to the arrival instant, never to the
        // previous response
        let sched = start + offset;
        let now = Instant::now();
        if sched > now {
            std::thread::sleep(sched - now);
        }
        if meta_tx.send((offset, kind)).is_err() {
            unsent += 1;
            continue; // reader died (connection reset) — count the rest
        }
        if stream.write_all(line.as_bytes()).is_err() {
            // the meta above is now owed a response that cannot come;
            // the reader will see EOF and count it dropped
            unsent += (conn_idx..total).step_by(conns).filter(|&j| j > k).count() as u64;
            break;
        }
    }
    drop(meta_tx); // reader drains in-flight metas, then stops
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut result = reader.join().unwrap_or_default();
    result.unsent += unsent;
    result
}

/// Connect with bounded retries: on a refused/failed connect, sleep the
/// [`retry_backoff`] schedule and try again, up to `attempts` total
/// connect calls (`0` is treated as 1). Retries cover the *initial*
/// connect only — once a stream exists, mid-run failures stay failures
/// (they are part of what the run measures).
fn connect_with_retries(addr: &str, attempts: usize, conn_idx: usize) -> Option<TcpStream> {
    let attempts = attempts.max(1);
    for attempt in 0..attempts {
        match TcpStream::connect(addr) {
            Ok(s) => return Some(s),
            Err(_) if attempt + 1 < attempts => {
                std::thread::sleep(retry_backoff(addr, conn_idx, attempt));
            }
            Err(_) => return None,
        }
    }
    None
}

/// Backoff before retrying attempt `attempt` (0-based): `10ms * 2^attempt`
/// capped at 2 s, plus up to 25% deterministic jitter seeded by fnv1a
/// over (addr, connection index, attempt) — the fleet's retries
/// de-synchronize without a random source, and a given run's schedule is
/// reproducible.
fn retry_backoff(addr: &str, conn_idx: usize, attempt: usize) -> Duration {
    let base_ms = 10u64.saturating_mul(1 << attempt.min(16)).min(2_000);
    let seed = crate::util::fnv1a(format!("{addr}#{conn_idx}#{attempt}").as_bytes());
    let jitter_ms = seed % (base_ms / 4 + 1);
    Duration::from_millis(base_ms + jitter_ms)
}

fn read_responses(
    stream: TcpStream,
    start: Instant,
    meta_rx: Receiver<(Duration, OpKind)>,
) -> ConnResult {
    let mut reader = BufReader::new(stream);
    let mut result = ConnResult::default();
    let mut line = String::new();
    while let Ok((offset, kind)) = meta_rx.recv() {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => {
                // connection died with requests in flight: this one and
                // everything still queued behind it lost its response
                result.dropped += 1;
                while meta_rx.recv().is_ok() {
                    result.dropped += 1;
                }
                return result;
            }
            Ok(_) => {
                let done = start.elapsed();
                let latency = done.saturating_sub(offset);
                result.samples.push(Sample {
                    kind,
                    latency_ms: latency.as_secs_f64() * 1e3,
                    outcome: classify(&line),
                    done_at_s: done.as_secs_f64(),
                });
            }
        }
    }
    result
}

fn summarize(latencies: &[f64]) -> LatencySummary {
    if latencies.is_empty() {
        return LatencySummary::default();
    }
    LatencySummary {
        p50: quantile(latencies, 0.50),
        p95: quantile(latencies, 0.95),
        p99: quantile(latencies, 0.99),
        p999: quantile(latencies, 0.999),
        mean: crate::util::mean(latencies),
        max: latencies.iter().cloned().fold(0.0, f64::max),
    }
}

fn aggregate(
    opts: &LoadgenOptions,
    scheduled: u64,
    samples: Vec<Sample>,
    dropped: u64,
    unsent: u64,
) -> LoadgenReport {
    let completed = samples.len() as u64;
    let sent = completed + dropped;
    debug_assert!(sent + unsent <= scheduled + conn_slack(scheduled));
    let ok = samples.iter().filter(|s| s.outcome == Outcome::Ok).count() as u64;
    let overloaded = samples
        .iter()
        .filter(|s| s.outcome == Outcome::Overloaded)
        .count() as u64;
    let errors = completed - ok - overloaded;
    let latencies: Vec<f64> = samples.iter().map(|s| s.latency_ms).collect();
    let elapsed_s = samples
        .iter()
        .map(|s| s.done_at_s)
        .fold(0.0, f64::max)
        .max(opts.duration.as_secs_f64());
    let mut per_op = Vec::new();
    for kind in [OpKind::Predict, OpKind::Recommend] {
        let lats: Vec<f64> = samples
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.latency_ms)
            .collect();
        let ok_n = samples
            .iter()
            .filter(|s| s.kind == kind && s.outcome == Outcome::Ok)
            .count() as u64;
        per_op.push((
            kind,
            OpSummary {
                count: lats.len() as u64,
                ok: ok_n,
                p50: quantile(&lats, 0.50),
                p99: quantile(&lats, 0.99),
            },
        ));
    }
    LoadgenReport {
        opts: opts.clone(),
        sent,
        completed,
        ok,
        errors,
        overloaded,
        dropped,
        unsent,
        elapsed_s,
        throughput_rps: if elapsed_s > 0.0 {
            completed as f64 / elapsed_s
        } else {
            0.0
        },
        latency: summarize(&latencies),
        per_op,
        server: None,
        cluster: Vec::new(),
    }
}

// debug-assert bookkeeping slack: a writer that dies between queueing a
// meta and counting its remainder can be off by one per connection
fn conn_slack(scheduled: u64) -> u64 {
    scheduled.min(64)
}

impl LoadgenReport {
    /// Serialize to the documented `profet.loadgen.v2` schema (see the
    /// module docs / README §Loadgen).
    pub fn to_json(&self) -> Json {
        let mut config = Json::obj();
        config.set("addr", Json::Str(self.opts.addr.clone()));
        config.set("rate", Json::Num(self.opts.rate));
        config.set(
            "duration_s",
            Json::Num(self.opts.duration.as_secs_f64()),
        );
        config.set("conns", Json::Num(self.opts.conns as f64));
        config.set("predict_pct", Json::Num(self.opts.predict_pct as f64));

        let mut totals = Json::obj();
        totals.set("sent", Json::Num(self.sent as f64));
        totals.set("completed", Json::Num(self.completed as f64));
        totals.set("ok", Json::Num(self.ok as f64));
        totals.set("errors", Json::Num(self.errors as f64));
        totals.set("overloaded", Json::Num(self.overloaded as f64));
        totals.set("dropped", Json::Num(self.dropped as f64));
        totals.set("unsent", Json::Num(self.unsent as f64));

        let mut latency = Json::obj();
        latency.set("p50", Json::Num(self.latency.p50));
        latency.set("p95", Json::Num(self.latency.p95));
        latency.set("p99", Json::Num(self.latency.p99));
        latency.set("p999", Json::Num(self.latency.p999));
        latency.set("mean", Json::Num(self.latency.mean));
        latency.set("max", Json::Num(self.latency.max));

        let mut per_op = Json::obj();
        for (kind, s) in &self.per_op {
            let mut o = Json::obj();
            o.set("count", Json::Num(s.count as f64));
            o.set("ok", Json::Num(s.ok as f64));
            o.set("p50", Json::Num(s.p50));
            o.set("p99", Json::Num(s.p99));
            per_op.set(kind.key(), o);
        }

        let mut root = Json::obj();
        root.set("schema", Json::Str("profet.loadgen.v2".into()));
        root.set("config", config);
        root.set("totals", totals);
        root.set("elapsed_s", Json::Num(self.elapsed_s));
        root.set("throughput_rps", Json::Num(self.throughput_rps));
        root.set("latency_ms", latency);
        root.set("per_op", per_op);
        if let Some(sv) = &self.server {
            let hist = |h: &HistSnapshot| {
                let mut o = Json::obj();
                o.set("count", Json::Num(h.count as f64));
                o.set("p50", Json::Num(h.quantile_ns(0.50) as f64 / 1e6));
                o.set("p99", Json::Num(h.quantile_ns(0.99) as f64 / 1e6));
                o.set("max", Json::Num(h.max_ns() as f64 / 1e6));
                o
            };
            let mut s = Json::obj();
            s.set("requests", Json::Num(sv.requests as f64));
            s.set("cache_hits", Json::Num(sv.cache_hits as f64));
            s.set("cache_misses", Json::Num(sv.cache_misses as f64));
            s.set("cache_hit_ratio", Json::Num(sv.cache_hit_ratio()));
            s.set("evictions", Json::Num(sv.evictions as f64));
            s.set("overloaded", Json::Num(sv.overloaded as f64));
            s.set("queue_wait_ms", hist(&sv.queue_wait));
            s.set("execute_ms", hist(&sv.execute));
            root.set("server", s);
        }
        if !self.cluster.is_empty() {
            let total: u64 = self.cluster.iter().map(|b| b.requests).sum();
            let n = self.cluster.len() as f64;
            let mut backends = Vec::with_capacity(self.cluster.len());
            let mut max_share = 0.0f64;
            for b in &self.cluster {
                let share = if total > 0 {
                    b.requests as f64 / total as f64
                } else {
                    0.0
                };
                max_share = max_share.max(share);
                let mut o = Json::obj();
                o.set("addr", Json::Str(b.addr.clone()));
                o.set("requests", Json::Num(b.requests as f64));
                o.set(
                    "throughput_rps",
                    Json::Num(if self.elapsed_s > 0.0 {
                        b.requests as f64 / self.elapsed_s
                    } else {
                        0.0
                    }),
                );
                o.set("share", Json::Num(share));
                backends.push(o);
            }
            let mut c = Json::obj();
            c.set("backends", Json::Arr(backends));
            // 1.0 = perfectly even; n = everything landed on one backend
            c.set("shard_skew", Json::Num(max_share * n));
            root.set("cluster", c);
        }
        root
    }

    /// The CI gate: violations that make a `--strict` run exit nonzero.
    pub fn strict_violations(&self) -> Vec<String> {
        let mut v = Vec::new();
        if self.completed == 0 {
            v.push("no request completed — server unreachable or dead".into());
        }
        if self.dropped > 0 {
            v.push(format!(
                "{} request(s) dropped — a connection died owing responses",
                self.dropped
            ));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dispatch::{EnginePool, Job};
    use crate::coordinator::server::serve_pool;
    use std::sync::mpsc::Receiver as JobReceiver;

    #[test]
    fn cluster_section_reports_share_and_skew() {
        let opts = LoadgenOptions {
            targets: vec!["a:1".into(), "b:2".into(), "c:3".into()],
            ..LoadgenOptions::default()
        };
        let mut report = aggregate(&opts, 0, Vec::new(), 0, 0);
        report.elapsed_s = 2.0;
        report.cluster = vec![
            ClusterSample { addr: "a:1".into(), requests: 60 },
            ClusterSample { addr: "b:2".into(), requests: 30 },
            ClusterSample { addr: "c:3".into(), requests: 10 },
        ];
        let j = report.to_json();
        let c = j.get("cluster").expect("cluster section");
        let backends = c.get("backends").and_then(Json::as_arr).unwrap();
        assert_eq!(backends.len(), 3);
        assert_eq!(backends[0].get("addr").and_then(Json::as_str), Some("a:1"));
        assert!((backends[0].get("share").and_then(Json::as_f64).unwrap() - 0.6).abs() < 1e-9);
        assert!(
            (backends[0].get("throughput_rps").and_then(Json::as_f64).unwrap() - 30.0).abs()
                < 1e-9
        );
        // hottest backend holds 60% of 3 backends' traffic: skew 1.8
        assert!((c.get("shard_skew").and_then(Json::as_f64).unwrap() - 1.8).abs() < 1e-9);

        // single-endpoint mode (no --targets): no cluster section at all
        let solo = aggregate(&LoadgenOptions::default(), 0, Vec::new(), 0, 0);
        assert!(solo.to_json().get("cluster").is_none());
    }

    #[test]
    fn mix_is_deterministic_and_proportional() {
        let predicts = (0..1000).filter(|&k| op_for(k, 90) == OpKind::Predict).count();
        assert_eq!(predicts, 900);
        assert_eq!(
            (0..1000).filter(|&k| op_for(k, 0) == OpKind::Predict).count(),
            0
        );
        assert_eq!(
            (0..1000).filter(|&k| op_for(k, 100) == OpKind::Predict).count(),
            1000
        );
        // stable: same k, same kind
        assert_eq!(op_for(7, 50), op_for(7, 50));
    }

    #[test]
    fn request_lines_are_valid_wire_json() {
        for k in 0..32 {
            for kind in [OpKind::Predict, OpKind::Recommend] {
                let line = request_line(kind, k, "g4dn", "p3");
                assert!(line.ends_with('\n'));
                let j = Json::parse(line.trim()).expect("generator emitted invalid JSON");
                assert_eq!(j.req_str("op").unwrap(), kind.key());
            }
        }
    }

    #[test]
    fn summary_percentiles_match_quantiles() {
        let lat: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let s = summarize(&lat);
        assert!((s.p50 - 500.5).abs() < 1.0, "{}", s.p50);
        assert!((s.p99 - 990.0).abs() < 1.5, "{}", s.p99);
        assert!((s.p999 - 999.0).abs() < 1.5, "{}", s.p999);
        assert_eq!(s.max, 1000.0);
        assert!(summarize(&[]).max == 0.0);
    }

    #[test]
    fn connect_backoff_schedule_is_bounded_and_deterministic() {
        for attempt in 0..12 {
            let base = 10u64.saturating_mul(1 << attempt.min(16)).min(2_000);
            let d = retry_backoff("127.0.0.1:1", 3, attempt);
            assert!(d >= Duration::from_millis(base), "attempt {attempt}: {d:?}");
            assert!(
                d <= Duration::from_millis(base + base / 4),
                "attempt {attempt}: {d:?} exceeds 25% jitter over {base}ms"
            );
        }
        // deterministic: same (addr, conn, attempt) → same delay
        assert_eq!(retry_backoff("a", 0, 3), retry_backoff("a", 0, 3));
        // jitter spreads the fleet: not every connection gets one delay
        let distinct: std::collections::BTreeSet<Duration> =
            (0..16).map(|c| retry_backoff("a", c, 3)).collect();
        assert!(distinct.len() > 1, "jitter never varied across the fleet");
    }

    /// `--connect-retries` semantics: a refused port exhausts its bounded
    /// attempts and gives up; a server that binds mid-backoff is reached.
    #[test]
    fn connect_retries_are_bounded_and_recover_when_the_server_appears() {
        // reserve a port, then free it: nothing is listening
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        assert!(
            connect_with_retries(&addr.to_string(), 2, 0).is_none(),
            "connect to a dead port should exhaust its attempts"
        );
        // late-binding server: the listener appears while retries back off
        let server = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            std::net::TcpListener::bind(addr)
        });
        let stream = connect_with_retries(&addr.to_string(), 8, 0);
        let listener = server.join().unwrap();
        assert!(
            listener.is_ok(),
            "reserved port was taken by another process — rerun"
        );
        assert!(
            stream.is_some(),
            "retries never reached the late-binding server"
        );
    }

    #[test]
    fn classification_matches_wire_shapes() {
        assert_eq!(classify("{\"latency_ms\":1.0,\"ok\":true}"), Outcome::Ok);
        assert_eq!(
            classify("{\"error\":\"x\",\"kind\":\"overloaded\",\"ok\":false}"),
            Outcome::Overloaded
        );
        assert_eq!(classify("{\"error\":\"x\",\"ok\":false}"), Outcome::Error);
    }

    /// Full open-loop run against a live (mock-pool) server: every
    /// scheduled request is sent, answered, and accounted — zero drops —
    /// and the report serializes to the documented schema.
    #[test]
    fn end_to_end_run_against_mock_server_loses_nothing() {
        let body = |_idx: usize, rx: &JobReceiver<Job>| {
            for job in rx {
                match job {
                    Job::Shutdown => return,
                    Job::Predict(_, _, reply) => {
                        reply.send(crate::coordinator::protocol::Response::Latency {
                            latency_ms: 1.0,
                        });
                    }
                    Job::Recommend { reply, .. } => {
                        reply.send(crate::coordinator::protocol::Response::Health);
                    }
                    _ => {}
                }
            }
        };
        let pool = EnginePool::mock(2, 256, 256, body, move |rx| body(0, rx));
        let handle = serve_pool("127.0.0.1:0", pool, 32).unwrap();
        let opts = LoadgenOptions {
            addr: handle.addr.to_string(),
            rate: 400.0,
            duration: Duration::from_millis(250),
            conns: 4,
            predict_pct: 75,
            ..LoadgenOptions::default()
        };
        let report = run(&opts).unwrap();
        assert_eq!(report.dropped, 0, "drain contract violated");
        assert_eq!(report.unsent, 0);
        assert_eq!(report.sent, 100, "400 rps * 0.25 s");
        assert_eq!(report.completed, 100);
        assert_eq!(report.ok, 100);
        assert!(report.strict_violations().is_empty());
        assert!(report.throughput_rps > 0.0);
        assert!(report.latency.p50 >= 0.0 && report.latency.p999 >= report.latency.p50);

        // schema round-trip: required keys present and well-formed
        let text = report.to_json().to_string();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.req_str("schema").unwrap(), "profet.loadgen.v2");
        for key in ["config", "totals", "latency_ms", "per_op", "server"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        // server-side delta: both `stats` and `metrics` answered, and the
        // section carries the documented shape
        let server = j.get("server").unwrap();
        for key in [
            "requests",
            "cache_hits",
            "cache_misses",
            "cache_hit_ratio",
            "evictions",
            "overloaded",
            "queue_wait_ms",
            "execute_ms",
        ] {
            assert!(server.get(key).is_some(), "missing server.{key}");
        }
        let ratio = server.get("cache_hit_ratio").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&ratio), "{ratio}");
        for h in ["queue_wait_ms", "execute_ms"] {
            for k in ["count", "p50", "p99", "max"] {
                assert!(
                    server.get(h).unwrap().get(k).and_then(Json::as_f64).is_some(),
                    "missing server.{h}.{k}"
                );
            }
        }
        for key in ["p50", "p95", "p99", "p999", "mean", "max"] {
            assert!(
                j.get("latency_ms").unwrap().get(key).and_then(Json::as_f64).is_some(),
                "missing latency_ms.{key}"
            );
        }
        let totals = j.get("totals").unwrap();
        assert_eq!(
            totals.get("dropped").and_then(Json::as_f64),
            Some(0.0)
        );
        let per_op = j.get("per_op").unwrap();
        let n = |o: &Json, k: &str| o.get(k).and_then(Json::as_f64).unwrap() as u64;
        let predict = per_op.get("predict").unwrap();
        let recommend = per_op.get("recommend").unwrap();
        assert_eq!(n(predict, "count") + n(recommend, "count"), 100);
        assert_eq!(n(predict, "count"), 75, "75% predict mix");
        handle.stop();
    }
}
