//! CNN operations with TF-Profiler-style names and roofline accounting.
//!
//! Each [`Op`] carries the exact operation name TensorFlow's profiler
//! reports (the *feature identity* PROFET's name-clustering operates on),
//! plus the FLOPs / bytes / output-element counts the simulator's cost
//! model consumes. Backward ops are first-class — PROFET profiles whole
//! training steps, so Conv2DBackpropFilter etc. dominate real profiles.

use std::fmt;

/// Broad cost-model class of an op (efficiency bands differ per class).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Dense conv/matmul compute — can use tensor cores.
    MatrixCompute,
    /// Depthwise conv — bandwidth-bound on GPUs.
    Depthwise,
    /// Elementwise map (ReLU, Add, Mul, casts).
    Elementwise,
    /// Window reductions (pooling).
    Pooling,
    /// Normalization (fused batch norm).
    Normalization,
    /// Full/axis reductions (Mean, Sum, Softmax, ArgMax).
    Reduction,
    /// Layout/data movement (ConcatV2, Slice, Pad, Tile, Transpose).
    DataMovement,
    /// Optimizer variable updates.
    Optimizer,
}

/// One profiled operation instance (one layer-level kernel invocation).
#[derive(Debug, Clone)]
pub struct Op {
    /// TF-profiler operation name, e.g. "Conv2DBackpropFilter". This is
    /// the string PROFET's Levenshtein clustering sees.
    pub name: &'static str,
    /// Layer instance name, e.g. "conv2d_3" (operation-details field; the
    /// part PROFET deliberately does NOT use as a model feature).
    pub layer: String,
    pub class: OpClass,
    /// Floating-point operations for one mini-batch execution.
    pub flops: f64,
    /// Bytes moved to/from device memory (inputs + outputs + weights).
    pub bytes: f64,
    /// Output tensor element count (parallelism proxy for utilization).
    pub out_elems: f64,
    /// Output tensor shape as reported by the profiler (for records).
    pub out_shape: Vec<usize>,
}

impl Op {
    pub fn new(
        name: &'static str,
        layer: impl Into<String>,
        class: OpClass,
        flops: f64,
        bytes: f64,
        out_shape: Vec<usize>,
    ) -> Self {
        let out_elems = out_shape.iter().product::<usize>() as f64;
        Self {
            name,
            layer: layer.into(),
            class,
            flops,
            bytes,
            out_elems,
            out_shape,
        }
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}({}) flops={:.3e} bytes={:.3e}",
            self.name, self.layer, self.flops, self.bytes
        )
    }
}

/// The op-name vocabulary the simulator can emit. Kept here so tests can
/// assert the clustering corpus stays inside the expected universe.
pub const VOCABULARY: &[&str] = &[
    "Conv2D",
    "Conv2DBackpropFilter",
    "Conv2DBackpropInput",
    "DepthwiseConv2dNative",
    "DepthwiseConv2dNativeBackpropFilter",
    "DepthwiseConv2dNativeBackpropInput",
    "MatMul",
    "BiasAdd",
    "BiasAddGrad",
    "Relu",
    "ReluGrad",
    "Relu6",
    "Relu6Grad",
    "MaxPool",
    "MaxPoolGrad",
    "AvgPool",
    "AvgPoolGrad",
    "Mean",
    "Tile",
    "FusedBatchNormV3",
    "FusedBatchNormGradV3",
    "RsqrtGrad",
    "AddV2",
    "AddN",
    "ConcatV2",
    "Slice",
    "Pad",
    "Softmax",
    "SoftmaxCrossEntropyWithLogits",
    "ArgMax",
    "Mul",
    "Sub",
    "Sum",
    "Cast",
    "Transpose",
    "Reshape",
    "AssignSubVariableOp",
    "AssignAddVariableOp",
    // transformer extension (Sec VII "non-CNN models"): attention + GeLU +
    // layer-norm + embedding vocabulary
    "BatchMatMulV2",
    "Erf",
    "SquaredDifference",
    "Rsqrt",
    "GatherV2",
    "UnsortedSegmentSum",
    "Tanh",
];

/// True if `name` is in the simulator's op vocabulary.
pub fn in_vocabulary(name: &str) -> bool {
    VOCABULARY.contains(&name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocabulary_unique_and_nonempty() {
        let mut v = VOCABULARY.to_vec();
        v.sort();
        v.dedup();
        assert_eq!(v.len(), VOCABULARY.len(), "duplicate vocabulary entries");
        assert!(VOCABULARY.len() >= 30);
    }

    #[test]
    fn op_elems_from_shape() {
        let op = Op::new("Conv2D", "conv2d_0", OpClass::MatrixCompute, 1e9, 1e6, vec![16, 32, 32, 64]);
        assert_eq!(op.out_elems, (16 * 32 * 32 * 64) as f64);
    }

    #[test]
    fn paper_cluster_examples_in_vocabulary() {
        // Sec III-B3 lists representative clusters; all members must be
        // emittable by our simulator.
        for name in [
            "FusedBatchNormV3",
            "FusedBatchNormGradV3",
            "AssignSubVariableOp",
            "AssignAddVariableOp",
            "Softmax",
            "ArgMax",
            "MaxPoolGrad",
            "AvgPoolGrad",
            "DepthwiseConv2dNativeBackpropInput",
            "DepthwiseConv2dNativeBackpropFilter",
            "BiasAddGrad",
            "BiasAdd",
            "MatMul",
            "MaxPool",
            "AvgPool",
            "Relu6Grad",
            "RsqrtGrad",
            "ReluGrad",
        ] {
            assert!(in_vocabulary(name), "{name} missing");
        }
    }
}
