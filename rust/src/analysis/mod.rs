//! In-repo invariant linter (`repro lint`).
//!
//! The serving stack makes promises the type system cannot state: the
//! wire path allocates nothing ([`crate::util::json_stream`]), reactor
//! threads never block ([`crate::coordinator::reactor`]), every
//! `unsafe` and every relaxed atomic is justified in prose, and
//! `docs/PROTOCOL.md` lists exactly the ops/error kinds/fields the code
//! ships. This module is a dependency-free static-analysis engine that
//! machine-checks those promises on every CI run, complementing the
//! runtime gates (`tests/wire_alloc.rs`, the stress harness).
//!
//! Architecture, bottom-up:
//!
//! * [`lexer`] — one-pass string/comment-aware scan producing masked
//!   text (so tokens inside literals/comments can never trip a rule)
//!   plus string-literal and comment tables.
//! * [`rules`] — the five per-file rules (`hot-path-alloc`,
//!   `reactor-blocking-call`, `unsafe-hygiene`, `relaxed-ordering`,
//!   advisory `unwrap-in-server`) and the `// lint: allow(…)`
//!   annotation machinery, itself checked by the `lint-annotation`
//!   meta-rule.
//! * [`docsync`] — the cross-file `doc-drift` rule: protocol/obs
//!   enumerations extracted from source string literals, cross-checked
//!   against `docs/PROTOCOL.md`.
//! * this module — source discovery, orchestration, and the three
//!   output forms: human text, machine JSON (`--json`), and the
//!   committed allowlist audit (`--audit`, pasted into
//!   `docs/ANALYSIS.md`).
//!
//! Hard findings fail `repro lint` (exit 1); advisory findings are
//! printed but do not. See `docs/ANALYSIS.md` for the rule catalogue.

pub mod docsync;
pub mod lexer;
pub mod rules;

use crate::util::json::Json;
use docsync::CodeInventory;
use rules::{check_file, Allowance, FileCtx, Finding};
use std::fs;
use std::path::{Path, PathBuf};

/// Everything one lint run produced.
#[derive(Debug, Default)]
pub struct Report {
    /// Files scanned, as `rust/`-relative paths (e.g. `src/lib.rs`).
    pub files: Vec<String>,
    /// All findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// All allowlisted sites (annotations + builtin allowances), sorted.
    pub allowances: Vec<Allowance>,
}

impl Report {
    pub fn hard_count(&self) -> usize {
        self.findings.iter().filter(|f| !f.advisory).count()
    }

    pub fn advisory_count(&self) -> usize {
        self.findings.iter().filter(|f| f.advisory).count()
    }

    /// Human-readable rendering: one block per finding plus a summary
    /// trailer.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let tag = if f.advisory { " (advisory)" } else { "" };
            out.push_str(&format!("{}:{} [{}]{} {}\n", f.file, f.line, f.rule, tag, f.message));
            if !f.snippet.is_empty() {
                out.push_str(&format!("    {}\n", f.snippet));
            }
        }
        out.push_str(&format!(
            "lint: {} files scanned, {} hard finding(s), {} advisory finding(s), \
             {} allowlisted site(s)\n",
            self.files.len(),
            self.hard_count(),
            self.advisory_count(),
            self.allowances.len(),
        ));
        out
    }

    /// Machine-readable rendering (`repro lint --json`).
    pub fn to_json(&self) -> String {
        let mut root = Json::obj();
        root.set("files_scanned", Json::Num(self.files.len() as f64))
            .set("hard_findings", Json::Num(self.hard_count() as f64))
            .set("advisory_findings", Json::Num(self.advisory_count() as f64));
        let findings = self
            .findings
            .iter()
            .map(|f| {
                let mut o = Json::obj();
                o.set("file", Json::Str(f.file.clone()))
                    .set("line", Json::Num(f.line as f64))
                    .set("rule", Json::Str(f.rule.to_string()))
                    .set("advisory", Json::Bool(f.advisory))
                    .set("message", Json::Str(f.message.clone()))
                    .set("snippet", Json::Str(f.snippet.clone()));
                o
            })
            .collect();
        root.set("findings", Json::Arr(findings));
        let allows = self
            .allowances
            .iter()
            .map(|a| {
                let mut o = Json::obj();
                o.set("file", Json::Str(a.file.clone()))
                    .set("line", Json::Num(a.line as f64))
                    .set("rule", Json::Str(a.rule.clone()))
                    .set("reason", Json::Str(a.reason.clone()));
                o
            })
            .collect();
        root.set("allowances", Json::Arr(allows));
        root.to_string()
    }

    /// The allowlist audit table (`repro lint --audit`) — the markdown
    /// committed in `docs/ANALYSIS.md` §Allowlist audit is regenerated
    /// from this verbatim.
    pub fn render_audit(&self) -> String {
        let mut out = String::from("| file | line | rule | reason |\n|---|---:|---|---|\n");
        for a in &self.allowances {
            out.push_str(&format!(
                "| `{}` | {} | `{}` | {} |\n",
                a.file, a.line, a.rule, a.reason
            ));
        }
        out
    }
}

/// Recursively collect `.rs` files under `dir`, as `rust/`-relative
/// forward-slash paths, sorted for deterministic output.
fn collect_rs(rust_root: &Path, dir: &str, out: &mut Vec<String>) {
    fn walk(base: &Path, dir: &Path, out: &mut Vec<String>) {
        let Ok(entries) = fs::read_dir(dir) else { return };
        for entry in entries.flatten() {
            let p = entry.path();
            if p.is_dir() {
                walk(base, &p, out);
            } else if p.extension().is_some_and(|e| e == "rs") {
                if let Ok(rel) = p.strip_prefix(base) {
                    out.push(rel.to_string_lossy().replace('\\', "/"));
                }
            }
        }
    }
    walk(rust_root, &rust_root.join(dir), out);
    out.sort();
}

/// The sources the doc-drift checker reads its enumerations from.
const PROTOCOL_SRC: &str = "src/coordinator/protocol.rs";
const ROUTER_SRC: &str = "src/coordinator/router.rs";
const OBS_SRC: &str = "src/obs/mod.rs";
const DOC_FILE: &str = "docs/PROTOCOL.md";

/// Build the code-side inventory for the doc-drift check from the
/// already-lexed file contexts.
fn build_inventory(ctxs: &[(String, FileCtx)]) -> CodeInventory {
    let mut inv = CodeInventory::default();
    for (path, ctx) in ctxs {
        let in_test = |l: usize| ctx.in_test(l);
        // error kinds come from every coordinator file that can emit an
        // error response (reactor, router, server, lane, protocol)
        if path.starts_with("src/coordinator/") {
            docsync::error_kinds_in_code(&ctx.scan, &in_test, &mut inv.error_kinds);
        }
        if path == PROTOCOL_SRC {
            inv.ops = docsync::ops_in_code(&ctx.scan, &in_test);
            inv.stats_keys = docsync::keys_in_encode_arm(&ctx.scan, "Response::Stats", &in_test);
            inv.cluster_stats_keys =
                docsync::keys_in_encode_arm(&ctx.scan, "Response::ClusterStats", &in_test);
            inv.metrics_keys =
                docsync::keys_in_encode_arm(&ctx.scan, "Response::Metrics", &in_test);
        }
        if path == ROUTER_SRC {
            inv.gauges = docsync::gauges_in_code(&ctx.scan, &in_test);
        }
        if path == OBS_SRC {
            inv.stages = docsync::stages_in_code(&ctx.scan, &in_test);
        }
    }
    inv
}

/// Run the full lint over the repo rooted at `repo_root` (the directory
/// holding `rust/` and `docs/`).
pub fn run(repo_root: &Path) -> std::io::Result<Report> {
    let rust_root = repo_root.join("rust");
    let mut files = Vec::new();
    for dir in ["src", "tests", "benches"] {
        collect_rs(&rust_root, dir, &mut files);
    }
    files.sort();
    files.dedup();

    let mut findings = Vec::new();
    let mut allowances = Vec::new();
    let mut ctxs: Vec<(String, FileCtx)> = Vec::new();
    for path in &files {
        let src = fs::read_to_string(rust_root.join(path))?;
        let ctx = check_file(path, &src, &mut findings);
        allowances.extend(ctx.allowances.iter().cloned());
        ctxs.push((path.clone(), ctx));
    }

    let inv = build_inventory(&ctxs);
    let doc_path: PathBuf = repo_root.join(DOC_FILE);
    match fs::read_to_string(&doc_path) {
        Ok(doc) => docsync::check_doc(&inv, &doc, DOC_FILE, &mut findings),
        Err(e) => findings.push(Finding {
            file: DOC_FILE.to_string(),
            line: 1,
            rule: rules::RULE_DOC_DRIFT,
            message: format!("cannot read protocol doc: {e}"),
            snippet: String::new(),
            advisory: false,
        }),
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    allowances.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });

    Ok(Report { files, findings, allowances })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_all_three_forms() {
        let report = Report {
            files: vec!["src/a.rs".into()],
            findings: vec![Finding {
                file: "src/a.rs".into(),
                line: 3,
                rule: rules::RULE_ALLOC,
                message: "boom".into(),
                snippet: "let v = Vec::new();".into(),
                advisory: false,
            }],
            allowances: vec![Allowance {
                file: "src/b.rs".into(),
                line: 9,
                rule: rules::RULE_BLOCK.into(),
                reason: "poller wait".into(),
            }],
        };
        let text = report.render_text();
        assert!(text.contains("src/a.rs:3 [hot-path-alloc] boom"));
        assert!(text.contains("1 hard finding(s)"));
        let json = Json::parse(&report.to_json()).expect("valid json");
        assert_eq!(json.req_usize("hard_findings").unwrap(), 1);
        assert_eq!(json.req_arr("findings").unwrap().len(), 1);
        assert_eq!(
            json.req_arr("allowances").unwrap()[0].req_str("reason").unwrap(),
            "poller wait"
        );
        let audit = report.render_audit();
        assert!(audit.contains("| `src/b.rs` | 9 | `reactor-blocking-call` | poller wait |"));
    }
}
