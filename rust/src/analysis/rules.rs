//! The invariant rules and their annotation/allowlist machinery.
//!
//! Every rule is named, and every rule can be silenced at a specific
//! site with an inline annotation comment (the committed audit of all
//! annotations lives in `docs/ANALYSIS.md`):
//!
//! * `// lint: allow(<rule>): <reason>` — trailing on a line allows
//!   that line; on its own line it allows the next line.
//! * `// lint: allow(<rule>) begin` … `// lint: allow(<rule>) end` —
//!   allows every line of the enclosed region.
//!
//! Two rules use *justification comments* instead of allow-annotations,
//! because the point is forcing an explanation, not an exemption:
//!
//! * `unsafe-hygiene` — every `unsafe` keyword needs a `// SAFETY:`
//!   comment on the same line or in the contiguous comment/code block
//!   directly above it.
//! * `relaxed-ordering` — every `Relaxed` atomic ordering needs an
//!   `// ordering:` comment the same way.
//!
//! `#[cfg(test)]` items (tracked brace-exactly) are exempt from every
//! rule except `unsafe-hygiene` — test code may allocate and panic
//! freely, but a bare `unsafe` is never fine.

use super::lexer::{is_ident_byte, scan, Scan};
use std::collections::BTreeMap;

/// Rule identifiers (stable: they appear in findings, annotations, CI
/// output, and `docs/ANALYSIS.md`).
pub const RULE_ALLOC: &str = "hot-path-alloc";
pub const RULE_BLOCK: &str = "reactor-blocking-call";
pub const RULE_UNSAFE: &str = "unsafe-hygiene";
pub const RULE_ORDERING: &str = "relaxed-ordering";
pub const RULE_UNWRAP: &str = "unwrap-in-server";
pub const RULE_ANNOTATION: &str = "lint-annotation";
pub const RULE_DOC_DRIFT: &str = "doc-drift";

/// Every rule id an annotation may name.
pub const ALL_RULES: &[&str] = &[
    RULE_ALLOC,
    RULE_BLOCK,
    RULE_UNSAFE,
    RULE_ORDERING,
    RULE_UNWRAP,
    RULE_ANNOTATION,
    RULE_DOC_DRIFT,
];

/// Files (paths relative to `rust/`) where the hot-path allocation rule
/// applies: the zero-allocation wire layer. Runtime complement:
/// `tests/wire_alloc.rs` (the counting-allocator gate).
pub const ALLOC_HOT_FILES: &[&str] = &[
    "src/util/json_stream.rs",
    "src/coordinator/protocol.rs",
    "src/coordinator/reactor.rs",
];

/// Files where the reactor blocking-call rule applies: everything that
/// runs on a reactor thread's event loop.
pub const BLOCK_FILES: &[&str] = &["src/coordinator/reactor.rs"];

/// Path prefix for the advisory unwrap rule (the serving tier).
pub const UNWRAP_PREFIX: &str = "src/coordinator/";

/// Allocation-capable constructs forbidden on the wire-hot files.
/// Token matching is word-bounded and runs over comment/string-masked
/// text, so `"format!"` in a string literal never trips it.
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "Vec::with_capacity",
    "vec!",
    "String::new",
    "String::from",
    "String::with_capacity",
    "format!",
    "Box::new",
    "Arc::new",
    "Rc::new",
    "HashMap::new",
    "BTreeMap::new",
    ".to_string(",
    ".to_owned(",
    ".to_vec(",
    ".clone(",
    ".collect(",
    ".with_capacity(",
];

/// Blocking or lock-taking constructs forbidden on reactor threads.
const BLOCK_TOKENS: &[&str] = &[
    ".lock(",
    ".join(",
    "::sleep(",
    ".recv(",
    ".recv_timeout(",
    ".wait(",
    ".wait_timeout(",
    ".read_to_end(",
    ".read_to_string(",
    ".read_exact(",
    ".write_all(",
    ".accept(",
];

/// One lint finding. `advisory` findings are reported but do not fail
/// `repro lint` (today: only `unwrap-in-server`).
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
    pub snippet: String,
    pub advisory: bool,
}

/// One allowlist entry, for the audit (`repro lint --audit` regenerates
/// the table committed in `docs/ANALYSIS.md`).
#[derive(Debug, Clone)]
pub struct Allowance {
    pub file: String,
    pub line: usize,
    pub rule: String,
    /// The annotation's reason text, or a builtin tag
    /// (`lock-poison propagation`, `cfg(test) item`).
    pub reason: String,
}

/// Parsed per-file context shared by all rules.
pub struct FileCtx {
    pub path: String,
    pub scan: Scan,
    /// 1-based line → raw source text.
    raw_lines: Vec<String>,
    /// `true` for lines inside a `#[cfg(test)]`-gated item.
    test_mask: Vec<bool>,
    /// rule → lines allowed by line annotations.
    line_allows: BTreeMap<String, Vec<usize>>,
    /// rule → (begin, end) line ranges from region annotations.
    region_allows: BTreeMap<String, Vec<(usize, usize)>>,
    /// Annotation audit entries (+ problems surface as findings).
    pub allowances: Vec<Allowance>,
}

impl FileCtx {
    /// Lex and pre-process one source file.
    pub fn new(path: &str, src: &str, findings: &mut Vec<Finding>) -> FileCtx {
        let scan = scan(src);
        let raw_lines: Vec<String> = src.split('\n').map(|l| l.to_string()).collect();
        let test_mask = cfg_test_mask(&raw_lines, &scan);
        let mut ctx = FileCtx {
            path: path.to_string(),
            scan,
            raw_lines,
            test_mask,
            line_allows: BTreeMap::new(),
            region_allows: BTreeMap::new(),
            allowances: Vec::new(),
        };
        ctx.parse_annotations(findings);
        ctx
    }

    fn n_lines(&self) -> usize {
        self.raw_lines.len()
    }

    /// 1-based raw line (empty string past EOF).
    fn raw_line(&self, line: usize) -> &str {
        self.raw_lines.get(line.wrapping_sub(1)).map_or("", |s| s.as_str())
    }

    pub fn in_test(&self, line: usize) -> bool {
        self.test_mask.get(line.wrapping_sub(1)).copied().unwrap_or(false)
    }

    /// Does `line` carry a comment whose text contains `needle`?
    fn line_comment_contains(&self, line: usize, needle: &str) -> bool {
        self.scan
            .comments
            .iter()
            .any(|c| c.line == line && c.text.contains(needle))
    }

    /// Is the masked content of `line` effectively empty (blank or
    /// comment-only)?
    fn masked_blank(&self, line: usize) -> bool {
        masked_line(&self.scan.masked, line).trim().is_empty()
    }

    /// `// lint: allow(rule): reason` and region begin/end parsing.
    fn parse_annotations(&mut self, findings: &mut Vec<Finding>) {
        let mut open: BTreeMap<String, (usize, String)> = BTreeMap::new();
        let comments: Vec<(usize, String)> = self
            .scan
            .comments
            .iter()
            .map(|c| (c.line, c.text.clone()))
            .collect();
        for (line, text) in comments {
            let Some(rest) = text.trim().strip_prefix("lint: allow(") else {
                continue;
            };
            let Some(close) = rest.find(')') else {
                findings.push(self.annotation_problem(line, "malformed annotation: missing `)`"));
                continue;
            };
            let rule = rest[..close].trim().to_string();
            let tail = rest[close + 1..].trim();
            if !ALL_RULES.contains(&rule.as_str()) {
                findings.push(self.annotation_problem(
                    line,
                    &format!("unknown rule `{rule}` in annotation"),
                ));
                continue;
            }
            if tail == "begin" || tail.starts_with("begin:") {
                let reason = tail.strip_prefix("begin").unwrap_or("").trim_start_matches(':');
                open.insert(rule, (line, reason.trim().to_string()));
            } else if tail == "end" {
                match open.remove(&rule) {
                    Some((begin, reason)) => {
                        self.region_allows.entry(rule.clone()).or_default().push((begin, line));
                        self.allowances.push(Allowance {
                            file: self.path.clone(),
                            line: begin,
                            rule: format!("{rule} (region → {line})"),
                            reason,
                        });
                    }
                    None => findings.push(self.annotation_problem(
                        line,
                        &format!("`lint: allow({rule}) end` without a begin"),
                    )),
                }
            } else {
                // line annotation: covers its own line when trailing
                // code, else the next line
                let reason = tail.trim_start_matches(':').trim().to_string();
                let target = if self.masked_blank(line) { line + 1 } else { line };
                self.line_allows.entry(rule.clone()).or_default().push(target);
                self.allowances.push(Allowance {
                    file: self.path.clone(),
                    line: target,
                    rule,
                    reason,
                });
            }
        }
        for (rule, (line, _)) in open {
            findings.push(self.annotation_problem(
                line,
                &format!("`lint: allow({rule}) begin` without an end"),
            ));
        }
    }

    fn annotation_problem(&self, line: usize, msg: &str) -> Finding {
        Finding {
            file: self.path.clone(),
            line,
            rule: RULE_ANNOTATION,
            message: msg.to_string(),
            snippet: self.raw_line(line).trim().to_string(),
            advisory: false,
        }
    }

    fn allowed(&self, rule: &str, line: usize) -> bool {
        if self.line_allows.get(rule).is_some_and(|v| v.contains(&line)) {
            return true;
        }
        self.region_allows
            .get(rule)
            .is_some_and(|v| v.iter().any(|&(b, e)| (b..=e).contains(&line)))
    }

    /// `needle` appears as a comment on `line` or anywhere in the
    /// contiguous (no blank raw line) block of at most `window` lines
    /// directly above it — the justification-comment coverage rule.
    fn justified(&self, line: usize, needle: &str, window: usize) -> bool {
        if self.line_comment_contains(line, needle) {
            return true;
        }
        let mut l = line;
        for _ in 0..window {
            if l <= 1 {
                return false;
            }
            l -= 1;
            if self.raw_line(l).trim().is_empty() {
                return false;
            }
            if self.line_comment_contains(l, needle) {
                return true;
            }
        }
        false
    }

    fn finding(&self, rule: &'static str, line: usize, message: String, advisory: bool) -> Finding {
        Finding {
            file: self.path.clone(),
            line,
            rule,
            message,
            snippet: self.raw_line(line).trim().to_string(),
            advisory,
        }
    }
}

/// 1-based line slice of the masked text.
fn masked_line(masked: &str, line: usize) -> &str {
    masked.split('\n').nth(line.wrapping_sub(1)).unwrap_or("")
}

/// Compute which lines sit inside `#[cfg(test)]`-gated items by brace
/// tracking over masked text: the attribute gates the next item, which
/// extends to where its braces re-balance (or to its terminating `;`
/// before any brace opens, e.g. `#[cfg(test)] use …;`).
fn cfg_test_mask(raw_lines: &[String], scan: &Scan) -> Vec<bool> {
    let masked_lines: Vec<&str> = scan.masked.split('\n').collect();
    let mut mask = vec![false; raw_lines.len()];
    let mut i = 0usize;
    while i < raw_lines.len() {
        // masked text: a `#[cfg(test)]` inside a doc comment or string
        // literal must not open a region
        if !masked_lines.get(i).copied().unwrap_or("").trim().starts_with("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0i64;
        let mut opened = false;
        let mut j = i;
        while j < raw_lines.len() {
            mask[j] = true;
            let ml = masked_lines.get(j).copied().unwrap_or("");
            for b in ml.bytes() {
                match b {
                    b'{' => {
                        depth += 1;
                        opened = true;
                    }
                    b'}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            if !opened && ml.trim_end().ends_with(';') {
                break; // braceless item (use/static declaration)
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// Word-bounded occurrences of `token` in `masked`, as byte offsets.
/// Tokens starting with `.` or ending with `(`/`!` carry their own
/// boundary on that side; identifier edges are checked explicitly.
fn token_sites(masked: &str, token: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let tb = token.as_bytes();
    let mb = masked.as_bytes();
    for (pos, _) in masked.match_indices(token) {
        let first = tb[0];
        if is_ident_byte(first) && pos > 0 && is_ident_byte(mb[pos - 1]) {
            continue;
        }
        let last = tb[tb.len() - 1];
        let after = pos + tb.len();
        if is_ident_byte(last) && after < mb.len() && is_ident_byte(mb[after]) {
            continue;
        }
        out.push(pos);
    }
    out
}

/// 1-based line of byte offset `pos`.
fn line_of(masked: &str, pos: usize) -> usize {
    masked.as_bytes()[..pos].iter().filter(|&&b| b == b'\n').count() + 1
}

/// Rule 1: hot-path allocation lint (wire-hot files only).
pub fn check_alloc(ctx: &mut FileCtx, findings: &mut Vec<Finding>) {
    if !ALLOC_HOT_FILES.contains(&ctx.path.as_str()) {
        return;
    }
    for token in ALLOC_TOKENS {
        for pos in token_sites(&ctx.scan.masked, token) {
            let line = line_of(&ctx.scan.masked, pos);
            if ctx.in_test(line) || ctx.allowed(RULE_ALLOC, line) {
                continue;
            }
            findings.push(ctx.finding(
                RULE_ALLOC,
                line,
                format!(
                    "allocation-capable `{}` in wire-hot module (annotate cold/error paths \
                     with `lint: allow({RULE_ALLOC})`)",
                    token.trim_matches(|c| c == '.' || c == '(')
                ),
                false,
            ));
        }
    }
}

/// Rule 2: no blocking calls on reactor threads.
pub fn check_block(ctx: &mut FileCtx, findings: &mut Vec<Finding>) {
    if !BLOCK_FILES.contains(&ctx.path.as_str()) {
        return;
    }
    for token in BLOCK_TOKENS {
        for pos in token_sites(&ctx.scan.masked, token) {
            let line = line_of(&ctx.scan.masked, pos);
            if ctx.in_test(line) || ctx.allowed(RULE_BLOCK, line) {
                continue;
            }
            findings.push(ctx.finding(
                RULE_BLOCK,
                line,
                format!(
                    "blocking call `{}` on a reactor-thread path (annotate designed \
                     waits with `lint: allow({RULE_BLOCK})`)",
                    token.trim_matches(|c| c == '.' || c == '(')
                ),
                false,
            ));
        }
    }
}

/// Rule 3: every `unsafe` carries a `// SAFETY:` comment. Applies
/// everywhere, test code included.
pub fn check_unsafe(ctx: &mut FileCtx, findings: &mut Vec<Finding>) {
    for pos in token_sites(&ctx.scan.masked, "unsafe") {
        let line = line_of(&ctx.scan.masked, pos);
        if ctx.justified(line, "SAFETY:", 20) || ctx.allowed(RULE_UNSAFE, line) {
            continue;
        }
        findings.push(ctx.finding(
            RULE_UNSAFE,
            line,
            "`unsafe` without a `// SAFETY:` comment on or directly above it".to_string(),
            false,
        ));
    }
}

/// Rule 4: every `Relaxed` atomic ordering carries an `// ordering:`
/// justification. `use` imports are exempt (the use sites are not), and
/// the rule only covers library code under `src/` — test/bench
/// harnesses may count however they like.
pub fn check_ordering(ctx: &mut FileCtx, findings: &mut Vec<Finding>) {
    if !ctx.path.starts_with("src/") {
        return;
    }
    for pos in token_sites(&ctx.scan.masked, "Relaxed") {
        let line = line_of(&ctx.scan.masked, pos);
        if ctx.in_test(line) || masked_line(&ctx.scan.masked, line).trim_start().starts_with("use ")
        {
            continue;
        }
        if ctx.justified(line, "ordering:", 20) || ctx.allowed(RULE_ORDERING, line) {
            continue;
        }
        findings.push(ctx.finding(
            RULE_ORDERING,
            line,
            "`Ordering::Relaxed` without an `// ordering:` justification comment".to_string(),
            false,
        ));
    }
}

/// Rule 5 (advisory): `.unwrap()`/`.expect(` on serving-tier runtime
/// paths. `.lock().unwrap()` is auto-allowed as deliberate lock-poison
/// propagation (crash over serving with a corrupted invariant) and
/// recorded in the audit.
pub fn check_unwrap(ctx: &mut FileCtx, findings: &mut Vec<Finding>) {
    if !ctx.path.starts_with(UNWRAP_PREFIX) {
        return;
    }
    let masked = ctx.scan.masked.clone();
    for token in [".unwrap()", ".expect("] {
        for pos in token_sites(&masked, token) {
            let line = line_of(&masked, pos);
            if ctx.in_test(line) || ctx.allowed(RULE_UNWRAP, line) {
                continue;
            }
            // builtin allowance: receiver is a `.lock()` call (possibly
            // across a line break from rustfmt chaining)
            let before = masked[..pos].trim_end();
            if before.ends_with(".lock()") {
                ctx.allowances.push(Allowance {
                    file: ctx.path.clone(),
                    line,
                    rule: RULE_UNWRAP.to_string(),
                    reason: "builtin: lock-poison propagation".to_string(),
                });
                continue;
            }
            findings.push(ctx.finding(
                RULE_UNWRAP,
                line,
                format!(
                    "`{}` on a serving-tier runtime path — return a structured error instead",
                    token.trim_matches(|c| c == '.' || c == '(')
                ),
                true,
            ));
        }
    }
}

/// Run every per-file rule over one source file.
pub fn check_file(path: &str, src: &str, findings: &mut Vec<Finding>) -> FileCtx {
    let mut ctx = FileCtx::new(path, src, findings);
    check_alloc(&mut ctx, findings);
    check_block(&mut ctx, findings);
    check_unsafe(&mut ctx, findings);
    check_ordering(&mut ctx, findings);
    check_unwrap(&mut ctx, findings);
    ctx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> (Vec<Finding>, FileCtx) {
        let mut findings = Vec::new();
        let ctx = check_file(path, src, &mut findings);
        (findings, ctx)
    }

    #[test]
    fn cfg_test_items_are_brace_tracked_not_to_eof() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn b() {}\n";
        let (_, ctx) = run("src/x.rs", src);
        assert!(!ctx.in_test(1));
        assert!(ctx.in_test(3) && ctx.in_test(4) && ctx.in_test(5));
        assert!(!ctx.in_test(6), "code after the test mod is live again");
    }

    #[test]
    fn unsafe_requires_safety_comment_with_exact_location() {
        let src = "fn f() {\n    let x = unsafe { g() };\n}\n";
        let (f, _) = run("src/any.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RULE_UNSAFE);
        assert_eq!(f[0].line, 2);
        let ok = "fn f() {\n    // SAFETY: g has no preconditions\n    let x = unsafe { g() };\n}\n";
        assert!(run("src/any.rs", ok).0.is_empty());
    }

    #[test]
    fn tokens_in_strings_and_comments_do_not_fire() {
        let src = "fn f() -> &'static str {\n    // format! would allocate here\n    \"format!(vec![Box::new])\"\n}\n";
        let (f, _) = run("src/util/json_stream.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn line_annotation_allows_trailing_and_next_line() {
        let bad = "fn f() { let v = Vec::new(); }\n";
        let (f, _) = run("src/util/json_stream.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), (RULE_ALLOC, 1));
        let trailing =
            "fn f() { let v = Vec::new(); } // lint: allow(hot-path-alloc): cold init\n";
        assert!(run("src/util/json_stream.rs", trailing).0.is_empty());
        let above = "// lint: allow(hot-path-alloc): cold init\nfn f() { let v = Vec::new(); }\n";
        assert!(run("src/util/json_stream.rs", above).0.is_empty());
    }

    #[test]
    fn region_annotation_and_unbalanced_region() {
        let src = "// lint: allow(hot-path-alloc) begin: DOM reference path\nfn f() { format!(\"x\"); }\n// lint: allow(hot-path-alloc) end\nfn g() { format!(\"y\"); }\n";
        let (f, _) = run("src/coordinator/protocol.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), (RULE_ALLOC, 4));
        let unbalanced = "// lint: allow(hot-path-alloc) begin\nfn f() {}\n";
        let (f, _) = run("src/coordinator/protocol.rs", unbalanced);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RULE_ANNOTATION);
    }

    #[test]
    fn unknown_rule_annotation_is_a_finding() {
        let src = "// lint: allow(no-such-rule): oops\nfn f() {}\n";
        let (f, _) = run("src/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), (RULE_ANNOTATION, 1));
    }

    #[test]
    fn blocking_call_in_reactor_fires_and_allows() {
        let src = "fn f(m: &std::sync::Mutex<i32>) {\n    let g = m.lock();\n}\n";
        let (f, _) = run("src/coordinator/reactor.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), (RULE_BLOCK, 2));
        // same file path is also alloc-hot; a non-alloc token only trips block
        assert!(f.iter().all(|x| x.rule == RULE_BLOCK));
    }

    #[test]
    fn relaxed_ordering_needs_justification_but_use_is_exempt() {
        let src = "use std::sync::atomic::{AtomicU64, Ordering::Relaxed};\nfn f(c: &std::sync::atomic::AtomicU64) {\n    c.fetch_add(1, Relaxed);\n}\n";
        let (f, _) = run("src/obs/hist.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), (RULE_ORDERING, 3));
        let ok = "fn f(c: &std::sync::atomic::AtomicU64) {\n    // ordering: independent counter, no cross-field sync\n    c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);\n}\n";
        assert!(run("src/obs/hist.rs", ok).0.is_empty());
    }

    #[test]
    fn unwrap_is_advisory_and_lock_poison_is_builtin_allowed() {
        let src = "fn f(m: &std::sync::Mutex<i32>, r: Result<i32, ()>) {\n    let a = m.lock().unwrap();\n    let b = r.unwrap();\n}\n";
        let (f, ctx) = run("src/coordinator/registry.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line, f[0].advisory), (RULE_UNWRAP, 3, true));
        assert!(ctx
            .allowances
            .iter()
            .any(|a| a.line == 2 && a.reason.contains("lock-poison")));
        // multiline chain: `.lock()\n.unwrap()` still auto-allowed
        let chained = "fn f(m: &std::sync::Mutex<i32>) {\n    let a = m\n        .lock()\n        .unwrap();\n}\n";
        assert!(run("src/coordinator/registry.rs", chained).0.is_empty());
    }

    #[test]
    fn alloc_fires_outside_reactor_test_mod_only() {
        let src = "fn hot() { let s = x.to_string(); }\n#[cfg(test)]\nmod tests {\n    fn t() { let v = vec![1]; }\n}\n";
        let (f, _) = run("src/coordinator/reactor.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), (RULE_ALLOC, 1));
    }
}
