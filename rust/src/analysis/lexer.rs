//! String/comment-aware lexical scan for the invariant linter.
//!
//! [`scan`] walks a Rust source file once and produces a [`Scan`]:
//!
//! * `masked` — a byte-for-byte copy of the source where every comment
//!   and every string/char-literal *body* is replaced with spaces
//!   (newlines preserved). Token searches over `masked` can therefore
//!   never be fooled by a forbidden token living inside a string
//!   literal or a comment — the same trick the repo's balance-scan
//!   syntax checker uses.
//! * `strings` — every string literal (regular, raw, byte) with its
//!   line, the byte offset of its first content byte in the original
//!   source, and its raw (unescaped-as-written) content. The doc-drift
//!   checker reads op names, error kinds, and field names out of these.
//! * `comments` — one entry **per source line** of every comment (line
//!   comments, doc comments, and each line of a block comment), so rule
//!   code can ask "does line N carry a comment containing X" without
//!   re-lexing.
//!
//! The lexer understands: `//`/`///`/`//!` line comments, nested `/* */`
//! block comments, `"…"` strings with escapes, raw strings `r"…"` /
//! `r#"…"#` (any hash count) and their byte twins `br#"…"#`, byte
//! strings `b"…"`, char literals `'a'` / `'\n'` / `'\u{1F600}'`, and
//! lifetimes (`'a`, which must *not* be consumed as an unterminated
//! char literal). That is everything the crate's own sources use; the
//! linter only ever runs over this repository.

/// One string literal: `line` is 1-based, `start` is the byte offset of
/// the first *content* byte (just past the opening quote) in the
/// original source, `text` is the content as written (escapes not
/// processed — op names and JSON keys never contain escapes).
#[derive(Debug, Clone)]
pub struct StrLit {
    pub line: usize,
    pub start: usize,
    pub text: String,
}

/// One source line's worth of comment text (`//` markers and `/*`/`*/`
/// delimiters stripped from the recorded text's edges, interior kept).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// Result of lexing one file. See the module docs for the fields.
#[derive(Debug, Default)]
pub struct Scan {
    pub masked: String,
    pub strings: Vec<StrLit>,
    pub comments: Vec<Comment>,
}

/// `true` for bytes that can continue a Rust identifier — used to give
/// plain-substring token searches word boundaries.
pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lex `src` (see module docs). Never fails: unterminated constructs
/// simply run to end-of-file, which is fine for a linter that only runs
/// over sources the compiler also accepts.
pub fn scan(src: &str) -> Scan {
    let bytes = src.as_bytes();
    let n = bytes.len();
    let mut masked = Vec::with_capacity(n);
    let mut strings = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    // Push one comment entry per line of `text` starting at `start_line`.
    let mut push_comment = |start_line: usize, text: &str| {
        for (k, part) in text.split('\n').enumerate() {
            let t = part.trim();
            let t = t.strip_prefix("/*").unwrap_or(t);
            let t = t.strip_suffix("*/").unwrap_or(t);
            let t = t.trim_start_matches('/').trim_start_matches('!').trim();
            let t = t.strip_prefix('*').unwrap_or(t).trim();
            comments.push(Comment { line: start_line + k, text: t.to_string() });
        }
    };

    // Copy `len` bytes verbatim into masked, tracking newlines.
    macro_rules! copy {
        ($len:expr) => {{
            let l = $len;
            for _ in 0..l {
                if bytes[i] == b'\n' {
                    line += 1;
                }
                masked.push(bytes[i]);
                i += 1;
            }
        }};
    }
    // Blank `len` bytes (newlines preserved), tracking newlines.
    macro_rules! blank {
        ($len:expr) => {{
            let l = $len;
            for _ in 0..l {
                if bytes[i] == b'\n' {
                    line += 1;
                    masked.push(b'\n');
                } else {
                    masked.push(b' ');
                }
                i += 1;
            }
        }};
    }

    while i < n {
        let b = bytes[i];
        // line comment
        if b == b'/' && i + 1 < n && bytes[i + 1] == b'/' {
            let end = bytes[i..].iter().position(|&c| c == b'\n').map_or(n, |p| i + p);
            push_comment(line, &src[i..end]);
            blank!(end - i);
            continue;
        }
        // block comment (nested)
        if b == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
            let start = i;
            let start_line = line;
            let mut depth = 0usize;
            let mut j = i;
            while j < n {
                if bytes[j] == b'/' && j + 1 < n && bytes[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if bytes[j] == b'*' && j + 1 < n && bytes[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    j += 1;
                }
            }
            push_comment(start_line, &src[start..j.min(n)]);
            blank!(j.min(n) - i);
            continue;
        }
        // raw string r"…" / r#"…"# / br#"…"# (only when `r` starts a token)
        if (b == b'r' || (b == b'b' && i + 1 < n && bytes[i + 1] == b'r'))
            && (i == 0 || !is_ident_byte(bytes[i - 1]))
        {
            let mut j = i + if b == b'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && bytes[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && bytes[j] == b'"' {
                // find closing `"` + `hashes` hashes
                let body_start = j + 1;
                let mut k = body_start;
                let close = loop {
                    if k >= n {
                        break n;
                    }
                    if bytes[k] == b'"' && bytes[k + 1..].iter().take(hashes).filter(|&&c| c == b'#').count() == hashes {
                        break k;
                    }
                    k += 1;
                };
                strings.push(StrLit {
                    line: line + src[i..body_start].matches('\n').count(),
                    start: body_start,
                    text: src[body_start..close].to_string(),
                });
                copy!(body_start - i); // prefix + opening quote stay visible
                blank!(close.min(n) - body_start);
                // closing quote + hashes
                copy!((close + 1 + hashes).min(n) - close.min(n));
                continue;
            }
            // not a raw string — fall through as a normal identifier char
        }
        // string literal (also reached for the `"` of b"…")
        if b == b'"' {
            let body_start = i + 1;
            let mut j = body_start;
            while j < n {
                match bytes[j] {
                    b'\\' => j += 2,
                    b'"' => break,
                    _ => j += 1,
                }
            }
            strings.push(StrLit {
                line,
                start: body_start,
                text: src[body_start..j.min(n)].to_string(),
            });
            copy!(1); // opening quote
            blank!(j.min(n) - body_start);
            if i < n {
                copy!(1); // closing quote
            }
            continue;
        }
        // char literal vs lifetime
        if b == b'\'' {
            // 'x' or '\…' is a char literal; anything else ('a, 'static,
            // '_) is a lifetime and the quote passes through untouched
            let is_char = if i + 1 < n && bytes[i + 1] == b'\\' {
                true
            } else {
                i + 2 < n && bytes[i + 2] == b'\''
            };
            if is_char {
                let mut j = i + 1;
                while j < n {
                    match bytes[j] {
                        b'\\' => j += 2,
                        b'\'' => break,
                        _ => j += 1,
                    }
                }
                copy!(1); // opening quote
                blank!(j.min(n) - (i));
                if i < n {
                    copy!(1); // closing quote
                }
                continue;
            }
        }
        copy!(1);
    }

    Scan {
        masked: String::from_utf8(masked).expect("masking preserves UTF-8 boundaries"),
        strings,
        comments,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_strings_and_comments_but_not_code() {
        let src = r#"
fn f() {
    let a = "format!(inside a string)"; // format! in a comment
    let b = format!("real");
}
"#;
        let s = scan(src);
        assert_eq!(s.masked.len(), src.len());
        // the real macro call survives in masked text
        assert!(s.masked.contains("format!("));
        // exactly once: the string body and the comment are blanked
        assert_eq!(s.masked.matches("format!").count(), 1);
        assert_eq!(s.strings.len(), 2);
        assert_eq!(s.strings[0].text, "format!(inside a string)");
        assert!(s.comments.iter().any(|c| c.text.contains("format! in a comment")));
    }

    #[test]
    fn raw_strings_and_chars_and_lifetimes() {
        let src = "fn g<'a>(x: &'a str) { let c = 'x'; let e = '\\n'; let r = r#\"vec![\"#; }";
        let s = scan(src);
        assert!(!s.masked.contains("vec!"), "raw string body must be blanked");
        assert!(s.masked.contains("<'a>"), "lifetime must survive");
        assert_eq!(s.strings.iter().filter(|l| l.text == "vec![").count(), 1);
        assert_eq!(s.masked.len(), src.len());
    }

    #[test]
    fn nested_block_comments_and_multiline() {
        let src = "a /* outer /* inner */ still */ b\n/* l1\n l2 */ c";
        let s = scan(src);
        assert!(s.masked.contains('a') && s.masked.contains('b') && s.masked.contains('c'));
        assert!(!s.masked.contains("inner") && !s.masked.contains("still"));
        // multiline block comment yields one entry per line
        assert!(s.comments.iter().any(|c| c.line == 2 && c.text.contains("l1")));
        assert!(s.comments.iter().any(|c| c.line == 3 && c.text.contains("l2")));
    }

    #[test]
    fn string_line_and_offset_are_exact() {
        let src = "let x = 1;\nlet op = \"predict\";\n";
        let s = scan(src);
        assert_eq!(s.strings.len(), 1);
        assert_eq!(s.strings[0].line, 2);
        assert_eq!(&src[s.strings[0].start..s.strings[0].start + 7], "predict");
    }
}
