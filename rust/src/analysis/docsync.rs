//! Protocol ↔ documentation consistency checker (`doc-drift` rule).
//!
//! The wire reference (`docs/PROTOCOL.md`) makes enumerable claims —
//! the op table, the error-kind table, the `stats`/`metrics` field
//! lists, the stage taxonomy — that silently rot as the code moves.
//! This module extracts the same enumerations from the *sources*
//! (string literals located via the masked lexical scan, so comments
//! and unrelated strings cannot contaminate them) and cross-checks:
//!
//! | enumeration | code source | doc anchor | direction |
//! |---|---|---|---|
//! | op names | `protocol.rs` `"…" => Op::…` match | `## Ops` table + `### <op>` headings | both |
//! | error kinds | `ParseError::kind()` arms + every literal `err_kind("…")` / `cluster_err("…")` call site | `## Error kinds` table | both |
//! | `stats` fields | `w.key("…")` calls in the `Response::Stats` encode arm | `### stats` response example | both |
//! | `cluster_stats` fields | `w.key("…")` calls in the `Response::ClusterStats` encode arm | `### cluster_stats` response example | both |
//! | `metrics` gauges | the `gauges = vec![…]` table in `router.rs` | `"gauges":{…}` in the `### metrics` example | both |
//! | `metrics` fields | `w.key("…")` calls in the `Response::Metrics` encode arm | `### metrics` section text | code → doc |
//! | stage names | `Stage::… => "…"` arms in `obs/mod.rs` | `### metrics` section text | code → doc |
//!
//! Any mismatch is a hard `doc-drift` finding pointing at the doc
//! section (the doc is what gets edited either way: add the missing
//! row or drop the stale one).

use super::lexer::Scan;
use super::rules::{Finding, RULE_DOC_DRIFT};
use std::collections::BTreeSet;

/// Everything extracted from the sources that the doc must agree with.
#[derive(Debug, Default)]
pub struct CodeInventory {
    pub ops: BTreeSet<String>,
    pub error_kinds: BTreeSet<String>,
    pub stats_keys: BTreeSet<String>,
    pub cluster_stats_keys: BTreeSet<String>,
    pub metrics_keys: BTreeSet<String>,
    pub gauges: BTreeSet<String>,
    pub stages: BTreeSet<String>,
}

/// Is `line` (1-based) inside a `#[cfg(test)]` item? Callers pass the
/// per-file test mask computed by the rules engine.
type TestMask<'a> = &'a dyn Fn(usize) -> bool;

fn line_of(masked: &str, pos: usize) -> usize {
    masked.as_bytes()[..pos].iter().filter(|&&b| b == b'\n').count() + 1
}

fn masked_line(masked: &str, line: usize) -> &str {
    masked.split('\n').nth(line.wrapping_sub(1)).unwrap_or("")
}

/// Op names: string literals on non-test lines of `protocol.rs` whose
/// masked text contains a `=> Op::` match arm.
pub fn ops_in_code(protocol: &Scan, in_test: TestMask) -> BTreeSet<String> {
    protocol
        .strings
        .iter()
        .filter(|l| !in_test(l.line) && masked_line(&protocol.masked, l.line).contains("=> Op::"))
        .map(|l| l.text.clone())
        .collect()
}

/// Stage names: literals on `Stage::… => "…"` arms of `obs/mod.rs`.
pub fn stages_in_code(obs: &Scan, in_test: TestMask) -> BTreeSet<String> {
    obs.strings
        .iter()
        .filter(|l| {
            let ml = masked_line(&obs.masked, l.line);
            !in_test(l.line) && ml.contains("Stage::") && ml.contains("=>")
        })
        .map(|l| l.text.clone())
        .collect()
}

/// Error kinds from one file: `ParseError::… => "…"` arms (the parser's
/// own `kind()` table — the literal must directly follow `=>`, which
/// excludes `Display` arms like `… => write!(f, "…")`) plus the first
/// literal argument of every `err_kind(` and `cluster_err(` call site
/// (the route tier's structured per-node errors carry a kind too). A
/// non-literal first argument (e.g. `err_kind(e.kind(), …)`)
/// contributes nothing: the literal must follow the call with only
/// whitespace and the opening quote in between.
pub fn error_kinds_in_code(scan: &Scan, in_test: TestMask, out: &mut BTreeSet<String>) {
    for l in &scan.strings {
        if in_test(l.line) || l.start == 0 {
            continue;
        }
        let ml = masked_line(&scan.masked, l.line);
        if ml.contains("ParseError::")
            && scan.masked[..l.start - 1].trim_end().ends_with("=>")
        {
            out.insert(l.text.clone());
        }
    }
    for needle in ["err_kind(", "cluster_err("] {
        for (pos, _) in scan.masked.match_indices(needle) {
            let call_end = pos + needle.len();
            if in_test(line_of(&scan.masked, pos)) {
                continue;
            }
            if let Some(lit) = scan.strings.iter().find(|l| l.start > call_end) {
                let between = &scan.masked[call_end..lit.start.min(scan.masked.len())];
                if between.chars().all(|c| c.is_whitespace() || c == '"') {
                    out.insert(lit.text.clone());
                }
            }
        }
    }
}

/// `w.key("…")` literals between the `anchor` occurrence (e.g.
/// `Response::Stats`) and the next `Response::` token — i.e. the keys
/// one encode arm emits.
pub fn keys_in_encode_arm(scan: &Scan, anchor: &str, in_test: TestMask) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for (pos, _) in scan.masked.match_indices(anchor) {
        if in_test(line_of(&scan.masked, pos)) {
            continue;
        }
        let start = pos + anchor.len();
        let end = scan.masked[start..]
            .find("Response::")
            .map_or(scan.masked.len(), |p| start + p);
        for l in &scan.strings {
            if l.start > start
                && l.start < end
                && l.start >= 1
                && scan.masked[..l.start - 1].ends_with(".key(")
            {
                out.insert(l.text.clone());
            }
        }
    }
    out
}

/// Gauge names: every literal inside the `gauges = vec![ … ];` table.
pub fn gauges_in_code(router: &Scan, in_test: TestMask) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for (pos, _) in router.masked.match_indices("gauges = vec![") {
        if in_test(line_of(&router.masked, pos)) {
            continue;
        }
        let end = router.masked[pos..].find("];").map_or(router.masked.len(), |p| pos + p);
        for l in &router.strings {
            if l.start > pos && l.start < end {
                out.insert(l.text.clone());
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// Markdown side
// ---------------------------------------------------------------------

/// A `#`-heading section of the doc: (1-based heading line, body text
/// from the heading to the next heading of the same or higher level).
pub fn md_section(doc: &str, heading: &str) -> Option<(usize, String)> {
    let level = heading.bytes().take_while(|&b| b == b'#').count();
    let lines: Vec<&str> = doc.split('\n').collect();
    let start = lines.iter().position(|l| l.trim_end() == heading)?;
    let mut body = String::new();
    for l in &lines[start + 1..] {
        let hashes = l.bytes().take_while(|&b| b == b'#').count();
        if hashes > 0 && hashes <= level && l.as_bytes().get(hashes) == Some(&b' ') {
            break;
        }
        body.push_str(l);
        body.push('\n');
    }
    Some((start + 1, body))
}

/// First-column backticked tokens of a markdown table: rows look like
/// ``| [`name`](#anchor) | …`` or ``| `name` | …``.
pub fn md_table_tokens(section: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in section.split('\n') {
        let t = line.trim_start();
        if !t.starts_with("| [`") && !t.starts_with("| `") {
            continue;
        }
        let after = &t[t.find('`').map(|p| p + 1).unwrap_or(t.len())..];
        if let Some(end) = after.find('`') {
            out.insert(after[..end].to_string());
        }
    }
    out
}

/// Fenced code blocks (``` … ```), in order.
pub fn md_code_blocks(section: &str) -> Vec<String> {
    let mut blocks = Vec::new();
    let mut cur: Option<String> = None;
    for line in section.split('\n') {
        if line.trim_start().starts_with("```") {
            match cur.take() {
                Some(b) => blocks.push(b),
                None => cur = Some(String::new()),
            }
            continue;
        }
        if let Some(b) = cur.as_mut() {
            b.push_str(line);
            b.push('\n');
        }
    }
    blocks
}

/// `"ident":` keys of a JSON-ish example text.
pub fn json_example_keys(block: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let b = block.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'"' {
            if let Some(close) = block[i + 1..].find('"') {
                let name = &block[i + 1..i + 1 + close];
                let rest = &b[i + 1 + close + 1..];
                if rest.first() == Some(&b':')
                    && !name.is_empty()
                    && name.bytes().all(|c| c.is_ascii_lowercase() || c == b'_' || c.is_ascii_digit())
                {
                    out.insert(name.to_string());
                }
                i += close + 2;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// The response example keys of an op section: the union of every
/// fenced block that is *not* the request (requests carry `"op":`).
fn response_example_keys(section: &str) -> BTreeSet<String> {
    md_code_blocks(section)
        .iter()
        .filter(|b| !b.contains("\"op\":"))
        .flat_map(|b| json_example_keys(b))
        .collect()
}

fn drift(doc_file: &str, line: usize, msg: String) -> Finding {
    Finding {
        file: doc_file.to_string(),
        line,
        rule: RULE_DOC_DRIFT,
        message: msg,
        snippet: String::new(),
        advisory: false,
    }
}

fn compare_sets(
    what: &str,
    code: &BTreeSet<String>,
    doc: &BTreeSet<String>,
    doc_file: &str,
    line: usize,
    findings: &mut Vec<Finding>,
) {
    for missing in code.difference(doc) {
        findings.push(drift(
            doc_file,
            line,
            format!("{what}: code has `{missing}` but the doc does not list it"),
        ));
    }
    for stale in doc.difference(code) {
        findings.push(drift(
            doc_file,
            line,
            format!("{what}: doc lists `{stale}` but the code does not produce it"),
        ));
    }
}

/// Cross-check one [`CodeInventory`] against the protocol doc text.
pub fn check_doc(
    inv: &CodeInventory,
    doc: &str,
    doc_file: &str,
    findings: &mut Vec<Finding>,
) {
    // ops table + per-op section headings
    match md_section(doc, "## Ops") {
        Some((line, body)) => {
            compare_sets("op table", &inv.ops, &md_table_tokens(&body), doc_file, line, findings);
        }
        None => findings.push(drift(doc_file, 1, "missing `## Ops` section".into())),
    }
    for op in &inv.ops {
        if md_section(doc, &format!("### {op}")).is_none() {
            findings.push(drift(doc_file, 1, format!("op `{op}` has no `### {op}` section")));
        }
    }

    // error kinds
    match md_section(doc, "## Error kinds") {
        Some((line, body)) => compare_sets(
            "error-kind table",
            &inv.error_kinds,
            &md_table_tokens(&body),
            doc_file,
            line,
            findings,
        ),
        None => findings.push(drift(doc_file, 1, "missing `## Error kinds` section".into())),
    }

    // stats response fields
    if let Some((line, body)) = md_section(doc, "### stats") {
        compare_sets(
            "stats fields",
            &inv.stats_keys,
            &response_example_keys(&body),
            doc_file,
            line,
            findings,
        );
    }

    // cluster_stats response fields (the route tier's own op) — nested
    // per-backend keys included, the doc example must show them all
    if let Some((line, body)) = md_section(doc, "### cluster_stats") {
        compare_sets(
            "cluster_stats fields",
            &inv.cluster_stats_keys,
            &response_example_keys(&body),
            doc_file,
            line,
            findings,
        );
    } else if !inv.cluster_stats_keys.is_empty() {
        findings.push(drift(doc_file, 1, "missing `### cluster_stats` section".into()));
    }

    // metrics: gauges exactly, other emitted keys + stage names by mention
    if let Some((line, body)) = md_section(doc, "### metrics") {
        let doc_gauges: BTreeSet<String> = body
            .find("\"gauges\":{")
            .map(|p| {
                let after = &body[p + "\"gauges\":{".len()..];
                let end = after.find('}').unwrap_or(after.len());
                json_example_keys(&after[..end])
            })
            .unwrap_or_default();
        compare_sets("metrics gauges", &inv.gauges, &doc_gauges, doc_file, line, findings);
        for key in &inv.metrics_keys {
            if key == "gauges" || doc_gauges.contains(key) {
                continue;
            }
            if !body.contains(&format!("\"{key}\"")) && !body.contains(&format!("`{key}`")) {
                findings.push(drift(
                    doc_file,
                    line,
                    format!("metrics fields: code emits `{key}` but the section never mentions it"),
                ));
            }
        }
        for stage in &inv.stages {
            if !body.contains(&format!("`{stage}`")) {
                findings.push(drift(
                    doc_file,
                    line,
                    format!("stage taxonomy: code records stage `{stage}` but the section never mentions it"),
                ));
            }
        }
    } else {
        findings.push(drift(doc_file, 1, "missing `### metrics` section".into()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::scan;

    fn never_test(_: usize) -> bool {
        false
    }

    #[test]
    fn extracts_ops_error_kinds_and_keys() {
        let src = r#"
fn parse(op: &str) {
    let op = match op {
        "health" => Op::Health,
        "predict" => Op::Predict,
        other => return Err(ParseError::UnknownOp(other.to_string())),
    };
}
impl ParseError {
    fn kind(&self) -> &'static str {
        match self {
            ParseError::UnknownOp(_) => "unknown_op",
            ParseError::Malformed(_) => "bad_request",
        }
    }
}
fn encode(w: &mut W) {
    match self {
        Response::Stats { .. } => {
            w.key("ok").bool_(true);
            w.key("requests").num(1.0);
        }
        Response::Err { .. } => {
            w.key("error").str_("x");
        }
    }
    let e = Response::err_kind(
        "overloaded",
        format!("queue full"),
    );
    let f = Response::err_kind(e.kind(), format!("bad request"));
    let g = Response::cluster_err(
        "epoch_divergence",
        "nodes disagree".to_string(),
        Vec::new(),
    );
}
"#;
        let s = scan(src);
        let ops = ops_in_code(&s, &never_test);
        assert_eq!(ops, ["health", "predict"].iter().map(|s| s.to_string()).collect());
        let mut kinds = std::collections::BTreeSet::new();
        error_kinds_in_code(&s, &never_test, &mut kinds);
        assert_eq!(
            kinds,
            ["unknown_op", "bad_request", "overloaded", "epoch_divergence"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            "literal-first err_kind/cluster_err only — `e.kind()` site contributes nothing"
        );
        let keys = keys_in_encode_arm(&s, "Response::Stats", &never_test);
        assert_eq!(keys, ["ok", "requests"].iter().map(|s| s.to_string()).collect());
    }

    #[test]
    fn markdown_tables_sections_and_examples() {
        let doc = "# P\n\n## Ops\n\n| op | x |\n|---|---|\n| [`health`](#health) | h |\n| [`predict`](#predict) | p |\n\n### health\n\n```json\n{\"op\":\"health\"}\n```\n```json\n{\"ok\":true,\"status\":\"healthy\"}\n```\n\n### predict\n\nbody\n\n## Error kinds\n\n| kind | m |\n|---|---|\n| `bad_request` | b |\n";
        let (line, ops_body) = md_section(doc, "## Ops").unwrap();
        assert_eq!(line, 3);
        assert_eq!(
            md_table_tokens(&ops_body),
            ["health", "predict"].iter().map(|s| s.to_string()).collect()
        );
        // section body stops at the next ## — it still includes ### subsections
        assert!(ops_body.contains("### health"));
        let (_, health) = md_section(doc, "### health").unwrap();
        let blocks = md_code_blocks(&health);
        assert_eq!(blocks.len(), 2);
        assert_eq!(
            json_example_keys(&blocks[1]),
            ["ok", "status"].iter().map(|s| s.to_string()).collect()
        );
    }

    #[test]
    fn drift_is_detected_in_both_directions() {
        let mut inv = CodeInventory::default();
        inv.ops.insert("health".into());
        inv.ops.insert("brand_new_op".into());
        let doc = "## Ops\n\n| [`health`](#health) | h |\n| [`removed_op`](#removed_op) | r |\n\n### health\n\n## Error kinds\n\n### metrics\n\nx\n";
        let mut findings = Vec::new();
        check_doc(&inv, doc, "docs/PROTOCOL.md", &mut findings);
        assert!(
            findings.iter().any(|f| f.message.contains("`brand_new_op`")
                && f.message.contains("doc does not list")),
            "{findings:?}"
        );
        assert!(
            findings.iter().any(|f| f.message.contains("`removed_op`")
                && f.message.contains("code does not produce")),
            "{findings:?}"
        );
        assert!(
            findings.iter().any(|f| f.message.contains("no `### brand_new_op` section")),
            "{findings:?}"
        );
        assert!(findings.iter().all(|f| f.rule == RULE_DOC_DRIFT && f.file == "docs/PROTOCOL.md"));
    }
}
