//! Minimal in-tree stand-in for the `xla` (PJRT bindings) crate.
//!
//! The offline build environment does not ship the xla-rs native bindings,
//! so this shim keeps the crate compiling and linking everywhere: it
//! mirrors exactly the API surface `runtime::Runtime` consumes and fails
//! fast — `PjRtClient::cpu()` returns an error, so `Runtime::load` reports
//! a clear "backend not available" failure instead of a link error, and
//! every runtime-dependent test/bench skips gracefully.
//!
//! To run against real PJRT, add the xla-rs bindings to Cargo.toml and
//! replace the `use self::xla_shim as xla;` alias in `runtime/mod.rs` with
//! `use xla;` — no other code changes are required.

use std::path::Path;

/// Error type mirroring the bindings' debug-printable errors.
#[derive(Debug)]
pub struct XlaError(pub String);

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "PJRT/xla bindings are not linked in this build; see runtime/xla_shim.rs".into(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}
