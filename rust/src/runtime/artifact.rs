//! Artifact metadata (`artifacts/meta.json`) and the MLP parameter layout.
//!
//! The layout here must stay byte-identical to
//! `python/compile/kernels/ref.py::mlp_param_sizes` — the flat vector the
//! rust trainer holds is consumed directly by the HLO train step.

use crate::util::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// The paper's dense stack: 128x64x32x16x1 (Sec III-C1).
pub const HIDDEN: [usize; 5] = [128, 64, 32, 16, 1];

/// `[( (fan_in, fan_out), bias_len ), ...]` for the dense stack.
pub fn mlp_param_sizes(d_in: usize) -> Vec<((usize, usize), usize)> {
    let mut sizes = Vec::with_capacity(HIDDEN.len());
    let mut prev = d_in;
    for &h in HIDDEN.iter() {
        sizes.push(((prev, h), h));
        prev = h;
    }
    sizes
}

/// Total flat parameter count for input dim `d_in`.
pub fn mlp_param_count(d_in: usize) -> usize {
    mlp_param_sizes(d_in)
        .iter()
        .map(|((i, o), b)| i * o + b)
        .sum()
}

/// Adam hyper-parameters recorded by the AOT step.
#[derive(Debug, Clone)]
pub struct AdamMeta {
    pub lr: f64,
    pub b1: f64,
    pub b2: f64,
    pub eps: f64,
}

/// Shapes the artifacts were lowered with (python/compile/aot.py).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Clustered-feature vector width the MLP consumes (padded).
    pub d_feat: usize,
    /// Serving batch of `mlp_fwd.hlo.txt`.
    pub b_pred: usize,
    /// Training minibatch of `mlp_train.hlo.txt`.
    pub b_train: usize,
    /// Flat parameter count (must equal `mlp_param_count(d_feat)`).
    pub param_count: usize,
    /// Levenshtein artifact: pairs per call.
    pub lev_k: usize,
    /// Levenshtein artifact: padded name width.
    pub lev_l: usize,
    pub hidden: Vec<usize>,
    pub adam: AdamMeta,
}

impl ArtifactMeta {
    /// Parse `meta.json`, validating the parameter-count invariant.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let j = Json::parse(&text).context("parsing meta.json")?;
        let adam = j
            .get("adam")
            .ok_or_else(|| anyhow::anyhow!("missing adam block"))?;
        let meta = Self {
            d_feat: j.req_usize("d_feat")?,
            b_pred: j.req_usize("b_pred")?,
            b_train: j.req_usize("b_train")?,
            param_count: j.req_usize("param_count")?,
            lev_k: j.req_usize("lev_k")?,
            lev_l: j.req_usize("lev_l")?,
            hidden: j
                .req_arr("hidden")?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            adam: AdamMeta {
                lr: adam.req_f64("lr")?,
                b1: adam.req_f64("b1")?,
                b2: adam.req_f64("b2")?,
                eps: adam.req_f64("eps")?,
            },
        };
        anyhow::ensure!(
            meta.param_count == mlp_param_count(meta.d_feat),
            "meta.json param_count {} != layout {}",
            meta.param_count,
            mlp_param_count(meta.d_feat)
        );
        anyhow::ensure!(meta.hidden == HIDDEN.to_vec(), "hidden layout mismatch");
        Ok(meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_python() {
        // D=48 reference value (same formula asserted in python tests).
        let want = 48 * 128 + 128 + 128 * 64 + 64 + 64 * 32 + 32 + 32 * 16 + 16 + 16 + 1;
        assert_eq!(mlp_param_count(48), want);
    }

    #[test]
    fn sizes_chain() {
        let sizes = mlp_param_sizes(10);
        assert_eq!(sizes[0].0, (10, 128));
        assert_eq!(sizes[4].0, (16, 1));
        for w in sizes.windows(2) {
            assert_eq!(w[0].0 .1, w[1].0 .0, "fan-out chains to fan-in");
        }
    }
}
