//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! This is the only bridge between the rust coordinator and the JAX/Pallas
//! build products. Artifacts are HLO *text* (`artifacts/*.hlo.txt`) because
//! jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! python/compile/aot.py and DESIGN.md §3).
//!
//! One [`Runtime`] owns the PJRT CPU client plus one compiled executable
//! per artifact; [`MlpState`] threads the flat parameter/optimizer vectors
//! through train steps without any pytree reconstruction.

mod artifact;
mod xla_shim;

/// PJRT bindings alias: the in-tree shim by default (the offline build has
/// no xla-rs native bindings — `Runtime::load` then fails with a clear
/// message). Point this at the real crate to execute artifacts.
use self::xla_shim as xla;

pub use artifact::{ArtifactMeta, mlp_param_count, mlp_param_sizes};

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Names of the three artifacts produced by `make artifacts`.
pub const ART_MLP_FWD: &str = "mlp_fwd";
pub const ART_MLP_TRAIN: &str = "mlp_train";
pub const ART_LEVENSHTEIN: &str = "levenshtein";

/// Flat DNN training state (mirrors python/compile/model.py::train_step).
#[derive(Debug, Clone)]
pub struct MlpState {
    /// Flat parameter vector, length `meta.param_count`.
    pub params: Vec<f32>,
    /// Adam first-moment vector.
    pub m: Vec<f32>,
    /// Adam second-moment vector.
    pub v: Vec<f32>,
    /// Step counter (f32 scalar in the artifact signature).
    pub t: f32,
}

impl MlpState {
    /// He-uniform init of the dense stack (biases zero), deterministic.
    pub fn init(d_feat: usize, seed: u64) -> Self {
        let mut rng = crate::util::Rng64::new(seed);
        let mut params = vec![0f32; mlp_param_count(d_feat)];
        let mut off = 0;
        for ((wi, wo), bo) in mlp_param_sizes(d_feat) {
            let lim = (6.0 / wi as f64).sqrt();
            for p in params[off..off + wi * wo].iter_mut() {
                *p = rng.range(-lim, lim) as f32;
            }
            off += wi * wo + bo; // biases stay zero
        }
        let n = params.len();
        Self {
            params,
            m: vec![0f32; n],
            v: vec![0f32; n],
            t: 0.0,
        }
    }
}

/// PJRT CPU runtime holding the compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    fwd: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    lev: xla::PjRtLoadedExecutable,
    /// Shapes/dims the artifacts were lowered with.
    pub meta: ArtifactMeta,
}

impl Runtime {
    /// Load and compile all artifacts from a directory (usually `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let meta = ArtifactMeta::load(dir.join("meta.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path: PathBuf = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))
        };
        Ok(Self {
            fwd: compile(ART_MLP_FWD)?,
            train: compile(ART_MLP_TRAIN)?,
            lev: compile(ART_LEVENSHTEIN)?,
            client,
            meta,
        })
    }

    /// Backend platform name (always "cpu"/"Host" here).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(data);
        if dims.len() == 1 && dims[0] as usize == data.len() {
            Ok(lit)
        } else {
            lit.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
        }
    }

    fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(data);
        if dims.len() == 1 && dims[0] as usize == data.len() {
            Ok(lit)
        } else {
            lit.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
        }
    }

    fn run(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }

    /// Batched MLP inference: `x` is row-major `[b_pred, d_feat]`.
    /// Returns `yhat[b_pred]`.
    pub fn mlp_forward(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let m = &self.meta;
        anyhow::ensure!(params.len() == m.param_count, "param len");
        anyhow::ensure!(x.len() == m.b_pred * m.d_feat, "x len");
        let args = [
            Self::lit_f32(params, &[m.param_count as i64])?,
            Self::lit_f32(x, &[m.b_pred as i64, m.d_feat as i64])?,
        ];
        let out = Self::run(&self.fwd, &args)?;
        out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("fwd out: {e:?}"))
    }

    /// One Adam train step over a `[b_train, d_feat]` minibatch.
    /// Mutates `state` in place and returns the step loss.
    pub fn train_step(&self, state: &mut MlpState, x: &[f32], y: &[f32]) -> Result<f32> {
        let m = &self.meta;
        anyhow::ensure!(x.len() == m.b_train * m.d_feat, "x len");
        anyhow::ensure!(y.len() == m.b_train, "y len");
        let p = m.param_count as i64;
        let args = [
            Self::lit_f32(&state.params, &[p])?,
            Self::lit_f32(&state.m, &[p])?,
            Self::lit_f32(&state.v, &[p])?,
            Self::lit_f32(&[state.t], &[])?,
            Self::lit_f32(x, &[m.b_train as i64, m.d_feat as i64])?,
            Self::lit_f32(y, &[m.b_train as i64])?,
        ];
        let out = Self::run(&self.train, &args)?;
        anyhow::ensure!(out.len() == 5, "train step arity {}", out.len());
        state.params = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        state.m = out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        state.v = out[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        state.t = out[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        let loss = out[4].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok(loss)
    }

    /// Batched Levenshtein over `lev_k` padded name pairs of width `lev_l`.
    pub fn levenshtein(
        &self,
        a: &[i32],
        b: &[i32],
        la: &[i32],
        lb: &[i32],
    ) -> Result<Vec<i32>> {
        let m = &self.meta;
        let (k, l) = (m.lev_k, m.lev_l);
        anyhow::ensure!(a.len() == k * l && b.len() == k * l, "pair matrix len");
        anyhow::ensure!(la.len() == k && lb.len() == k, "length vec len");
        let args = [
            Self::lit_i32(a, &[k as i64, l as i64])?,
            Self::lit_i32(b, &[k as i64, l as i64])?,
            Self::lit_i32(la, &[k as i64])?,
            Self::lit_i32(lb, &[k as i64])?,
        ];
        let out = Self::run(&self.lev, &args)?;
        out[0].to_vec::<i32>().map_err(|e| anyhow!("lev out: {e:?}"))
    }

    /// Levenshtein over arbitrary-many string pairs, chunked into the fixed
    /// artifact batch. Strings longer than `lev_l` are truncated (profiler
    /// op names are all shorter in practice).
    pub fn levenshtein_strs(&self, pairs: &[(&str, &str)]) -> Result<Vec<i32>> {
        let (k, l) = (self.meta.lev_k, self.meta.lev_l);
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(k) {
            let mut a = vec![0i32; k * l];
            let mut b = vec![0i32; k * l];
            let mut la = vec![0i32; k];
            let mut lb = vec![0i32; k];
            for (i, (s1, s2)) in chunk.iter().enumerate() {
                for (j, c) in s1.chars().take(l).enumerate() {
                    a[i * l + j] = c as i32;
                }
                for (j, c) in s2.chars().take(l).enumerate() {
                    b[i * l + j] = c as i32;
                }
                la[i] = s1.chars().count().min(l) as i32;
                lb[i] = s2.chars().count().min(l) as i32;
            }
            let d = self.levenshtein(&a, &b, &la, &lb)?;
            out.extend_from_slice(&d[..chunk.len()]);
        }
        Ok(out)
    }
}

/// Locate the artifacts directory: `$REPRO_ARTIFACTS` or `artifacts/`
/// relative to the crate root (works from tests/benches/examples).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("REPRO_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.join("artifacts")
}

/// Load the runtime from the default artifact location with a helpful error.
pub fn load_default() -> Result<Runtime> {
    let dir = default_artifact_dir();
    Runtime::load(&dir).with_context(|| {
        format!(
            "loading artifacts from {} — run `make artifacts` first",
            dir.display()
        )
    })
}
