//! Sharded, capacity-bounded memoization cache for phase-1 predictions.
//!
//! Keyed by (registry epoch, anchor, target, quantized anchor latency,
//! quantized profile fingerprint). The value is the exact `(latency,
//! member)` pair the ensemble produced, stored verbatim — a cache hit
//! returns a prediction bitwise-equal to the cold one it memoizes.
//! Quantization (2^20 buckets per millisecond) only widens the *key*: two
//! requests whose profile values agree to within ~1 ppm of a millisecond
//! share an entry; anything coarser gets its own.
//!
//! The **epoch** component makes the cache registry-swap-safe: when the
//! coordinator's model registry publishes a new epoch (see
//! `crate::coordinator::registry`), every key built afterwards carries the
//! new epoch, so entries computed by the old models simply stop matching —
//! no stop-the-world flush, no lock over the whole cache. Stale entries
//! age out through the normal per-shard FIFO eviction. Library callers
//! without a registry pass any fixed epoch (by convention `0`).
//!
//! The shard array bounds lock hold times and keeps contention negligible
//! when multiple threads consult the cache concurrently; each shard is
//! independently capacity-bounded with FIFO eviction, so the cache as a
//! whole never holds more than `n_shards * per_shard_cap` entries.

use crate::gpu::Instance;
use crate::predictor::Member;
use crate::util::fnv1a;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Key-quantization scale: buckets per millisecond.
const Q: f64 = (1u64 << 20) as f64;

/// Quantized key encoding of a millisecond value. The low 64 bits hold
/// either the rounded bucket or — for values whose scaled form leaves
/// the exactly-representable integer range (absurd-scale or non-finite
/// inputs the protocol layer rejects, but library callers may pass) —
/// the raw f64 bit pattern. Bit 64 tags which encoding was used, so the
/// two branches occupy disjoint ranges and two distinct values can never
/// alias to one bucket.
fn quantize(v: f64) -> u128 {
    let q = v * Q;
    if q.abs() < 9.0e15 {
        (q.round() as i64) as u64 as u128
    } else {
        (1u128 << 64) | v.to_bits() as u128
    }
}

/// Canonical quantized profile byte stream + its FNV-1a fingerprint.
/// Build once per profile and share across the per-target keys of a
/// sweep (the stream is `Arc`-shared, never copied per key).
#[derive(Debug, Clone)]
pub struct ProfileFingerprint {
    bytes: std::sync::Arc<Vec<u8>>,
    fingerprint: u64,
}

impl ProfileFingerprint {
    pub fn of(profile: &BTreeMap<String, f64>) -> ProfileFingerprint {
        // BTreeMap iteration is sorted and each record is length-prefixed
        // (name length, name bytes, 16-byte quantized value), so the byte
        // stream parses unambiguously — it is *injective* over profiles:
        // no choice of op names (which are client-controlled and may
        // contain any bytes) can make two distinct profiles collide.
        let mut bytes = Vec::with_capacity(profile.len() * 32);
        for (op, ms) in profile {
            bytes.extend_from_slice(&(op.len() as u64).to_le_bytes());
            bytes.extend_from_slice(op.as_bytes());
            bytes.extend_from_slice(&quantize(*ms).to_le_bytes());
        }
        let fingerprint = fnv1a(&bytes);
        ProfileFingerprint {
            bytes: std::sync::Arc::new(bytes),
            fingerprint,
        }
    }
}

/// Cache key: registry epoch + instance pair + quantized anchor latency +
/// the canonical quantized profile byte stream. The full byte stream
/// participates in equality AND in the derived `Hash` (so the map's keyed
/// SipHash sees the client-controlled bytes — crafted FNV collisions
/// cannot force HashMap bucket pile-ups): a fingerprint collision between
/// two different profiles degrades to a cache miss, never the wrong
/// workload's prediction. `route` is only the shard selector, folding in
/// every key component so per-target keys of one sweep spread across
/// shards. The epoch participates in equality, hash, and route: entries
/// from a superseded model epoch can never answer a current-epoch lookup.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CacheKey {
    pub epoch: u64,
    pub anchor: Instance,
    pub target: Instance,
    lat_q: u128,
    fingerprint: u64,
    bytes: std::sync::Arc<Vec<u8>>,
    route: u64,
}

impl CacheKey {
    pub fn of(
        epoch: u64,
        anchor: Instance,
        target: Instance,
        anchor_latency_ms: f64,
        profile: &BTreeMap<String, f64>,
    ) -> CacheKey {
        CacheKey::keyed(
            epoch,
            anchor,
            target,
            anchor_latency_ms,
            &ProfileFingerprint::of(profile),
        )
    }

    /// Key from a precomputed profile fingerprint — the byte stream is
    /// shared, only the (epoch, anchor, target, latency) header is hashed
    /// here.
    pub fn keyed(
        epoch: u64,
        anchor: Instance,
        target: Instance,
        anchor_latency_ms: f64,
        pf: &ProfileFingerprint,
    ) -> CacheKey {
        let lat_q = quantize(anchor_latency_ms);
        let mut header = Vec::with_capacity(40);
        header.extend_from_slice(&epoch.to_le_bytes());
        header.push(0x1f);
        header.extend_from_slice(anchor.key().as_bytes());
        header.push(0x1f);
        header.extend_from_slice(target.key().as_bytes());
        header.push(0x1f);
        header.extend_from_slice(&lat_q.to_le_bytes());
        CacheKey {
            epoch,
            anchor,
            target,
            lat_q,
            fingerprint: pf.fingerprint,
            bytes: pf.bytes.clone(),
            route: fnv1a(&header) ^ pf.fingerprint,
        }
    }
}

/// Allocation-free [`CacheKey`] construction for the wire hot path.
///
/// The canonical profile byte stream normally lives in a fresh
/// `Arc<Vec<u8>>` per key; this scratch *reuses* one across calls
/// (`Arc::get_mut` succeeds as long as the previously returned key has
/// been dropped — the router's peek-then-drop flow guarantees it), so a
/// warm `key()` call performs zero heap allocations. If a caller does
/// retain a key (e.g. inserts it into the cache), the next call detects
/// the shared `Arc` and self-heals with one fresh allocation.
///
/// Keys built here are `==` (and hash-identical) to [`CacheKey::of`] over
/// the materialized profile, provided `pairs` is sorted by key with
/// duplicate keys removed (the wire layer's `sort_dedup_pairs` order —
/// the same order a `BTreeMap` iterates).
#[derive(Default)]
pub struct CacheKeyScratch {
    bytes: Option<std::sync::Arc<Vec<u8>>>,
    header: Vec<u8>,
}

impl CacheKeyScratch {
    pub fn key<'a>(
        &mut self,
        epoch: u64,
        anchor: Instance,
        target: Instance,
        anchor_latency_ms: f64,
        pairs: impl Iterator<Item = (&'a str, f64)>,
    ) -> CacheKey {
        let mut arc = self
            .bytes
            .take()
            .unwrap_or_else(|| std::sync::Arc::new(Vec::new()));
        if std::sync::Arc::get_mut(&mut arc).is_none() {
            arc = std::sync::Arc::new(Vec::new());
        }
        let buf = std::sync::Arc::get_mut(&mut arc).unwrap();
        buf.clear();
        for (op, ms) in pairs {
            buf.extend_from_slice(&(op.len() as u64).to_le_bytes());
            buf.extend_from_slice(op.as_bytes());
            buf.extend_from_slice(&quantize(ms).to_le_bytes());
        }
        let fingerprint = fnv1a(buf);
        let lat_q = quantize(anchor_latency_ms);
        self.header.clear();
        self.header.extend_from_slice(&epoch.to_le_bytes());
        self.header.push(0x1f);
        self.header.extend_from_slice(anchor.key().as_bytes());
        self.header.push(0x1f);
        self.header.extend_from_slice(target.key().as_bytes());
        self.header.push(0x1f);
        self.header.extend_from_slice(&lat_q.to_le_bytes());
        let key = CacheKey {
            epoch,
            anchor,
            target,
            lat_q,
            fingerprint,
            bytes: arc.clone(),
            route: fnv1a(&self.header) ^ fingerprint,
        };
        self.bytes = Some(arc);
        key
    }
}

/// Hit/miss counters. Embedded in the coordinator's `EngineStats` (shared
/// across every engine replica of the pool) so the `stats` op surfaces
/// them; the advisor sweep shares the same counters.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

struct Shard {
    map: HashMap<CacheKey, (f64, Member)>,
    /// Insertion order for FIFO eviction (keys are pushed exactly once:
    /// on first insert; value updates do not reorder).
    fifo: VecDeque<CacheKey>,
}

/// The sharded cache. All methods take `&self`; interior mutability is one
/// mutex per shard.
pub struct PredictionCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_cap: usize,
}

impl PredictionCache {
    /// `capacity` is the total entry bound, split evenly across shards
    /// (rounded up to at least one entry per shard).
    pub fn new(n_shards: usize, capacity: usize) -> PredictionCache {
        let n_shards = n_shards.max(1);
        let per_shard_cap = ((capacity + n_shards - 1) / n_shards).max(1);
        PredictionCache {
            shards: (0..n_shards)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::new(),
                        fifo: VecDeque::new(),
                    })
                })
                .collect(),
            per_shard_cap,
        }
    }

    fn shard_of(&self, key: &CacheKey) -> &Mutex<Shard> {
        &self.shards[(key.route % self.shards.len() as u64) as usize]
    }

    /// Counter-free lookup for the router's wire-layer fast path: a miss
    /// there is not a real miss (the engine lane re-checks and counts),
    /// so only the lane's `get` touches the hit/miss statistics for it.
    pub fn peek(&self, key: &CacheKey) -> Option<(f64, Member)> {
        self.shard_of(key).lock().unwrap().map.get(key).copied()
    }

    /// Look up a prediction, counting the outcome in `stats`.
    pub fn get(&self, key: &CacheKey, stats: &CacheStats) -> Option<(f64, Member)> {
        let got = self.shard_of(key).lock().unwrap().map.get(key).copied();
        // ordering: hit/miss tallies are stats-only monotonic counters read
        // by the metrics snapshot; they order nothing.
        match got {
            Some(_) => stats.hits.fetch_add(1, Ordering::Relaxed),
            None => stats.misses.fetch_add(1, Ordering::Relaxed),
        };
        got
    }

    /// Insert (or refresh) a prediction, evicting oldest-first past the
    /// shard capacity.
    pub fn insert(&self, key: CacheKey, value: (f64, Member)) {
        let mut shard = self.shard_of(&key).lock().unwrap();
        if shard.map.insert(key.clone(), value).is_none() {
            shard.fifo.push_back(key);
            while shard.map.len() > self.per_shard_cap {
                match shard.fifo.pop_front() {
                    Some(old) => {
                        shard.map.remove(&old);
                    }
                    None => break,
                }
            }
        }
    }

    /// Total entries across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hard entry bound (`n_shards * per_shard_cap`).
    pub fn capacity(&self) -> usize {
        self.per_shard_cap * self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn identical_inputs_share_a_key() {
        let p = profile(&[("Conv2D", 286.0), ("Relu", 26.0)]);
        let a = CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p);
        let b = CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p.clone());
        assert_eq!(a, b);
    }

    #[test]
    fn key_separates_pairs_latency_and_profiles() {
        let p = profile(&[("Conv2D", 286.0)]);
        let base = CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p);
        assert_ne!(base, CacheKey::of(0, Instance::G4dn, Instance::P2, 42.5, &p));
        assert_ne!(base, CacheKey::of(0, Instance::P3, Instance::G4dn, 42.5, &p));
        assert_ne!(base, CacheKey::of(0, Instance::G4dn, Instance::P3, 42.6, &p));
        let p2 = profile(&[("Conv2D", 287.0)]);
        assert_ne!(base, CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p2));
        let p3 = profile(&[("Conv2D", 286.0), ("Relu", 1.0)]);
        assert_ne!(base, CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p3));
    }

    /// A registry swap bumps the epoch; keys from different epochs must
    /// never collide (this is how a publish invalidates stale entries
    /// without flushing the cache).
    #[test]
    fn epoch_separates_otherwise_identical_keys() {
        let p = profile(&[("Conv2D", 286.0)]);
        let e0 = CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p);
        let e1 = CacheKey::of(1, Instance::G4dn, Instance::P3, 42.5, &p);
        assert_ne!(e0, e1);
        assert_ne!(e0.route, e1.route);
        let cache = PredictionCache::new(4, 64);
        cache.insert(e0, (1.0, Member::Forest));
        // a lookup under the new epoch misses the old entry
        assert!(cache.peek(&e1).is_none());
    }

    #[test]
    fn quantization_granularity() {
        let p = profile(&[("Conv2D", 286.0)]);
        // below a quantization bucket (2^-20 ms): same key
        let near = profile(&[("Conv2D", 286.0 + 1e-8)]);
        assert_eq!(
            CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p),
            CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &near)
        );
        // a few buckets away: distinct key
        let far = profile(&[("Conv2D", 286.0 + 1e-5)]);
        assert_ne!(
            CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p),
            CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &far)
        );
    }

    #[test]
    fn byte_stream_is_injective_over_adversarial_op_names() {
        // without length prefixes, {"A": 0, "B": 7} and one entry whose
        // *name* embeds A's separator + value bytes + "B" would serialize
        // to identical streams and share a cache key
        let p1 = profile(&[("A", 0.0), ("B", 7.0)]);
        let mut tricky = String::from("A\u{1f}");
        tricky.extend(std::iter::repeat('\0').take(16));
        tricky.push('B');
        let p2: BTreeMap<String, f64> = [(tricky, 7.0)].into_iter().collect();
        assert_ne!(
            CacheKey::of(0, Instance::G4dn, Instance::P3, 1.0, &p1),
            CacheKey::of(0, Instance::G4dn, Instance::P3, 1.0, &p2)
        );
    }

    #[test]
    fn absurd_scale_values_do_not_alias() {
        // quantize() falls back to bit patterns instead of saturating
        let a = profile(&[("Conv2D", 1e300)]);
        let b = profile(&[("Conv2D", 2e300)]);
        assert_ne!(
            CacheKey::of(0, Instance::G4dn, Instance::P3, 1.0, &a),
            CacheKey::of(0, Instance::G4dn, Instance::P3, 1.0, &b)
        );
        let p = profile(&[("Conv2D", 1.0)]);
        assert_ne!(
            CacheKey::of(0, Instance::G4dn, Instance::P3, 1e14, &p),
            CacheKey::of(0, Instance::G4dn, Instance::P3, 2e14, &p)
        );
        // the tag bit keeps the fallback branch disjoint from the
        // quantized branch even for large-negative values, whose raw bit
        // patterns (as integers) land inside the quantized range
        let neg_huge = -1.7e308f64;
        let in_band = (neg_huge.to_bits() as i64) as f64 / (1u64 << 20) as f64;
        assert_ne!(
            CacheKey::of(0, Instance::G4dn, Instance::P3, 1.0, &profile(&[("Conv2D", neg_huge)])),
            CacheKey::of(0, Instance::G4dn, Instance::P3, 1.0, &profile(&[("Conv2D", in_band)]))
        );
    }

    #[test]
    fn keyed_shares_profile_bytes_across_targets() {
        let p = profile(&[("Conv2D", 286.0), ("Relu", 26.0)]);
        let pf = ProfileFingerprint::of(&p);
        let k_p3 = CacheKey::keyed(0, Instance::G4dn, Instance::P3, 42.5, &pf);
        let k_p2 = CacheKey::keyed(0, Instance::G4dn, Instance::P2, 42.5, &pf);
        // same key as the from-scratch constructor
        assert_eq!(k_p3, CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p));
        // distinct keys, distinct shard routes, shared byte allocation
        assert_ne!(k_p3, k_p2);
        assert_ne!(k_p3.route, k_p2.route);
        assert!(std::sync::Arc::ptr_eq(&k_p3.bytes, &k_p2.bytes));
    }

    #[test]
    fn get_insert_and_counters() {
        let cache = PredictionCache::new(4, 64);
        let stats = CacheStats::default();
        let p = profile(&[("Conv2D", 286.0)]);
        let key = CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p);
        assert!(cache.get(&key, &stats).is_none());
        cache.insert(key.clone(), (123.456, Member::Forest));
        let (v, m) = cache.get(&key, &stats).unwrap();
        assert_eq!(v.to_bits(), 123.456f64.to_bits());
        assert_eq!(m, Member::Forest);
        assert_eq!(stats.hits.load(Ordering::Relaxed), 1);
        assert_eq!(stats.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn capacity_bound_with_fifo_eviction() {
        let cache = PredictionCache::new(2, 8);
        let stats = CacheStats::default();
        let keys: Vec<CacheKey> = (0..200)
            .map(|i| {
                let p = profile(&[("Conv2D", i as f64)]);
                CacheKey::of(0, Instance::G4dn, Instance::P3, 1.0, &p)
            })
            .collect();
        for (i, k) in keys.iter().enumerate() {
            cache.insert(k.clone(), (i as f64, Member::Linear));
        }
        assert!(cache.len() <= cache.capacity(), "{}", cache.len());
        // newest keys survive, oldest were evicted from their shard
        assert!(cache.get(keys.last().unwrap(), &stats).is_some());
        assert!(cache.get(&keys[0], &stats).is_none());
    }

    #[test]
    fn reinsert_does_not_duplicate_fifo_entries() {
        let cache = PredictionCache::new(1, 4);
        let p = profile(&[("Conv2D", 1.0)]);
        let key = CacheKey::of(0, Instance::G4dn, Instance::P3, 1.0, &p);
        for _ in 0..100 {
            cache.insert(key.clone(), (1.0, Member::Dnn));
        }
        assert_eq!(cache.len(), 1);
        let shard = cache.shard_of(&key).lock().unwrap();
        assert_eq!(shard.fifo.len(), 1);
    }

    #[test]
    fn concurrent_access_smoke() {
        use std::sync::Arc;
        let cache = Arc::new(PredictionCache::new(8, 1024));
        let stats = Arc::new(CacheStats::default());
        let mut joins = Vec::new();
        for t in 0..4 {
            let cache = cache.clone();
            let stats = stats.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    let p = profile(&[("Conv2D", (i % 64) as f64)]);
                    let key = CacheKey::of(0, Instance::G4dn, Instance::P3, t as f64, &p);
                    cache.insert(key.clone(), (i as f64, Member::Forest));
                    assert!(cache.get(&key, &stats).is_some());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert!(cache.len() <= cache.capacity());
    }

    #[test]
    fn scratch_built_keys_match_the_owned_constructor() {
        let p = profile(&[("Conv2D", 286.0), ("Relu", 26.5), ("A\u{1f}b", 1.0)]);
        let owned = CacheKey::of(0, Instance::G4dn, Instance::P3, 42.5, &p);
        let mut scratch = CacheKeyScratch::default();
        // BTreeMap iteration is already sorted/deduped — the contract the
        // wire layer upholds via sort_dedup_pairs
        let built = scratch.key(
            0,
            Instance::G4dn,
            Instance::P3,
            42.5,
            p.iter().map(|(k, v)| (k.as_str(), *v)),
        );
        assert_eq!(built, owned);
        assert_eq!(built.route, owned.route);
        // peek finds entries inserted under the owned key
        let cache = PredictionCache::new(4, 64);
        cache.insert(owned, (9.5, Member::Dnn));
        assert_eq!(scratch_peek(&cache, &built), Some((9.5, Member::Dnn)));
        drop(built);
        // the scratch reuses its byte allocation once the key is dropped
        let before = std::sync::Arc::as_ptr(scratch.bytes.as_ref().unwrap());
        let again = scratch.key(
            0,
            Instance::G4dn,
            Instance::P3,
            42.5,
            p.iter().map(|(k, v)| (k.as_str(), *v)),
        );
        assert_eq!(std::sync::Arc::as_ptr(scratch.bytes.as_ref().unwrap()), before);
        // ...and self-heals (fresh allocation) when a previous key is
        // retained by the cache, instead of mutating shared bytes
        cache.insert(again, (9.5, Member::Dnn));
        let healed = scratch.key(
            0,
            Instance::G4dn,
            Instance::P2,
            1.0,
            p.iter().map(|(k, v)| (k.as_str(), *v)),
        );
        assert_ne!(
            std::sync::Arc::as_ptr(scratch.bytes.as_ref().unwrap()),
            before
        );
        assert_eq!(healed.target, Instance::P2);
    }

    fn scratch_peek(cache: &PredictionCache, key: &CacheKey) -> Option<(f64, Member)> {
        cache.peek(key)
    }
}
