//! Constrained deployment planning over swept candidates: "cheapest under
//! deadline D", "fastest under budget B", and epochs-to-deadline.
//!
//! All selections are deterministic: score ties fall through to the
//! candidate's total-order [`Candidate::tie_key`].

use super::sweep::Candidate;
use crate::util::cmp_f64;

/// The training job being planned: a dataset swept `epochs` times.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainingJob {
    pub dataset_images: f64,
    pub epochs: f64,
}

/// What the planner optimizes, and under which constraint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Minimize cost among candidates finishing within the deadline.
    CheapestUnderDeadline { deadline_hours: f64 },
    /// Minimize wall time among candidates within the budget.
    FastestUnderBudget { budget_usd: f64 },
    /// Maximize whole epochs completed by the deadline (the job's `epochs`
    /// field is ignored; ties go to the cheaper candidate).
    MaxEpochsUnderDeadline { deadline_hours: f64 },
}

/// The planner's pick: candidate index plus its realized schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanChoice {
    pub index: usize,
    pub hours: f64,
    pub cost_usd: f64,
    pub epochs: f64,
}

/// Wall-clock hours for the full job on one candidate.
pub fn hours(c: &Candidate, job: &TrainingJob) -> f64 {
    job.epochs * job.dataset_images / c.imgs_per_s / 3600.0
}

/// Total cost (USD) for the full job on one candidate.
pub fn cost_usd(c: &Candidate, job: &TrainingJob) -> f64 {
    hours(c, job) * c.price_hr
}

/// Pick the best candidate for `objective`; `None` when no candidate
/// satisfies the constraint (or `cands` is empty).
pub fn plan(cands: &[Candidate], job: &TrainingJob, objective: &Objective) -> Option<PlanChoice> {
    match *objective {
        Objective::CheapestUnderDeadline { deadline_hours } => cands
            .iter()
            .enumerate()
            .map(|(i, c)| (i, c, hours(c, job), cost_usd(c, job)))
            .filter(|&(_, _, h, _)| h <= deadline_hours)
            .min_by(|a, b| {
                cmp_f64(a.3, b.3)
                    .then(cmp_f64(a.2, b.2))
                    .then(a.1.tie_key().cmp(&b.1.tie_key()))
            })
            .map(|(i, _, h, cost)| PlanChoice {
                index: i,
                hours: h,
                cost_usd: cost,
                epochs: job.epochs,
            }),
        Objective::FastestUnderBudget { budget_usd } => cands
            .iter()
            .enumerate()
            .map(|(i, c)| (i, c, hours(c, job), cost_usd(c, job)))
            .filter(|&(_, _, _, cost)| cost <= budget_usd)
            .min_by(|a, b| {
                cmp_f64(a.2, b.2)
                    .then(cmp_f64(a.3, b.3))
                    .then(a.1.tie_key().cmp(&b.1.tie_key()))
            })
            .map(|(i, _, h, cost)| PlanChoice {
                index: i,
                hours: h,
                cost_usd: cost,
                epochs: job.epochs,
            }),
        Objective::MaxEpochsUnderDeadline { deadline_hours } => cands
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let epochs =
                    (deadline_hours * 3600.0 * c.imgs_per_s / job.dataset_images).floor();
                (epochs >= 1.0).then_some((i, c, epochs))
            })
            .max_by(|a, b| {
                cmp_f64(a.2, b.2)
                    // more epochs wins; then cheaper per image; tie_key is
                    // inverted because max_by keeps the *greatest* element
                    .then(cmp_f64(b.1.cost_per_img_usd, a.1.cost_per_img_usd))
                    .then(b.1.tie_key().cmp(&a.1.tie_key()))
            })
            .map(|(i, c, epochs)| {
                let one_epoch = TrainingJob {
                    dataset_images: job.dataset_images,
                    epochs,
                };
                PlanChoice {
                    index: i,
                    hours: hours(c, &one_epoch),
                    cost_usd: cost_usd(c, &one_epoch),
                    epochs,
                }
            }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Instance;
    use crate::sim::cost_model::Pricing;

    /// `latency_ms` for batch 64, priced at `price_hr`.
    fn cand(target: Instance, latency_ms: f64, price_hr: f64) -> Candidate {
        let imgs_per_s = 64.0 * 1e3 / latency_ms;
        Candidate {
            target,
            batch: 64,
            pixels: 64,
            n_gpus: 1,
            pricing: Pricing::OnDemand,
            latency_ms,
            imgs_per_s,
            price_hr,
            cost_per_img_usd: price_hr / 3600.0 / imgs_per_s,
        }
    }

    // Throughputs: fast = 640 img/s at $3.60/hr, slow = 64 img/s at $0.36/hr.
    fn fixture() -> Vec<Candidate> {
        vec![
            cand(Instance::P3, 100.0, 3.6),
            cand(Instance::G3s, 1000.0, 0.36),
        ]
    }

    // job: 230400 images x 1 epoch -> fast: 0.1 h / $0.36; slow: 1 h / $0.36.
    fn job() -> TrainingJob {
        TrainingJob {
            dataset_images: 230_400.0,
            epochs: 1.0,
        }
    }

    #[test]
    fn schedule_arithmetic() {
        let c = fixture();
        assert!((hours(&c[0], &job()) - 0.1).abs() < 1e-12);
        assert!((hours(&c[1], &job()) - 1.0).abs() < 1e-12);
        assert!((cost_usd(&c[0], &job()) - 0.36).abs() < 1e-12);
        assert!((cost_usd(&c[1], &job()) - 0.36).abs() < 1e-12);
    }

    #[test]
    fn cheapest_under_deadline() {
        let c = fixture();
        // generous deadline: both feasible, equal cost -> lower hours wins
        let p = plan(
            &c,
            &job(),
            &Objective::CheapestUnderDeadline { deadline_hours: 2.0 },
        )
        .unwrap();
        assert_eq!(p.index, 0);
        // tight deadline: only the fast candidate fits
        let p = plan(
            &c,
            &job(),
            &Objective::CheapestUnderDeadline { deadline_hours: 0.5 },
        )
        .unwrap();
        assert_eq!(p.index, 0);
        assert!((p.hours - 0.1).abs() < 1e-12);
        // impossible deadline
        assert!(plan(
            &c,
            &job(),
            &Objective::CheapestUnderDeadline { deadline_hours: 0.01 },
        )
        .is_none());
    }

    #[test]
    fn cheapest_prefers_lower_cost_when_costs_differ() {
        let mut c = fixture();
        c[1].price_hr = 0.18; // slow candidate now half the job cost
        c[1].cost_per_img_usd /= 2.0;
        let p = plan(
            &c,
            &job(),
            &Objective::CheapestUnderDeadline { deadline_hours: 2.0 },
        )
        .unwrap();
        assert_eq!(p.index, 1);
        assert!((p.cost_usd - 0.18).abs() < 1e-12);
    }

    #[test]
    fn fastest_under_budget() {
        let c = fixture();
        // both within budget -> fastest
        let p = plan(&c, &job(), &Objective::FastestUnderBudget { budget_usd: 1.0 }).unwrap();
        assert_eq!(p.index, 0);
        // budget below both -> infeasible
        assert!(plan(&c, &job(), &Objective::FastestUnderBudget { budget_usd: 0.1 }).is_none());
    }

    #[test]
    fn max_epochs_under_deadline() {
        let c = fixture();
        // 1 hour: fast does 10 epochs, slow does 1 -> fast wins with 10
        let p = plan(
            &c,
            &job(),
            &Objective::MaxEpochsUnderDeadline { deadline_hours: 1.0 },
        )
        .unwrap();
        assert_eq!((p.index, p.epochs as u64), (0, 10));
        assert!((p.hours - 1.0).abs() < 1e-12);
        // too short for even one epoch anywhere
        assert!(plan(
            &c,
            &job(),
            &Objective::MaxEpochsUnderDeadline { deadline_hours: 0.05 },
        )
        .is_none());
    }

    #[test]
    fn empty_candidates() {
        assert!(plan(&[], &job(), &Objective::FastestUnderBudget { budget_usd: 1e9 }).is_none());
    }
}
