//! Cost-aware instance advisor (paper Sec II / Fig 2): turns PROFET
//! *predictions* into *recommendations* — which instance, batch size,
//! pixel size, GPU count, and purchase option to train on.
//!
//! * [`sweep`] — evaluate a profiled workload across the whole candidate
//!   grid by composing phase-1 cross-instance prediction with the
//!   batch/pixel interpolation models (batched, cache-first);
//! * [`pareto`] — the cost-latency Pareto frontier over swept candidates;
//! * [`plan`] — constrained queries: cheapest under deadline, fastest
//!   under budget, epochs-to-deadline;
//! * [`cache`] — sharded, capacity-bounded memoization of phase-1
//!   predictions (hits are bitwise-equal to cold predictions).
//!
//! Served through the coordinator's `recommend` and `plan` ops; usable
//! in-process via [`sweep::sweep`] (see `examples/instance_recommender.rs`).

pub mod cache;
pub mod pareto;
pub mod plan;
pub mod sweep;

pub use cache::{CacheKey, CacheKeyScratch, CacheStats, PredictionCache, ProfileFingerprint};
pub use pareto::{dominates, pareto_frontier, pareto_frontier_naive};
pub use plan::{cost_usd, hours, plan, Objective, PlanChoice, TrainingJob};
pub use sweep::{rank_candidates, sweep, Candidate, EndpointProfiles, SweepRequest};
